"""Beyond-paper scaling benches: worker-count scaling (the paper's
'configurable scaling' §III) and gradient-compression shuffle volume —
the training-plane analogue of the combiner claim."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import compress_int8

from .common import INPUT_SIZES, fmt_csv, run_paper_job


def run(print_rows=True) -> list[str]:
    rows = []
    n = INPUT_SIZES[3]
    # worker scaling at fixed input: more mappers → less mapper wall time
    base = None
    for m in (1, 2, 4, 8):
        report, wall, _, _ = run_paper_job(n, cold_start=0.0, n_mappers=m,
                                           n_reducers=2)
        comp = report.component_times()
        base = base or comp["mapper"]
        rows.append(fmt_csv(f"scaling/mappers_{m}", wall * 1e6,
                            f"mapper_avg_s={comp['mapper']:.4f};"
                            f"speedup_vs_m1={base/comp['mapper']:.2f}"))

    # gradient compression: spill-volume reduction on the wire
    g = jnp.asarray(np.random.default_rng(0).normal(size=(1 << 20,)),
                    jnp.float32)
    q, scale = jax.jit(compress_int8)(g)
    raw, comp_b = g.size * 4, q.size * 1 + 4
    rows.append(fmt_csv("scaling/grad_compression_1M", 0.0,
                        f"bytes {raw}->{comp_b} ({raw/comp_b:.2f}x);"
                        f"max_err={float(jnp.max(jnp.abs(g - q*scale))):.4f}"))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
