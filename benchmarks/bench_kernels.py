"""Kernel micro-benchmarks: XLA reference path timings on CPU (wall time is
hardware-bound here; the TPU story is the §Roofline analysis) plus
combiner-volume derived metrics that mirror the paper's combiner claim."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import chunked_attention
from repro.kernels.flash_attention.ref import decode_ref
from repro.kernels.hash_combine.ref import hash_combine_ref
from repro.kernels.mamba_scan.ref import selective_scan_ref

from .common import fmt_csv


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(print_rows=True) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)

    # combiner: volume reduction factor at paper-like key skew
    n, buckets = 1 << 16, 4096
    keys = jnp.asarray(np.minimum(rng.zipf(1.3, n), buckets) - 1, jnp.int32)
    vals = jnp.ones((n,), jnp.float32)
    f = jax.jit(lambda k, v: hash_combine_ref(k, v, buckets))
    us = _time(f, keys, vals)
    uniques = int(len(np.unique(np.asarray(keys))))
    rows.append(fmt_csv("kernels/hash_combine/64k_records", us,
                        f"volume_reduction={n/uniques:.1f}x"))

    # flash attention fwd (chunked XLA path)
    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 128)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 1024, 128)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 1024, 128)), jnp.float32)
    f = jax.jit(lambda a, b, c: chunked_attention(a, b, c, causal=True,
                                                  chunk=256))
    us = _time(f, q, k, v)
    flops = 4 * 1 * 8 * 1024 * 1024 * 128
    rows.append(fmt_csv("kernels/flash_attention/b1_h8_s1024_d128", us,
                        f"gflops_per_s={flops/us/1e3:.1f}"))

    # flash decode against a 16k cache
    qd = jnp.asarray(rng.normal(size=(4, 8, 128)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(4, 2, 16384, 128)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(4, 2, 16384, 128)), jnp.float32)
    lens = jnp.full((4,), 16000, jnp.int32)
    f = jax.jit(lambda a, b, c, ln: decode_ref(a, b, c, ln))
    us = _time(f, qd, kc, vc, lens)
    rows.append(fmt_csv("kernels/flash_decode/b4_h8_s16k", us,
                        f"bytes_touched={2*4*2*16384*128*4}"))

    # fused streaming fold: the pallas backend's one-kernel hash → window
    # fan-out → (slot, bucket) scatter-accumulate vs the XLA chain it
    # replaces, at several batch × slots × buckets shapes.  On this CPU the
    # XLA ref JITs to native code while the kernel runs under the pallas
    # interpreter, so the pair tracks decode/dispatch overhead, not the TPU
    # win — that is the roofline streaming-fold row.  Interpret timing is
    # only taken at the smallest shape to keep the bench budget flat.
    from repro.kernels.fused_fold.ops import fold
    for n, n_slots, nb in [(4096, 8, 64), (16384, 8, 256), (16384, 16, 1024)]:
        cols = [rng.integers(0, 3 * n_slots, n), rng.integers(1, 5, n),
                rng.integers(0, 1 << 20, n), rng.integers(0, 100, n),
                np.ones(n)]
        frows = jnp.asarray(np.stack(cols, axis=1), jnp.float32)
        carry = jnp.zeros((n_slots * nb, 2), jnp.float32)
        kwf = dict(fanout=4, n_slots=n_slots, num_buckets=nb,
                   carry_buckets=nb, hashed=True, kind="sum")
        us = _time(lambda r, c: fold(r, c, 0, use_pallas=False, **kwf),
                   frows, carry)
        derived = (f"pairs_per_s={4 * n / us * 1e6:.0f};"
                   f"carry_cells={n_slots * nb}")
        if n == 4096:
            us_pal = _time(lambda r, c: fold(r, c, 0, use_pallas=True,
                                             interpret=True, **kwf),
                           frows, carry, n=2)
            derived += f";pallas_interpret_us={us_pal:.0f}"
        rows.append(fmt_csv(
            f"kernels/fused_fold/n{n}_s{n_slots}_b{nb}", us, derived))

    # mamba selective scan
    b, L, d, ns = 1, 1024, 512, 16
    u = jnp.asarray(rng.normal(size=(b, L, d)), jnp.float32)
    delta = jnp.asarray(np.abs(rng.normal(size=(b, L, d))) * 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(rng.normal(size=(d, ns))) + 0.5, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b, L, ns)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, L, ns)), jnp.float32)
    D = jnp.ones((d,), jnp.float32)
    f = jax.jit(lambda *a: selective_scan_ref(*a)[0])
    us = _time(f, u, delta, A, Bm, C, D)
    rows.append(fmt_csv("kernels/mamba_scan/b1_L1024_d512_n16", us,
                        f"tokens_per_s={b*L/us*1e6:.0f}"))

    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
