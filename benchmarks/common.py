"""Shared benchmark scaffolding: the paper's experimental configuration.

§IV-C: combiner + Finalizer enabled, 50 MB input/output buffers, 5 MB
multipart, merge fan-in 100, spill threshold 75%, 4 Mappers / 2 Reducers.
Input sizes are scaled to CPU-container scale (the paper's shape, not its
absolute magnitudes); the autoscaler injects a Knative-like cold start so
the small-input regime reproduces Fig. 6's flat region.
"""

from __future__ import annotations

import time

from repro.core import (AutoscalerConfig, Coordinator, MemoryStore,
                        MetadataStore, make_wordcount_job)
from repro.data.pipeline import synth_corpus

MB = 1024 * 1024

# paper §IV-C configuration (buffers kept at paper values; they exceed the
# scaled corpus sizes, so the threshold mechanics still engage via ratio)
PAPER_JOB = dict(
    n_mappers=4,
    n_reducers=2,
    run_combiner=True,
    run_finalizer=True,
    input_buffer_bytes=50 * MB,
    output_buffer_bytes=50 * MB,
    multipart_bytes=5 * MB,
    merge_fan_in=100,
    spill_threshold=0.75,
)

COLD_START_S = 0.08          # Knative-ish activation delay (scaled)

# input sizes (bytes of preprocessed text) — the paper's x-axis shape
INPUT_SIZES = [64 * 1024, 256 * 1024, 1 * MB, 4 * MB, 16 * MB]


def corpus_of_bytes(n_bytes: int, seed: int = 0) -> str:
    words = synth_corpus(max(64, n_bytes // 6), vocab_words=5000, seed=seed)
    return words[:n_bytes]


def run_paper_job(n_bytes: int, cold_start: float = COLD_START_S,
                  seed: int = 0, **overrides):
    store = MemoryStore()
    store.put("input/corpus.txt", corpus_of_bytes(n_bytes, seed).encode())
    meta = MetadataStore()
    coord = Coordinator(
        store, meta,
        autoscaler=AutoscalerConfig(cold_start=cold_start, max_scale=16,
                                    scale_to_zero_grace=10.0),
        speculative_execution=False)
    cfg = make_wordcount_job(**{**PAPER_JOB, **overrides})
    t0 = time.perf_counter()
    report = coord.run_job(cfg)
    wall = time.perf_counter() - t0
    assert report.state.value == "DONE", report.error
    return report, wall, coord, store


def fmt_csv(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
