"""§Perf hillclimb driver: compile a cell under a set of optimization opts
and report the three roofline terms + deltas vs the recorded baseline.

  PYTHONPATH=src python -m benchmarks.hillclimb --arch yi-34b \
      --shape train_4k --opts act=sp,zero2=1 [--deploy] [--save tag]
"""

from __future__ import annotations

import argparse
import json
import os

from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh
from repro import configs
from repro.models import SHAPES_BY_NAME

from .roofline import (HBM_BW, LINK_BW, PEAK_FLOPS,
                       _attention_correction, _mamba_correction,
                       model_flops_per_device)


def parse_opts(s: str) -> dict:
    out = {}
    if not s:
        return out
    for kv in s.split(","):
        k, _, v = kv.partition("=")
        out[k] = (v in ("1", "true", "True")) if v in (
            "0", "1", "true", "false", "True", "False") else v
    return out


def terms(arch: str, shape_name: str, flops, hbm, coll):
    cfg = configs.get(arch)
    shape = SHAPES_BY_NAME[shape_name]
    corr = _attention_correction(cfg, shape) + _mamba_correction(cfg, shape)
    flops = flops + corr
    t = {"t_compute": flops / PEAK_FLOPS, "t_memory": hbm / HBM_BW,
         "t_collective": coll / LINK_BW}
    bound = max(t.values())
    mf = model_flops_per_device(cfg, shape)
    t["roofline_fraction"] = (mf / PEAK_FLOPS) / bound if bound else 0.0
    t["dominant"] = max(t, key=lambda k: t[k] if k.startswith("t_") else -1)
    return t


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--opts", default="")
    ap.add_argument("--deploy", action="store_true",
                    help="also compile the deploy variant (memory check)")
    ap.add_argument("--baseline-dir", default="results/dryrun")
    ap.add_argument("--save", default=None,
                    help="save results under results/hillclimb/<save>.json")
    args = ap.parse_args()
    opts = parse_opts(args.opts)

    mesh = make_production_mesh()
    res = dr.account_costs(args.arch, args.shape, mesh, None, opts)
    out = {
        "arch": args.arch, "shape": args.shape, "opts": opts,
        "flops_per_device": res["flops_per_device"],
        "hbm_bytes_per_device": res["hbm_bytes_per_device"],
        "collective_bytes_per_device": res["collective_bytes_per_device"],
    }
    t = terms(args.arch, args.shape, res["flops_per_device"],
              res["hbm_bytes_per_device"],
              res["collective_bytes_per_device"]["total"])
    out.update(t)
    if args.deploy:
        _, compiled, _, tc = dr._compile_variant(args.arch, args.shape, mesh,
                                                 None, "deploy", opts)
        mem = compiled.memory_analysis()
        out["peak_gib"] = (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes) / 2**30
        out["deploy_compile_s"] = tc

    base_path = os.path.join(args.baseline_dir,
                             f"{args.arch}__{args.shape}__single.json")
    if os.path.exists(base_path):
        base = json.load(open(base_path))
        bt = terms(args.arch, args.shape, base["flops_per_device"],
                   base["hbm_bytes_per_device"],
                   base["collective_bytes_per_device"]["total"])
        out["baseline"] = bt
        print(f"--- {args.arch} × {args.shape} with opts={opts}")
        for k in ("t_compute", "t_memory", "t_collective",
                  "roofline_fraction"):
            d = t[k] / bt[k] - 1 if bt[k] else 0.0
            print(f"  {k:20s} {bt[k]:10.4f} → {t[k]:10.4f}  ({d:+.1%})")
        if "peak_gib" in out:
            peak0 = base["bytes_per_device"]["peak_estimate"] / 2 ** 30
            print(f"  peak_gib             {peak0:10.2f} → "
                  f"{out['peak_gib']:10.2f}")
    else:
        print(json.dumps(out, indent=1))
    if args.save:
        os.makedirs("results/hillclimb", exist_ok=True)
        with open(f"results/hillclimb/{args.save}.json", "w") as f:
            json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
