"""Paper Fig. 6: end-to-end execution time vs input size (4 Mappers /
2 Reducers).  Claims validated:

  1. roughly linear total time in the linear regime (large inputs);
  2. a flat, cold-start-dominated region at small inputs.
"""

from __future__ import annotations

from .common import INPUT_SIZES, fmt_csv, run_paper_job


def run(print_rows=True) -> list[str]:
    rows = []
    walls = []
    for n in INPUT_SIZES:
        report, wall, coord, _ = run_paper_job(n)
        cold = sum(p.cold_start_seconds for p in coord.pools.values())
        walls.append(wall)
        rows.append(fmt_csv(
            f"fig6/end_to_end/{n//1024}KiB", wall * 1e6,
            f"cold_start_s={cold:.3f};mappers=4;reducers=2"))
    # derived validation: linearity at the top end, flatness at the bottom
    big_ratio = walls[-1] / walls[-2]
    size_ratio = INPUT_SIZES[-1] / INPUT_SIZES[-2]
    small_ratio = walls[1] / walls[0]
    rows.append(fmt_csv("fig6/linearity", 0.0,
                        f"t({INPUT_SIZES[-1]})/t({INPUT_SIZES[-2]})="
                        f"{big_ratio:.2f}_vs_size_ratio={size_ratio:.1f}"))
    rows.append(fmt_csv("fig6/cold_start_flatness", 0.0,
                        f"t_small_ratio={small_ratio:.2f}_(≈1_means_cold-"
                        f"start-dominated)"))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
