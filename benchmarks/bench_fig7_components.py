"""Paper Fig. 7: average total time per component across input sizes.
Claim validated: the Mapper dominates (buffer sort + combiner before upload);
Coordinator/Splitter/Finalizer overheads stay small."""

from __future__ import annotations

from .common import INPUT_SIZES, fmt_csv, run_paper_job


def run(print_rows=True) -> list[str]:
    rows = []
    for n in INPUT_SIZES[1:4]:
        report, wall, _, _ = run_paper_job(n, cold_start=0.0)
        comp = report.component_times()
        for role in ("splitter", "mapper", "reducer", "finalizer"):
            rows.append(fmt_csv(f"fig7/{role}/{n//1024}KiB",
                                comp.get(role, 0.0) * 1e6,
                                f"share={comp.get(role, 0.0)/max(wall,1e-9):.2f}"))
        dominant = max(comp, key=comp.get)
        rows.append(fmt_csv(f"fig7/dominant/{n//1024}KiB", 0.0,
                            f"component={dominant}"))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
