"""Roofline analysis (§Roofline): three terms per (arch × shape) from the
dry-run artifacts in results/dryrun/*.json.

  compute term    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF bf16)
  memory term     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
  collective term = collective_bytes_per_device / link_bw      (50 GB/s)

cost_analysis()/memory_analysis() on the SPMD executable are per-device, so
no further division by chip count is needed.  Two analytic corrections cover
FLOPs that live inside *data* loops the cost model counts once
(EXPERIMENTS.md §Dry-run methodology):

  * chunked-attention inner scan: the implementation computes full
    rectangular (Sq × Skv) scores chunk by chunk; HLO saw one chunk →
    add (n_chunks−1)/n_chunks of the analytic attention matmul FLOPs;
  * mamba1 time-step scan: ≈ 9·B·L·d_inner·N VPU flops per pass.

Train multiplier for corrected terms: forward + remat recompute + backward
≈ 4× the forward matmul FLOPs under full-layer checkpointing.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import json
import os

from repro import configs
from repro.models import SHAPES_BY_NAME

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per link
CHIPS = 256                  # single-pod roofline


def _attention_correction(cfg, shape) -> float:
    """Analytic attention matmul FLOPs *missing* from the HLO count
    (the (n_chunks-1)/n_chunks of the chunked score/value matmuls),
    per device."""
    if cfg.layer_kind != "attn" and cfg.shared_attn_every == 0:
        return 0.0
    s = shape.seq_len
    if shape.kind == "decode":
        return 0.0               # decode attention has no chunk loop
    b_global = shape.global_batch
    if shape.kind == "train":
        b_global = shape.global_batch  # all microbatches per step
    hd = cfg.head_dim_
    n_chunks = max(1, s // cfg.attn_chunk)
    missing_frac = (n_chunks - 1) / n_chunks
    # per layer fwd: QK^T + PV = 4 · B · H · Sq · Skv · hd (full rectangle —
    # the chunked implementation does not skip masked chunks)
    per_layer = 4.0 * b_global * cfg.n_heads * s * s * hd
    n_attn = cfg.n_layers if cfg.layer_kind == "attn" else 0
    if cfg.shared_attn_every > 0:
        n_attn += cfg.n_layers // cfg.shared_attn_every
    mult = 4.0 if shape.kind == "train" else 1.0   # fwd+remat+bwd
    return per_layer * n_attn * mult * missing_frac / CHIPS


def _mamba_correction(cfg, shape) -> float:
    if cfg.layer_kind != "mamba1":
        return 0.0
    if shape.kind == "decode":
        return 0.0
    tokens = shape.global_batch * shape.seq_len
    per_layer = 9.0 * tokens * cfg.d_inner_ * cfg.ssm_state
    mult = 4.0 if shape.kind == "train" else 1.0
    return per_layer * cfg.n_layers * mult / CHIPS


def model_flops_per_device(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for train;
    2·N(_active)·D for serving passes; per device."""
    n = cfg.n_active_params() if cfg.is_moe else cfg.n_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / CHIPS
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / CHIPS
    tokens = shape.global_batch            # one token per sequence
    return 2.0 * n * tokens / CHIPS


def analyse(dirpath: str, mesh: str = "single") -> list[dict]:
    rows = []
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        for fname in sorted(os.listdir(dirpath)):
            if not fname.startswith(f"{arch}__") or \
                    not fname.endswith(f"__{mesh}.json"):
                continue
            cell = json.load(open(os.path.join(dirpath, fname)))
            if "flops_per_device" not in cell:
                continue
            shape = SHAPES_BY_NAME[cell["shape"]]
            corr = _attention_correction(cfg, shape) + \
                _mamba_correction(cfg, shape)
            flops = cell["flops_per_device"] + corr
            hbm = cell["hbm_bytes_per_device"]
            coll = cell["collective_bytes_per_device"]["total"]
            t_c = flops / PEAK_FLOPS
            t_m = hbm / HBM_BW
            t_x = coll / LINK_BW
            dom = max(("compute", t_c), ("memory", t_m),
                      ("collective", t_x), key=lambda kv: kv[1])[0]
            mf = model_flops_per_device(cfg, shape)
            bound = max(t_c, t_m, t_x)
            rows.append({
                "arch": arch, "shape": cell["shape"],
                "flops_per_dev": flops, "hbm_bytes": hbm, "coll_bytes": coll,
                "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
                "dominant": dom,
                "model_flops": mf,
                "useful_ratio": mf / flops if flops else 0.0,
                "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
                "peak_gib": cell["bytes_per_device"]["peak_estimate"] / 2**30,
                "corrections": corr,
            })
    return rows


# -- streaming fold (kernels/fused_fold) placement -----------------------------
#
# The streaming engine's per-batch fold — hash, window fan-out, (slot,
# bucket) scatter-accumulate — does a handful of VPU ops per byte, so on
# any accelerator it sits deep in the memory-bound region of the roofline
# and runtime ∝ HBM bytes moved.  Its placement is therefore the fraction
# of peak bandwidth spent on *useful* traffic: the wire rows in, plus one
# read-modify-write of the carry slab (S·C cells, S = n_slots · buckets).
#
#   useful     = n·row_bytes + 2·S·C·4
#   fused      = useful + (tiles_s − 1)·n·row_bytes      (rows re-stream
#                once per extra carry tile; one tile at these sizes)
#   xla chain  = useful + hash ids (w+r) + the fan-out-expanded
#                (slot, bucket, value, valid) pair matrix (w + one read
#                per scatter pass: values, then counts)
#
# The fused kernel keeps the expansion in registers/VMEM, so its % of
# peak bandwidth is ~100 and the XLA chain's falls with fanout — that
# ratio is the kernel's headroom on real hardware (CPU interpret-mode
# timings in bench_kernels.py cannot show it).

FOLD_SHAPES = [
    # (n records, fanout, n_slots, buckets, channels)
    (16384, 1, 8, 256, 2),
    (16384, 4, 8, 256, 2),
    (65536, 4, 16, 1024, 2),
    (65536, 8, 16, 4096, 4),
]


def streaming_fold_rows(shapes=FOLD_SHAPES) -> list[dict]:
    out = []
    for n, fanout, n_slots, buckets, ch in shapes:
        row_b = 5 * 4 if fanout > 1 else 4 * 4
        s = n_slots * buckets
        m = n * fanout                       # fan-out-expanded pair count
        useful = n * row_b + 2 * s * ch * 4
        tiles_s = 1                          # carry fits one VMEM tile here
        fused = useful + (tiles_s - 1) * n * row_b
        xla = useful + 2 * n * 4 + m * 16 * 3
        out.append({
            "shape": f"n{n}_f{fanout}_s{n_slots}_b{buckets}",
            "useful_bytes": useful, "fused_bytes": fused, "xla_bytes": xla,
            "pct_peak_bw_fused": 100.0 * useful / fused,
            "pct_peak_bw_xla": 100.0 * useful / xla,
            "t_mem_fused_s": fused / HBM_BW,
            "t_mem_xla_s": xla / HBM_BW,
            "speedup": xla / fused,
        })
    return out


def print_fold_table(rows: list[dict]) -> None:
    hdr = (f"{'streaming_fold':24s} {'useful_MB':>10s} {'fused_MB':>9s} "
           f"{'xla_MB':>9s} {'%bw_fused':>10s} {'%bw_xla':>8s} "
           f"{'speedup':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['shape']:24s} {r['useful_bytes']/2**20:10.2f} "
              f"{r['fused_bytes']/2**20:9.2f} {r['xla_bytes']/2**20:9.2f} "
              f"{r['pct_peak_bw_fused']:10.1f} {r['pct_peak_bw_xla']:8.1f} "
              f"{r['speedup']:7.2f}x")


def print_table(rows: list[dict]) -> None:
    hdr = (f"{'arch':18s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dom':>10s} {'useful':>7s} {'roofline':>9s} "
           f"{'peakGiB':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:18s} {r['shape']:12s} "
              f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
              f"{r['t_collective_s']:9.4f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.2f} {r['roofline_fraction']:9.3f} "
              f"{r['peak_gib']:8.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = analyse(args.dir) if os.path.isdir(args.dir) else []
    if rows:
        print_table(rows)
        print()
    fold_rows = streaming_fold_rows()
    print_fold_table(fold_rows)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"archs": rows, "streaming_fold": fold_rows}, f,
                      indent=1)


if __name__ == "__main__":
    main()
