"""Paper Fig. 8: stacked per-phase (processing / uploading / downloading)
time per component.  Claims validated: Mapper is processing-heavy (sort +
combiner), Reducer splits between download (spill fetch) and processing
(merge + reduce)."""

from __future__ import annotations

from .common import INPUT_SIZES, fmt_csv, run_paper_job


def run(print_rows=True) -> list[str]:
    rows = []
    n = INPUT_SIZES[3]
    report, _, _, store = run_paper_job(n, cold_start=0.0)
    phases = report.phase_times()
    for role, ph in sorted(phases.items()):
        total = sum(ph.values()) or 1e-9
        for phase in ("processing", "uploading", "downloading"):
            rows.append(fmt_csv(
                f"fig8/{role}/{phase}/{n//1024}KiB", ph[phase] * 1e6,
                f"share={ph[phase]/total:.2f}"))
    m = phases.get("mapper", {})
    if m:
        rows.append(fmt_csv(
            "fig8/mapper_processing_dominates", 0.0,
            f"processing>{'upload' if m['processing'] > m['uploading'] else 'FAIL'}"))
    rows.append(fmt_csv("fig8/shuffle_traffic_bytes", 0.0,
                        f"uploaded={store.bytes_uploaded};"
                        f"downloaded={store.bytes_downloaded}"))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
