# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys


def main() -> None:
    from . import (bench_fig6_end_to_end, bench_fig7_components,
                   bench_fig8_phases, bench_kernels, bench_scaling,
                   bench_streaming)

    print("name,us_per_call,derived")
    failures = []
    for mod in (bench_fig6_end_to_end, bench_fig7_components,
                bench_fig8_phases, bench_kernels, bench_scaling,
                bench_streaming):
        try:
            mod.run(print_rows=True)
        except Exception as exc:  # keep the harness going; report at the end
            failures.append((mod.__name__, exc))
            print(f"{mod.__name__},NaN,FAILED:{exc}")
    if failures:
        sys.exit(f"{len(failures)} benchmark modules failed")


if __name__ == "__main__":
    main()
