"""Streaming engine: sustained records/sec and per-batch latency vs
micro-batch size, plus host vs on-device sliding-window fan-out.

Small batches → low per-window emission delay but per-batch overhead
(dispatch, watermark bookkeeping, one collective per batch) dominates; large
batches amortize it toward the device engine's aggregate throughput.  The
fan-out comparison isolates the execution-plan layer's win: with
slide = size/4 every record belongs to 4 windows, and the host baseline
writes 4 numpy rows per event where the device path ships one row and
replicates on-chip (broadcast + iota).  The DAG fan-out comparison
measures the tee seam: two branches sharing one upstream stage through
per-edge carry handoffs vs the serverless-baseline shape of two separate
jobs each re-ingesting (and re-reducing) the full stream.

Each run appends its numbers to ``BENCH_streaming.json`` at the repo root,
so throughput is tracked as a trajectory across PRs instead of discarded.

CI runs this file on a small fixed config (``BENCH_STREAM_EVENTS`` /
``BENCH_STREAM_BATCHES`` env overrides) with ``--check``, which turns the
steady-state ≤5% pipeline-API overhead guard into a blocking exit code.
"""

from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import MemoryStore, MetadataStore
from repro.pipeline import Pipeline, Windowing
from repro.streaming import (StreamSource, StreamingConfig,
                             StreamingCoordinator)

from .common import fmt_csv

N_EVENTS = int(os.environ.get("BENCH_STREAM_EVENTS", 60_000))
N_KEYS = 64
EVENT_RATE = 200.0           # events per second of event time
BATCH_SIZES = [int(b) for b in os.environ.get(
    "BENCH_STREAM_BATCHES", "256,1024,4096,16384").split(",")]
SLIDING_BATCH = min(4096, max(BATCH_SIZES))
WINDOW_SIZE = 30.0           # sliding comparison: slide = size/4 → fan-out 4
BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_streaming.json"


def synth_stream(n: int = N_EVENTS, seed: int = 0):
    rng = np.random.default_rng(seed)
    ts = np.arange(n) / EVENT_RATE
    keys = rng.integers(0, N_KEYS, n)
    vals = rng.integers(0, 100, n).astype(float)
    return [(float(t), int(k), float(v)) for t, k, v in zip(ts, keys, vals)]


def run_stream_once(events, batch_records: int, *, slide: float | None = None,
                    fanout: str = "device", n_slots: int = 8,
                    job_id: str = "bench"):
    cfg = StreamingConfig(num_buckets=N_KEYS, n_workers=8,
                          window_size=WINDOW_SIZE, window_slide=slide,
                          n_slots=n_slots, batch_records=batch_records,
                          aggregation="sum", fanout=fanout, job_id=job_id)
    coord = StreamingCoordinator(MemoryStore(), MetadataStore(), cfg)
    source = StreamSource.from_records(events, batch_records=batch_records)
    report = coord.run_stream(source)
    return report, coord


def run_pipeline_once(events, batch_records: int, job_id: str):
    """The same tumbling-sum workload authored through the declarative
    Pipeline API — measures the dataflow front door's overhead vs the
    coordinator driving its execution plan off the flat config."""
    pipe = (Pipeline.from_source(records=events,
                                 batch_records=batch_records)
            .key_by().window(Windowing.tumbling(WINDOW_SIZE)).reduce("sum"))
    built = pipe.build(num_buckets=N_KEYS, n_workers=8, n_slots=8,
                       job_id=job_id)
    return built.run_streaming(MemoryStore(), MetadataStore())


def run_multistage_once(events, batch_records: int, job_id: str,
                        handoff: str):
    """A two-phase chain — count per key per window, then top-8 over the
    counts per 4-window span — comparing the on-device carry handoff
    against the host record path at the stage boundary."""
    pipe = (Pipeline.from_source(records=events,
                                 batch_records=batch_records)
            .key_by().window(Windowing.tumbling(WINDOW_SIZE)).reduce("count")
            .window(Windowing.tumbling(4 * WINDOW_SIZE)).reduce("sum")
            .top_k(8))
    built = pipe.build(num_buckets=N_KEYS, n_workers=8, n_slots=8,
                       job_id=job_id, handoff=handoff)
    return built.run_streaming(MemoryStore(), MetadataStore())


def _fanout_branches():
    """The two consumers of the shared per-window count stream: a top-8
    ranking and a coarse re-windowed rollup."""
    top = (Pipeline.branch().window(Windowing.tumbling(4 * WINDOW_SIZE))
           .reduce("sum").top_k(8).sink("bench-top/"))
    roll = (Pipeline.branch().window(Windowing.tumbling(4 * WINDOW_SIZE))
            .reduce("sum").sink("bench-roll/"))
    return top, roll


def run_fanout_tee(events, batch_records: int, job_id: str):
    """DAG fan-out: ingest + count ONCE, tee the counts into both
    branches through per-edge carry handoffs."""
    top, roll = _fanout_branches()
    pipe = (Pipeline.from_source(records=events,
                                 batch_records=batch_records)
            .key_by().window(Windowing.tumbling(WINDOW_SIZE)).reduce("count")
            .tee(top, roll))
    built = pipe.build(num_buckets=N_KEYS, n_workers=8, n_slots=8,
                       job_id=job_id)
    return built.run_streaming(MemoryStore(), MetadataStore())


def run_fanout_reingest(events, batch_records: int, job_id: str):
    """The baseline the paper's loosely-coupled services imply without a
    shared intermediate: one job per consumer, each re-ingesting the full
    stream and recomputing the count stage.  Returns the reports of both
    runs (wall time adds; the shared-handoff tee does this work once)."""
    reports = []
    for bi, branch in enumerate(_fanout_branches()):
        pipe = (Pipeline.from_source(records=events,
                                     batch_records=batch_records)
                .key_by().window(Windowing.tumbling(WINDOW_SIZE))
                .reduce("count"))
        # graft the branch onto a fresh single-consumer chain (each run
        # gets its own store, so the branch sinks cannot collide)
        pipe = Pipeline(pipe.nodes + branch.nodes[1:])
        built = pipe.build(num_buckets=N_KEYS, n_workers=8, n_slots=8,
                           job_id=f"{job_id}-{bi}")
        reports.append(built.run_streaming(MemoryStore(), MetadataStore()))
    return reports


def _append_trajectory(entry: dict) -> None:
    """Append this run to the cross-PR trajectory file (best effort)."""
    try:
        data = json.loads(BENCH_PATH.read_text())
    except (FileNotFoundError, json.JSONDecodeError):
        data = {"schema": 1, "runs": []}
    data["runs"].append(entry)
    BENCH_PATH.write_text(json.dumps(data, indent=1) + "\n")


def run(print_rows: bool = True,
        write_json: bool = True) -> tuple[list[str], dict]:
    events = synth_stream()
    rows = []
    entry: dict = {"unix_time": round(time.time(), 1),
                   "n_events": N_EVENTS,
                   "tumbling_records_per_sec": {},
                   "sliding_fanout_records_per_sec": {}}
    for bs in BATCH_SIZES:
        # warm the jit cache so rows measure the steady state, not compiles
        run_stream_once(events[: 2 * bs], bs, job_id=f"warm-{bs}")
        report, coord = run_stream_once(events, bs, job_id=f"bench-{bs}")
        entry["tumbling_records_per_sec"][str(bs)] = \
            round(report.records_per_sec)
        lat_us = report.mean_batch_latency * 1e6
        rows.append(fmt_csv(
            f"streaming/batch_{bs}", lat_us,
            f"records_per_s={report.records_per_sec:.0f};"
            f"batches={report.batches};"
            f"windows={report.windows_emitted};"
            f"max_lag={report.max_lag};"
            f"pool_replicas={coord.pool_stats()['replicas']}"))
    # sliding windows, slide = size/4: host event×window expansion vs the
    # plan layer's on-chip fan-out (records cross host→device once)
    slide = WINDOW_SIZE / 4.0
    for fanout in ("host", "device"):
        run_stream_once(events[: 2 * SLIDING_BATCH], SLIDING_BATCH,
                        slide=slide, fanout=fanout,
                        job_id=f"warm-{fanout}")
        report, _ = run_stream_once(events, SLIDING_BATCH, slide=slide,
                                    fanout=fanout, job_id=f"slide-{fanout}")
        entry["sliding_fanout_records_per_sec"][fanout] = \
            round(report.records_per_sec)
        rows.append(fmt_csv(
            f"streaming/sliding_fanout_{fanout}",
            report.mean_batch_latency * 1e6,
            f"records_per_s={report.records_per_sec:.0f};"
            f"expanded={report.records_expanded};"
            f"windows={report.windows_emitted}"))
    # the declarative Pipeline API on the tumbling workload: guard that the
    # graph front door costs <= 5% over driving the ExecutionPlan through
    # the flat-config path (same machinery underneath).  Each fresh build
    # re-traces its plan, so the first batch of every run carries the XLA
    # compile — the guard reads *steady-state* batch latency (first batch
    # dropped, median over the rest).  Runs alternate direct/pipeline and
    # the overhead is the MEDIAN of the per-iteration ratios: paired
    # adjacent runs share the machine's momentary load, so a slow window
    # on a shared CI runner cancels out instead of failing the gate; a
    # smaller guard batch keeps the sample count meaningful even when the
    # env overrides shrink the stream

    def steady_latency(report):
        tail = sorted(report.batch_latencies[1:] or report.batch_latencies)
        return tail[len(tail) // 2]

    guard_batch = min(1024, SLIDING_BATCH)
    run_pipeline_once(events[: 2 * guard_batch], guard_batch, "warm-pipe")
    run_stream_once(events[: 2 * guard_batch], guard_batch,
                    job_id="warm-direct")
    ratios, rep_pipe = [], None
    for i in range(5):
        # alternate which path runs first within the pair: whoever runs
        # second eats any within-pair drift (GC debt, thermal ramp), so a
        # fixed order would bias the ratio one way on every iteration
        if i % 2 == 0:
            rep_d, _ = run_stream_once(events, guard_batch,
                                       job_id=f"direct-{i}")
            rep_p = run_pipeline_once(events, guard_batch, f"pipe-{i}")
        else:
            rep_p = run_pipeline_once(events, guard_batch, f"pipe-{i}")
            rep_d, _ = run_stream_once(events, guard_batch,
                                       job_id=f"direct-{i}")
        ratios.append(steady_latency(rep_p) / steady_latency(rep_d))
        if rep_pipe is None or \
                rep_p.records_per_sec > rep_pipe.records_per_sec:
            rep_pipe = rep_p
    overhead = sorted(ratios)[len(ratios) // 2] - 1.0
    entry["pipeline_api_records_per_sec"] = round(rep_pipe.records_per_sec)
    # a NEW key: the pre-PR-4 "pipeline_api_overhead_pct" rows were a
    # wall-clock records/sec ratio (compile time included) and are not
    # comparable to this steady-state latency ratio
    entry["pipeline_api_steady_overhead_pct"] = round(100 * overhead, 2)
    entry["pipeline_api_overhead_ok"] = bool(overhead <= 0.05)
    rows.append(fmt_csv(
        "streaming/pipeline_api", rep_pipe.mean_batch_latency * 1e6,
        f"records_per_s={rep_pipe.records_per_sec:.0f};"
        f"overhead_vs_direct_pct={100 * overhead:.2f}"
        f"{'' if overhead <= 0.05 else ';WARN_ABOVE_5PCT'}"))
    if overhead > 0.05:
        print(f"! pipeline API overhead {100 * overhead:.2f}% exceeds the "
              f"5% guard vs the direct plan drive")
    # multi-stage chain (count → re-window → top-k) — the carry-handoff
    # seam measured both ways: on-device vs host record materialization
    entry["multistage_records_per_sec"] = {}
    for handoff in ("device", "host"):
        run_multistage_once(events[: 2 * SLIDING_BATCH], SLIDING_BATCH,
                            f"warm-ms-{handoff}", handoff)
        rep_ms = run_multistage_once(events, SLIDING_BATCH,
                                     f"ms-{handoff}", handoff)
        entry["multistage_records_per_sec"][handoff] = \
            round(rep_ms.records_per_sec)
        rows.append(fmt_csv(
            f"streaming/multistage_handoff_{handoff}",
            rep_ms.mean_batch_latency * 1e6,
            f"records_per_s={rep_ms.records_per_sec:.0f};"
            f"handoffs={rep_ms.handoffs};"
            f"windows={rep_ms.windows_emitted}"))
    # DAG fan-out: two consumers off one shared count stage (tee + per-edge
    # handoffs) vs two separate jobs each re-ingesting the full stream
    run_fanout_tee(events[: 2 * SLIDING_BATCH], SLIDING_BATCH, "warm-fan")
    rep_tee = run_fanout_tee(events, SLIDING_BATCH, "fan-tee")
    run_fanout_reingest(events[: 2 * SLIDING_BATCH], SLIDING_BATCH,
                        "warm-ri")
    reps_ri = run_fanout_reingest(events, SLIDING_BATCH, "fan-ri")
    ri_wall = sum(r.wall_time for r in reps_ri)
    speedup = ri_wall / rep_tee.wall_time if rep_tee.wall_time else 0.0
    entry["dag_fanout"] = {
        "tee_wall_s": round(rep_tee.wall_time, 4),
        "reingest_wall_s": round(ri_wall, 4),
        "tee_records_per_sec": round(rep_tee.records_per_sec),
        "speedup_vs_reingest": round(speedup, 3),
    }
    rows.append(fmt_csv(
        "streaming/dag_fanout_tee", rep_tee.mean_batch_latency * 1e6,
        f"records_per_s={rep_tee.records_per_sec:.0f};"
        f"handoffs={rep_tee.handoffs};"
        f"windows={rep_tee.windows_emitted};"
        f"speedup_vs_reingest={speedup:.2f}x"))
    rows.append(fmt_csv(
        "streaming/dag_fanout_reingest",
        sum(r.mean_batch_latency for r in reps_ri) * 1e6,
        f"wall_s={ri_wall:.3f};"
        f"windows={sum(r.windows_emitted for r in reps_ri)}"))
    if write_json:
        _append_trajectory(entry)
    if print_rows:
        for r in rows:
            print(r)
    return rows, entry


if __name__ == "__main__":
    print("name,us_per_call,derived")
    _rows, _entry = run()
    if "--check" in sys.argv[1:]:
        # the blocking CI guard: the declarative front door may cost at
        # most 5% steady-state latency over driving the plan directly
        if not _entry["pipeline_api_overhead_ok"]:
            print(f"BENCH GATE FAILED: pipeline API steady-state overhead "
                  f"{_entry['pipeline_api_steady_overhead_pct']}% > 5%")
            sys.exit(2)
        print(f"bench gate ok: pipeline API overhead "
              f"{_entry['pipeline_api_steady_overhead_pct']}% <= 5%")
