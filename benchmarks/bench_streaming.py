"""Streaming engine: sustained records/sec and per-batch latency vs
micro-batch size, host vs on-device sliding-window fan-out, and the
pipelined scheduler (overlap on vs off) — all driven through the one
front door, ``BuiltPipeline.run(..., options=RunOptions(...))``.

Small batches → low per-window emission delay but per-batch overhead
(dispatch, watermark bookkeeping, one collective per batch) dominates; large
batches amortize it toward the device engine's aggregate throughput.  The
fan-out comparison isolates the execution-plan layer's win: with
slide = size/4 every record belongs to 4 windows, and the host baseline
writes 4 numpy rows per event where the device path ships one row and
replicates on-chip (broadcast + iota).  The DAG fan-out comparison
measures the tee seam: two branches sharing one upstream stage through
per-edge carry handoffs vs the serverless-baseline shape of two separate
jobs each re-ingesting (and re-reducing) the full stream.  The overlap
comparison measures the scheduler seam: prepare/fold/drain lanes
(prefetch thread + deferred stats + batched sinks + donated carries) vs
the synchronous drive loop, paired run-for-run, with close→emit window
latency quantiles reported alongside throughput.

Each run appends its numbers to ``BENCH_streaming.json`` at the repo root,
so throughput is tracked as a trajectory across PRs instead of discarded.

CI runs this file on a small fixed config (``BENCH_STREAM_EVENTS`` /
``BENCH_STREAM_BATCHES`` env overrides) with ``--check``, which turns two
guards into blocking exit codes: the steady-state ≤5% pipeline-API
overhead gate, and the overlap gate (the pipelined scheduler must not be
slower than the synchronous loop at steady state; the latency quantiles
are recorded but not gated).
"""

from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import MemoryStore, MetadataStore
from repro.pipeline import Pipeline, RunOptions, Windowing
from repro.streaming import StreamSource, StreamingCoordinator

from .common import fmt_csv

N_EVENTS = int(os.environ.get("BENCH_STREAM_EVENTS", 60_000))
N_KEYS = 64
EVENT_RATE = 200.0           # events per second of event time
BATCH_SIZES = [int(b) for b in os.environ.get(
    "BENCH_STREAM_BATCHES", "256,1024,4096,16384").split(",")]
SLIDING_BATCH = min(4096, max(BATCH_SIZES))
WINDOW_SIZE = 30.0           # sliding comparison: slide = size/4 → fan-out 4
BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_streaming.json"

#: the pipelined scheduler (defaults: prefetch + deferred drains + donation)
ASYNC = RunOptions()
#: every lane off — the synchronous pre-async drive loop
SYNC = RunOptions(overlap=False, sink_batching=False, donate_carry=False)


def synth_stream(n: int = N_EVENTS, seed: int = 0):
    rng = np.random.default_rng(seed)
    ts = np.arange(n) / EVENT_RATE
    keys = rng.integers(0, N_KEYS, n)
    vals = rng.integers(0, 100, n).astype(float)
    return [(float(t), int(k), float(v)) for t, k, v in zip(ts, keys, vals)]


def _window(slide: float | None) -> Windowing:
    return (Windowing.sliding(WINDOW_SIZE, slide) if slide is not None
            else Windowing.tumbling(WINDOW_SIZE))


def run_stream_once(events, batch_records: int, *, slide: float | None = None,
                    fanout: str = "device", n_slots: int = 8,
                    job_id: str = "bench", options: RunOptions = ASYNC):
    """One windowed-sum drive with an inspectable coordinator (the
    trajectory rows read its pool stats; everything else goes through
    ``BuiltPipeline.run``)."""
    built = (Pipeline.from_source(records=events, batch_records=batch_records)
             .key_by().window(_window(slide)).reduce("sum")
             .build(num_buckets=N_KEYS, n_workers=8, n_slots=n_slots,
                    fanout=fanout, job_id=job_id))
    coord = StreamingCoordinator(MemoryStore(), MetadataStore(),
                                 program=built, options=options)
    source = StreamSource.from_records(events, batch_records=batch_records)
    report = coord.run_stream(source)
    return report, coord


def run_pipeline_once(events, batch_records: int, job_id: str,
                      options: RunOptions = SYNC):
    """The same tumbling-sum workload through the ``run()`` front door —
    the API-overhead guard drives it with every scheduler lane off so the
    ratio isolates the dataflow layer, not the new runtime."""
    pipe = (Pipeline.from_source(records=events,
                                 batch_records=batch_records)
            .key_by().window(Windowing.tumbling(WINDOW_SIZE)).reduce("sum"))
    built = pipe.build(num_buckets=N_KEYS, n_workers=8, n_slots=8,
                       job_id=job_id)
    return built.run(store=MemoryStore(), mode="streaming", options=options)


def run_multistage_once(events, batch_records: int, job_id: str,
                        handoff: str):
    """A two-phase chain — count per key per window, then top-8 over the
    counts per 4-window span — comparing the on-device carry handoff
    against the host record path at the stage boundary."""
    pipe = (Pipeline.from_source(records=events,
                                 batch_records=batch_records)
            .key_by().window(Windowing.tumbling(WINDOW_SIZE)).reduce("count")
            .window(Windowing.tumbling(4 * WINDOW_SIZE)).reduce("sum")
            .top_k(8))
    built = pipe.build(num_buckets=N_KEYS, n_workers=8, n_slots=8,
                       job_id=job_id, handoff=handoff)
    return built.run(store=MemoryStore(), mode="streaming")


def _fanout_branches():
    """The two consumers of the shared per-window count stream: a top-8
    ranking and a coarse re-windowed rollup."""
    top = (Pipeline.branch().window(Windowing.tumbling(4 * WINDOW_SIZE))
           .reduce("sum").top_k(8).sink("bench-top/"))
    roll = (Pipeline.branch().window(Windowing.tumbling(4 * WINDOW_SIZE))
            .reduce("sum").sink("bench-roll/"))
    return top, roll


def run_fanout_tee(events, batch_records: int, job_id: str):
    """DAG fan-out: ingest + count ONCE, tee the counts into both
    branches through per-edge carry handoffs."""
    top, roll = _fanout_branches()
    pipe = (Pipeline.from_source(records=events,
                                 batch_records=batch_records)
            .key_by().window(Windowing.tumbling(WINDOW_SIZE)).reduce("count")
            .tee(top, roll))
    built = pipe.build(num_buckets=N_KEYS, n_workers=8, n_slots=8,
                       job_id=job_id)
    return built.run(store=MemoryStore(), mode="streaming")


def run_fanout_reingest(events, batch_records: int, job_id: str):
    """The baseline the paper's loosely-coupled services imply without a
    shared intermediate: one job per consumer, each re-ingesting the full
    stream and recomputing the count stage.  Returns the reports of both
    runs (wall time adds; the shared-handoff tee does this work once)."""
    reports = []
    for bi, branch in enumerate(_fanout_branches()):
        pipe = (Pipeline.from_source(records=events,
                                     batch_records=batch_records)
                .key_by().window(Windowing.tumbling(WINDOW_SIZE))
                .reduce("count"))
        # graft the branch onto a fresh single-consumer chain (each run
        # gets its own store, so the branch sinks cannot collide)
        pipe = Pipeline(pipe.nodes + branch.nodes[1:])
        built = pipe.build(num_buckets=N_KEYS, n_workers=8, n_slots=8,
                           job_id=f"{job_id}-{bi}")
        reports.append(built.run(store=MemoryStore(), mode="streaming"))
    return reports


def _append_trajectory(entry: dict) -> None:
    """Append this run to the cross-PR trajectory file (best effort)."""
    try:
        data = json.loads(BENCH_PATH.read_text())
    except (FileNotFoundError, json.JSONDecodeError):
        data = {"schema": 1, "runs": []}
    data["runs"].append(entry)
    BENCH_PATH.write_text(json.dumps(data, indent=1) + "\n")


def steady_latency(report):
    """Median per-batch latency with the first batch dropped — each fresh
    build re-traces its plan, so batch 0 carries the XLA compile."""
    tail = sorted(report.batch_latencies[1:] or report.batch_latencies)
    return tail[len(tail) // 2]


def run(print_rows: bool = True,
        write_json: bool = True) -> tuple[list[str], dict]:
    events = synth_stream()
    rows = []
    entry: dict = {"unix_time": round(time.time(), 1),
                   "n_events": N_EVENTS,
                   "tumbling_records_per_sec": {},
                   "sliding_fanout_records_per_sec": {}}
    for bs in BATCH_SIZES:
        # warm the jit cache so rows measure the steady state, not compiles
        run_stream_once(events[: 2 * bs], bs, job_id=f"warm-{bs}")
        report, coord = run_stream_once(events, bs, job_id=f"bench-{bs}")
        entry["tumbling_records_per_sec"][str(bs)] = \
            round(report.records_per_sec)
        lat_us = report.mean_batch_latency * 1e6
        rows.append(fmt_csv(
            f"streaming/batch_{bs}", lat_us,
            f"records_per_s={report.records_per_sec:.0f};"
            f"batches={report.batches};"
            f"windows={report.windows_emitted};"
            f"max_lag={report.max_lag};"
            f"pool_replicas={coord.pool_stats()['replicas']}"))
    # sliding windows, slide = size/4: host event×window expansion vs the
    # plan layer's on-chip fan-out (records cross host→device once)
    slide = WINDOW_SIZE / 4.0
    for fanout in ("host", "device"):
        run_stream_once(events[: 2 * SLIDING_BATCH], SLIDING_BATCH,
                        slide=slide, fanout=fanout,
                        job_id=f"warm-{fanout}")
        report, _ = run_stream_once(events, SLIDING_BATCH, slide=slide,
                                    fanout=fanout, job_id=f"slide-{fanout}")
        entry["sliding_fanout_records_per_sec"][fanout] = \
            round(report.records_per_sec)
        rows.append(fmt_csv(
            f"streaming/sliding_fanout_{fanout}",
            report.mean_batch_latency * 1e6,
            f"records_per_s={report.records_per_sec:.0f};"
            f"expanded={report.records_expanded};"
            f"windows={report.windows_emitted}"))
    # the declarative Pipeline API on the tumbling workload: guard that the
    # graph front door costs <= 5% over driving the ExecutionPlan directly
    # (same machinery underneath; both sides run the synchronous lanes so
    # the ratio isolates the API layer).  Runs alternate direct/pipeline
    # and the overhead is the MEDIAN of the per-iteration ratios: paired
    # adjacent runs share the machine's momentary load, so a slow window
    # on a shared CI runner cancels out instead of failing the gate; a
    # smaller guard batch keeps the sample count meaningful even when the
    # env overrides shrink the stream
    guard_batch = min(1024, SLIDING_BATCH)
    run_pipeline_once(events[: 2 * guard_batch], guard_batch, "warm-pipe")
    run_stream_once(events[: 2 * guard_batch], guard_batch,
                    job_id="warm-direct", options=SYNC)
    ratios, rep_pipe = [], None
    for i in range(5):
        # alternate which path runs first within the pair: whoever runs
        # second eats any within-pair drift (GC debt, thermal ramp), so a
        # fixed order would bias the ratio one way on every iteration
        if i % 2 == 0:
            rep_d, _ = run_stream_once(events, guard_batch,
                                       job_id=f"direct-{i}", options=SYNC)
            rep_p = run_pipeline_once(events, guard_batch, f"pipe-{i}")
        else:
            rep_p = run_pipeline_once(events, guard_batch, f"pipe-{i}")
            rep_d, _ = run_stream_once(events, guard_batch,
                                       job_id=f"direct-{i}", options=SYNC)
        ratios.append(steady_latency(rep_p) / steady_latency(rep_d))
        if rep_pipe is None or \
                rep_p.records_per_sec > rep_pipe.records_per_sec:
            rep_pipe = rep_p
    overhead = sorted(ratios)[len(ratios) // 2] - 1.0
    entry["pipeline_api_records_per_sec"] = round(rep_pipe.records_per_sec)
    # a NEW key: the pre-PR-4 "pipeline_api_overhead_pct" rows were a
    # wall-clock records/sec ratio (compile time included) and are not
    # comparable to this steady-state latency ratio
    entry["pipeline_api_steady_overhead_pct"] = round(100 * overhead, 2)
    entry["pipeline_api_overhead_ok"] = bool(overhead <= 0.05)
    rows.append(fmt_csv(
        "streaming/pipeline_api", rep_pipe.mean_batch_latency * 1e6,
        f"records_per_s={rep_pipe.records_per_sec:.0f};"
        f"overhead_vs_direct_pct={100 * overhead:.2f}"
        f"{'' if overhead <= 0.05 else ';WARN_ABOVE_5PCT'}"))
    if overhead > 0.05:
        print(f"! pipeline API overhead {100 * overhead:.2f}% exceeds the "
              f"5% guard vs the direct plan drive")
    # the pipelined scheduler vs the synchronous loop.  The workload is
    # the paper's ingestion path — the JSON event log, whose per-record
    # parse is the prepare lane's real work — because ``from_records``
    # has nothing for the prefetch thread to hide.  Paired the same way
    # (alternate on/off per iteration, gate on the median ratio), but on
    # *steady drive time* — wall minus the compile-carrying first batch —
    # since per-batch processing latency can't see prepare-lane cost: the
    # synchronous loop parses between timed windows while the overlapped
    # loop leaks its (hidden) prepare work into them as GIL contention.
    # Close→emit latency (watermark passes a window's end → its bytes
    # land in the store) is recorded at p50/p99 for both modes but not
    # gated: batching sink writes trades a little per-window latency for
    # round trips, and the quantiles make that trade visible
    from repro.streaming import write_event_log
    ov_batch = SLIDING_BATCH
    ov_log = MemoryStore()
    write_event_log(ov_log, "streams/bench", events, segment_records=4096)

    def run_overlap_once(job_id: str, options: RunOptions):
        built = (Pipeline.from_source(prefix="streams/bench",
                                      batch_records=ov_batch)
                 .key_by().window(Windowing.tumbling(WINDOW_SIZE))
                 .reduce("sum")
                 .build(num_buckets=N_KEYS, n_workers=8, n_slots=8,
                        job_id=job_id))
        return built.run(store=ov_log, mode="streaming", options=options)

    def steady_drive(report):
        return report.wall_time - report.batch_latencies[0]

    run_overlap_once("warm-ov-on", ASYNC)
    run_overlap_once("warm-ov-off", SYNC)
    speedups, rep_on, rep_off = [], None, None
    for i in range(5):
        if i % 2 == 0:
            r_off = run_overlap_once(f"ov-off-{i}", SYNC)
            r_on = run_overlap_once(f"ov-on-{i}", ASYNC)
        else:
            r_on = run_overlap_once(f"ov-on-{i}", ASYNC)
            r_off = run_overlap_once(f"ov-off-{i}", SYNC)
        speedups.append(steady_drive(r_off) / steady_drive(r_on))
        if rep_on is None or r_on.records_per_sec > rep_on.records_per_sec:
            rep_on = r_on
        if rep_off is None or \
                r_off.records_per_sec > rep_off.records_per_sec:
            rep_off = r_off
    speedup_med = sorted(speedups)[len(speedups) // 2]
    entry["overlap"] = {
        "batch": ov_batch,
        "on_records_per_sec": round(rep_on.records_per_sec),
        "off_records_per_sec": round(rep_off.records_per_sec),
        "steady_speedup": round(speedup_med, 4),
        "p50_close_emit_ms_on": round(rep_on.p50_emit_latency * 1e3, 3),
        "p99_close_emit_ms_on": round(rep_on.p99_emit_latency * 1e3, 3),
        "p50_close_emit_ms_off": round(rep_off.p50_emit_latency * 1e3, 3),
        "p99_close_emit_ms_off": round(rep_off.p99_emit_latency * 1e3, 3),
        # the gate: overlap-on must be no slower at steady state (2%
        # paired-median tolerance absorbs scheduler jitter on shared
        # runners without hiding a real regression)
        "overlap_ok": bool(speedup_med >= 0.98),
    }
    for tag, rep in (("on", rep_on), ("off", rep_off)):
        rows.append(fmt_csv(
            f"streaming/overlap_{tag}", steady_drive(rep) * 1e6,
            f"records_per_s={rep.records_per_sec:.0f};"
            f"p50_close_emit_ms={rep.p50_emit_latency * 1e3:.3f};"
            f"p99_close_emit_ms={rep.p99_emit_latency * 1e3:.3f};"
            + (f"steady_speedup_vs_off={speedup_med:.3f}"
               if tag == "on" else f"windows={rep.windows_emitted}")))
    if not entry["overlap"]["overlap_ok"]:
        print(f"! overlap-on steady-state is slower than overlap-off "
              f"(paired median speedup {speedup_med:.3f} < 0.98)")
    # multi-stage chain (count → re-window → top-k) — the carry-handoff
    # seam measured both ways: on-device vs host record materialization
    entry["multistage_records_per_sec"] = {}
    for handoff in ("device", "host"):
        run_multistage_once(events[: 2 * SLIDING_BATCH], SLIDING_BATCH,
                            f"warm-ms-{handoff}", handoff)
        rep_ms = run_multistage_once(events, SLIDING_BATCH,
                                     f"ms-{handoff}", handoff)
        entry["multistage_records_per_sec"][handoff] = \
            round(rep_ms.records_per_sec)
        rows.append(fmt_csv(
            f"streaming/multistage_handoff_{handoff}",
            rep_ms.mean_batch_latency * 1e6,
            f"records_per_s={rep_ms.records_per_sec:.0f};"
            f"handoffs={rep_ms.handoffs};"
            f"windows={rep_ms.windows_emitted}"))
    # DAG fan-out: two consumers off one shared count stage (tee + per-edge
    # handoffs) vs two separate jobs each re-ingesting the full stream
    run_fanout_tee(events[: 2 * SLIDING_BATCH], SLIDING_BATCH, "warm-fan")
    rep_tee = run_fanout_tee(events, SLIDING_BATCH, "fan-tee")
    run_fanout_reingest(events[: 2 * SLIDING_BATCH], SLIDING_BATCH,
                        "warm-ri")
    reps_ri = run_fanout_reingest(events, SLIDING_BATCH, "fan-ri")
    ri_wall = sum(r.wall_time for r in reps_ri)
    speedup = ri_wall / rep_tee.wall_time if rep_tee.wall_time else 0.0
    entry["dag_fanout"] = {
        "tee_wall_s": round(rep_tee.wall_time, 4),
        "reingest_wall_s": round(ri_wall, 4),
        "tee_records_per_sec": round(rep_tee.records_per_sec),
        "speedup_vs_reingest": round(speedup, 3),
    }
    rows.append(fmt_csv(
        "streaming/dag_fanout_tee", rep_tee.mean_batch_latency * 1e6,
        f"records_per_s={rep_tee.records_per_sec:.0f};"
        f"handoffs={rep_tee.handoffs};"
        f"windows={rep_tee.windows_emitted};"
        f"speedup_vs_reingest={speedup:.2f}x"))
    rows.append(fmt_csv(
        "streaming/dag_fanout_reingest",
        sum(r.mean_batch_latency for r in reps_ri) * 1e6,
        f"wall_s={ri_wall:.3f};"
        f"windows={sum(r.windows_emitted for r in reps_ri)}"))
    # the fold backend seam: the same sliding fan-out-4 workload compiled
    # via the XLA chain (backend="vmap") vs the fused pallas kernel
    # (backend="pallas").  Recorded, not gated: off-TPU the kernel runs
    # under the pallas *interpreter*, so these rows track the dispatch
    # seam's cost trajectory, not the kernel's HBM win (that placement is
    # benchmarks/roofline.py's streaming-fold table).
    def run_fold_backend(job_id: str, backend: str):
        built = (Pipeline.from_source(records=events,
                                      batch_records=SLIDING_BATCH)
                 .key_by().window(Windowing.sliding(WINDOW_SIZE, slide))
                 .reduce("sum")
                 .build(num_buckets=N_KEYS, n_workers=8, n_slots=8,
                        job_id=job_id, backend=backend))
        return built.run(store=MemoryStore(), mode="streaming")

    entry["fold_backend_records_per_sec"] = {}
    for backend in ("vmap", "pallas"):
        run_fold_backend(f"warm-fb-{backend}", backend)
        rep_fb = run_fold_backend(f"fb-{backend}", backend)
        entry["fold_backend_records_per_sec"][backend] = \
            round(rep_fb.records_per_sec)
        rows.append(fmt_csv(
            f"streaming/fold_backend_{backend}",
            rep_fb.mean_batch_latency * 1e6,
            f"records_per_s={rep_fb.records_per_sec:.0f};"
            f"windows={rep_fb.windows_emitted};"
            + ("interpret=cpu" if backend == "pallas" else "jit=xla")))
    # the job-service lifecycle: cold-start latency (parked checkpoint →
    # running coordinator), the full scale-to-zero-and-back round trip
    # (event lands while the pool is at zero → its records are folded),
    # and the shared-ingest win over each tenant re-reading the log.
    # Recorded, not gated — these are the serverless trade lines the
    # paper's Fig. 6 charges against scale-to-zero.  The jit cache is
    # already warm here (the overlap section compiled the identical
    # tumbling-sum shape), so cold start measures the lifecycle — pool
    # activation, carry download, tracker rebuild — not XLA compiles.
    from repro.service import JobServer, ParkPolicy

    def _service_program(job_id):
        return (Pipeline.from_source(batch_records=SLIDING_BATCH).key_by()
                .window(Windowing.tumbling(WINDOW_SIZE)).reduce("sum")
                .sink("stream-output/")
                .build(num_buckets=N_KEYS, n_workers=8,
                       batch_records=SLIDING_BATCH, job_id=job_id))

    svc_store = MemoryStore()
    write_event_log(svc_store, "svc/", events[: N_EVENTS // 2],
                    segment_records=4096)
    server = JobServer(svc_store, MetadataStore(),
                       park_policy=ParkPolicy(idle_seconds=0.0))
    server.add_tenant("bench")
    jid = server.submit("bench", _service_program("svc-cold"),
                        source_prefix="svc/")
    while server.step():
        pass                    # drain the tail → park → pool at zero
    assert server.pool.stats()["replicas"] == 0
    t_zero = time.perf_counter()
    write_event_log(svc_store, "svc/", events[N_EVENTS // 2:],
                    segment_records=4096)
    server.step()               # pump + cold restore + fold the new tail
    back_s = time.perf_counter() - t_zero
    job = server.jobs[jid]
    cold_s = job.cold_start_latencies[-1] if job.cold_start_latencies else 0.0
    server.run_until_complete()
    entry["job_service"] = {
        "cold_start_ms": round(cold_s * 1e3, 3),
        "scale_to_zero_and_back_ms": round(back_s * 1e3, 3),
        "parks": server.registry.record(jid)["parks"],
        "restores": server.registry.record(jid)["restores"],
    }
    rows.append(fmt_csv(
        "streaming/job_cold_start", cold_s * 1e6,
        f"scale_to_zero_and_back_ms={back_s * 1e3:.3f};"
        f"parks={entry['job_service']['parks']};"
        f"restores={entry['job_service']['restores']}"))

    # shared vs duplicate ingest: N tenants on one source through the job
    # server's materialized stream (log read once) vs N standalone
    # coordinators each re-reading the physical log.  On the in-memory
    # store the win is physical_records_read (N× fewer GETs — the paper's
    # per-request billing line), not necessarily wall clock: GETs here
    # cost nanoseconds, so the row tracks the seam's overhead trajectory
    n_tenants = 2

    def run_shared():
        store = MemoryStore()
        write_event_log(store, "svc/", events, segment_records=4096)
        srv = JobServer(store, MetadataStore())
        t0 = time.perf_counter()
        for i in range(n_tenants):
            srv.add_tenant(f"t{i}")
            srv.submit(f"t{i}", _service_program(f"svc-sh-{i}"),
                       source_prefix="svc/")
        srv.run_until_complete()
        return time.perf_counter() - t0, srv.stats()["ingests"]["svc"]

    def run_duplicate():
        wall = 0.0
        for i in range(n_tenants):
            store = MemoryStore()
            write_event_log(store, "svc/", events, segment_records=4096)
            built = _service_program(f"svc-dup-{i}")
            t0 = time.perf_counter()
            built.run(StreamSource(store=store, prefix="svc/",
                                   batch_records=SLIDING_BATCH),
                      store=store, mode="streaming")
            wall += time.perf_counter() - t0
        return wall

    shared_wall, ing_stats = run_shared()
    dup_wall = run_duplicate()
    entry["job_service"]["shared_ingest"] = {
        "n_tenants": n_tenants,
        "shared_records_per_sec": round(n_tenants * N_EVENTS / shared_wall),
        "duplicate_records_per_sec": round(n_tenants * N_EVENTS / dup_wall),
        "speedup_vs_duplicate": round(dup_wall / shared_wall, 3),
        "physical_records_read": ing_stats["pumped"],
    }
    rows.append(fmt_csv(
        "streaming/shared_ingest", shared_wall * 1e6 / n_tenants,
        f"tenants={n_tenants};"
        f"records_per_s={n_tenants * N_EVENTS / shared_wall:.0f};"
        f"duplicate_records_per_s={n_tenants * N_EVENTS / dup_wall:.0f};"
        f"speedup_vs_duplicate={dup_wall / shared_wall:.2f}x"))

    # warm-pool vs forked-process worker cold start: the restore above
    # reuses this process's interpreter, imports, and jit cache — the
    # deployment alternative is a forked worker process that pays
    # interpreter + JAX init before touching a record.  One honest
    # subprocess measurement (python -c "import jax; one tiny op"), no
    # amortization.  Recorded, not gated.
    import subprocess
    t0 = time.perf_counter()
    subprocess.run(
        [sys.executable, "-c",
         "import jax.numpy as jnp; jnp.zeros((8,)).sum().block_until_ready()"],
        check=True, capture_output=True)
    forked_s = time.perf_counter() - t0
    entry["job_service"]["worker_cold_start"] = {
        "warm_pool_restore_ms": entry["job_service"]["cold_start_ms"],
        "forked_process_ms": round(forked_s * 1e3, 3),
        "warm_advantage": round(
            forked_s * 1e3 / max(entry["job_service"]["cold_start_ms"],
                                 1e-3), 1),
    }
    rows.append(fmt_csv(
        "streaming/worker_cold_start", forked_s * 1e6,
        f"warm_pool_restore_ms={entry['job_service']['cold_start_ms']};"
        f"forked_process_ms={forked_s * 1e3:.1f};"
        f"warm_advantage="
        f"{entry['job_service']['worker_cold_start']['warm_advantage']}x"))

    # overlapped vs serial multi-tenant drive: the same three tenants on
    # one shared source, serial round-robin (overlap=False) vs the
    # overlapped per-job prepare/fold lanes — identical job ids and
    # tenant names so the two runs' sink maps compare byte-for-byte.
    # Recorded, not gated (on CPU the shared device serializes folds;
    # the row tracks the scheduler seam's overhead and the byte flag).
    n_mt = 3

    def run_multi_tenant(overlap):
        store = MemoryStore()
        write_event_log(store, "svc/", events, segment_records=4096)
        srv = JobServer(store, MetadataStore(), overlap=overlap)
        t0 = time.perf_counter()
        for i in range(n_mt):
            srv.add_tenant(f"mt{i}")
            srv.submit(f"mt{i}", _service_program(f"svc-mt-{i}"),
                       source_prefix="svc/")
        srv.run_until_complete()
        wall = time.perf_counter() - t0
        sinks = {m.key: store.get(m.key)
                 for m in store.list_objects("tenants/")
                 if "/stream-output/" in m.key}
        return wall, sinks

    serial_wall, serial_sinks = run_multi_tenant(False)
    over_wall, over_sinks = run_multi_tenant(True)
    entry["job_service"]["multi_tenant"] = {
        "n_tenants": n_mt,
        "serial_records_per_sec": round(n_mt * N_EVENTS / serial_wall),
        "overlapped_records_per_sec": round(n_mt * N_EVENTS / over_wall),
        "speedup_vs_serial": round(serial_wall / over_wall, 3),
        "byte_identical": over_sinks == serial_sinks,
    }
    rows.append(fmt_csv(
        "streaming/multi_tenant_overlap", over_wall * 1e6 / n_mt,
        f"tenants={n_mt};"
        f"overlapped_records_per_s={n_mt * N_EVENTS / over_wall:.0f};"
        f"serial_records_per_s={n_mt * N_EVENTS / serial_wall:.0f};"
        f"speedup_vs_serial={serial_wall / over_wall:.2f}x;"
        f"byte_identical="
        f"{entry['job_service']['multi_tenant']['byte_identical']}"))
    if write_json:
        _append_trajectory(entry)
    if print_rows:
        for r in rows:
            print(r)
    return rows, entry


if __name__ == "__main__":
    print("name,us_per_call,derived")
    _rows, _entry = run()
    if "--check" in sys.argv[1:]:
        failed = False
        # blocking guard 1: the declarative front door may cost at most
        # 5% steady-state latency over driving the plan directly
        if not _entry["pipeline_api_overhead_ok"]:
            print(f"BENCH GATE FAILED: pipeline API steady-state overhead "
                  f"{_entry['pipeline_api_steady_overhead_pct']}% > 5%")
            failed = True
        else:
            print(f"bench gate ok: pipeline API overhead "
                  f"{_entry['pipeline_api_steady_overhead_pct']}% <= 5%")
        # blocking guard 2: the pipelined scheduler must be no slower
        # than the synchronous loop (p99 close→emit is recorded, not
        # gated)
        ov = _entry["overlap"]
        if not ov["overlap_ok"]:
            print(f"BENCH GATE FAILED: overlap-on steady-state speedup "
                  f"{ov['steady_speedup']} < 0.98 vs overlap-off")
            failed = True
        else:
            print(f"bench gate ok: overlap speedup {ov['steady_speedup']} "
                  f"(p99 close→emit on={ov['p99_close_emit_ms_on']} ms / "
                  f"off={ov['p99_close_emit_ms_off']} ms)")
        if failed:
            sys.exit(2)
