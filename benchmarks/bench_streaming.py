"""Streaming engine: sustained records/sec and per-batch latency vs
micro-batch size — the throughput/latency trade the micro-batch knob buys.

Small batches → low per-window emission delay but per-batch overhead
(dispatch, watermark bookkeeping, one collective per batch) dominates; large
batches amortize it toward the device engine's aggregate throughput.  Also
reports the backpressure path: pool scale chosen from consumer lag.
"""

from __future__ import annotations

import numpy as np

from repro.core import MemoryStore, MetadataStore
from repro.streaming import (StreamSource, StreamingConfig,
                             StreamingCoordinator)

from .common import fmt_csv

N_EVENTS = 60_000
N_KEYS = 64
EVENT_RATE = 200.0           # events per second of event time
BATCH_SIZES = [256, 1024, 4096, 16384]


def synth_stream(n: int = N_EVENTS, seed: int = 0):
    rng = np.random.default_rng(seed)
    ts = np.arange(n) / EVENT_RATE
    keys = rng.integers(0, N_KEYS, n)
    vals = rng.integers(0, 100, n).astype(float)
    return [(float(t), int(k), float(v)) for t, k, v in zip(ts, keys, vals)]


def run_stream_once(events, batch_records: int):
    cfg = StreamingConfig(num_buckets=N_KEYS, n_workers=8,
                          window_size=30.0, batch_records=batch_records,
                          aggregation="sum",
                          job_id=f"bench-{batch_records}")
    coord = StreamingCoordinator(MemoryStore(), MetadataStore(), cfg)
    source = StreamSource.from_records(events, batch_records=batch_records)
    report = coord.run_stream(source)
    return report, coord


def run(print_rows: bool = True) -> list[str]:
    events = synth_stream()
    rows = []
    for bs in BATCH_SIZES:
        # warm the jit cache so rows measure the steady state, not compiles
        run_stream_once(events[: 2 * bs], bs)
        report, coord = run_stream_once(events, bs)
        lat_us = report.mean_batch_latency * 1e6
        rows.append(fmt_csv(
            f"streaming/batch_{bs}", lat_us,
            f"records_per_s={report.records_per_sec:.0f};"
            f"batches={report.batches};"
            f"windows={report.windows_emitted};"
            f"max_lag={report.max_lag};"
            f"pool_replicas={coord.pool_stats()['replicas']}"))
    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
