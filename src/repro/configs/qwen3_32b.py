"""qwen3-32b [dense] — Qwen3 (family config per hf:Qwen/Qwen3-8B).

64L, d_model 5120, 64 heads (GQA kv=8, head_dim 128), d_ff 25600,
vocab 151936.  Per-head-dim RMS qk-norm, RMSNorm, SwiGLU, rope_theta 1e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab=151_936,
    rope_theta=1_000_000.0,
    qk_norm=True,
    activation="silu",
    notes="long_500k SKIPPED: pure full attention (DESIGN.md §5).",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=192, vocab=512,
        param_dtype="float32", compute_dtype="float32", remat=False)
