"""musicgen-medium [audio] — MusicGen (arXiv:2306.05284), decoder-only over
EnCodec tokens.

48L, d_model 1536, 24 heads (MHA kv=24), d_ff 6144, vocab 2048 (EnCodec
codebook).  Per the assignment spec the EnCodec frontend (and the codebook
delay pattern) is a STUB: the backbone consumes a single token stream /
precomputed frame embeddings.  Text-conditioning cross-attention is out of
scope for the backbone spec (noted in DESIGN.md).  GELU + LayerNorm.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    norm="layernorm",
    norm_eps=1e-5,
    activation="gelu",
    notes="EnCodec frontend + delay pattern stubbed per spec. "
          "long_500k SKIPPED: pure full attention (DESIGN.md §5).",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
        param_dtype="float32", compute_dtype="float32", remat=False)
