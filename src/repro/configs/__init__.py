"""Architecture registry: one module per assigned architecture.

Each module defines ``CONFIG`` (the exact published configuration) and
``reduced()`` (a tiny same-family variant for CPU smoke tests).
``get(name)`` / ``get_reduced(name)`` / ``ARCHS`` are the public API;
the launcher's ``--arch <id>`` resolves through here.
"""

from __future__ import annotations

import importlib

from ..models.config import ModelConfig

ARCHS = [
    "gemma2-9b",
    "stablelm-12b",
    "qwen3-32b",
    "yi-34b",
    "qwen2-moe-a2.7b",
    "mixtral-8x7b",
    "zamba2-1.2b",
    "internvl2-2b",
    "falcon-mamba-7b",
    "musicgen-medium",
    # the paper's own workload (wordcount MapReduce) has no model config;
    # its configs live in repro.core.job
]

_MODULES = {name: name.replace("-", "_").replace(".", "_") for name in ARCHS}


def _load(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ModelConfig:
    return _load(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _load(name).reduced()


def all_configs() -> dict[str, ModelConfig]:
    return {name: get(name) for name in ARCHS}
