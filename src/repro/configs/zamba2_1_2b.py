"""zamba2-1.2b [hybrid] — Zamba2 (arXiv:2411.15242).

38 Mamba2 blocks, d_model 2048 (d_inner 4096, ssm_state 64, 64 SSD heads of
dim 64), plus a *shared* full-attention transformer block (32 heads MHA,
d_ff 8192) invoked every 6 layers with the same parameters — the Zamba
weight-sharing trick.  vocab 32000.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,                 # shared attention block MLP
    vocab=32_000,
    layer_kind="mamba2",
    ssm_state=64,
    d_inner=4096,
    mamba_head_dim=64,
    conv_kernel=4,
    shared_attn_every=6,
    activation="gelu",
    notes="long_500k RUNS: O(1) SSM state; shared attn blocks carry their own"
          " KV caches per invocation (DESIGN.md §5).",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, ssm_state=8, d_inner=128, mamba_head_dim=32,
        shared_attn_every=3,
        param_dtype="float32", compute_dtype="float32", remat=False)
