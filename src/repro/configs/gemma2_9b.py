"""gemma2-9b [dense] — Gemma 2 (arXiv:2408.00118).

42L, d_model 3584, 16 heads (GQA kv=8, head_dim 256), d_ff 14336,
vocab 256000.  Local(4096)+global alternating attention, attn logit
softcap 50, final logit softcap 30, GeGLU, sandwich (post-block) norms,
Gemma-style (1+w) RMSNorm and sqrt(d) embedding scaling, tied embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256_000,
    rope_theta=10_000.0,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    window_pattern="alternate",
    post_block_norm=True,
    activation="gelu",
    norm_offset=1.0,
    embed_scale=True,
    tie_embeddings=True,
    notes="long_500k RUNS: half the layers are SWA-4096; decode is O(window)"
          " there and O(ctx) on the global layers (DESIGN.md §5).",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, sliding_window=16,
        param_dtype="float32", compute_dtype="float32", remat=False)
