"""qwen2-moe-a2.7b [moe] — Qwen1.5-MoE-A2.7B (hf:Qwen/Qwen1.5-MoE-A2.7B).

24L, d_model 2048, 16 heads (MHA, kv=16), vocab 151936.  MoE every layer:
60 routed experts top-4 (expert d_ff 1408) + 4 shared-expert slices
(shared intermediate 5632 = 4×1408) behind a sigmoid gate.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                   # routed expert intermediate
    vocab=151_936,
    rope_theta=1_000_000.0,
    n_experts=60,
    top_k=4,
    expert_d_ff=1408,
    n_shared_experts=4,
    shared_expert_d_ff=1408,     # ×4 shared slices = 5632
    capacity_factor=1.25,
    activation="silu",
    notes="MoE dispatch = the paper's shuffle: route(token)→expert replaces "
          "hash(key)→reducer (DESIGN.md §5). long_500k SKIPPED (full attn).",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96, vocab=512,
        n_experts=8, top_k=2, expert_d_ff=96, n_shared_experts=1,
        shared_expert_d_ff=96,
        param_dtype="float32", compute_dtype="float32", remat=False)
