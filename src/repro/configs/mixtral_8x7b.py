"""mixtral-8x7b [moe] — Mixtral of Experts (arXiv:2401.04088).

32L, d_model 4096, 32 heads (GQA kv=8, head_dim 128), vocab 32000.
8 experts top-2 (expert d_ff 14336), sliding-window attention (4096) on
every layer, rope_theta 1e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32_000,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    window_pattern="all",
    n_experts=8,
    top_k=2,
    expert_d_ff=14336,
    capacity_factor=1.25,
    activation="silu",
    notes="long_500k RUNS: SWA on all layers bounds the KV window "
          "(rolling cache) — sub-quadratic serving (DESIGN.md §5).",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, sliding_window=16,
        n_experts=4, top_k=2, expert_d_ff=128,
        param_dtype="float32", compute_dtype="float32", remat=False)
