"""yi-34b [dense] — Yi (arXiv:2403.04652), llama-arch GQA.

60L, d_model 7168, 56 heads (GQA kv=8, head_dim 128), d_ff 20480,
vocab 64000, rope_theta 5e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64_000,
    rope_theta=5_000_000.0,
    activation="silu",
    notes="long_500k SKIPPED: pure full attention (DESIGN.md §5).",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=56, n_heads=7, n_kv_heads=1, head_dim=8,
        d_ff=144, vocab=512,
        param_dtype="float32", compute_dtype="float32", remat=False)
