"""falcon-mamba-7b [ssm] — Falcon Mamba (arXiv:2410.05355), mamba1 arch.

64 Mamba-1 layers (attention-free), d_model 4096 (d_inner 8192,
ssm_state 16, conv kernel 4), vocab 65024, RMSNorm, tied embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,            # attention-free; unused
    n_kv_heads=1,
    d_ff=0,
    vocab=65_024,
    layer_kind="mamba1",
    ssm_state=16,
    d_inner=8192,
    conv_kernel=4,
    tie_embeddings=True,
    notes="Attention-free: the paper's shuffle applies to data/gradient "
          "plane only (DESIGN.md §5 — technique orthogonal to the mixer). "
          "long_500k RUNS: O(1) state.",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, vocab=512, ssm_state=8, d_inner=128,
        param_dtype="float32", compute_dtype="float32", remat=False)
