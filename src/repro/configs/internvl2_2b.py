"""internvl2-2b [vlm] — InternVL2 (arXiv:2404.16821): InternViT-300M vision
frontend + InternLM2-1.8B language backbone.

Per the assignment spec, only the transformer BACKBONE is modeled; the vision
frontend is a STUB — ``input_specs()`` supplies precomputed patch embeddings
(B, S, d_model), so ``input_mode='embeddings'``.

Backbone (InternLM2-1.8B): 24L, d_model 2048, 16 heads (GQA kv=8),
d_ff 8192, vocab 92553, rope_theta 1e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92_553,
    rope_theta=1_000_000.0,
    input_mode="embeddings",
    activation="silu",
    notes="Vision frontend stubbed (precomputed patch embeddings), per spec. "
          "long_500k SKIPPED: pure full attention (DESIGN.md §5).",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
        param_dtype="float32", compute_dtype="float32", remat=False)
