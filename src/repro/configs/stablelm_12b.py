"""stablelm-12b [dense] — StableLM 2 12B (hf:stabilityai/stablelm-2-12b,
family config per hf:stabilityai/stablelm-2-1_6b).

40L, d_model 5120, 32 heads (GQA kv=8), d_ff 13824, vocab 100352.
Partial rotary (25%), per-head qk-norm, LayerNorm.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100_352,
    rope_theta=10_000.0,
    rope_pct=0.25,
    qk_norm=True,
    norm="layernorm",
    norm_eps=1e-5,
    activation="silu",
    notes="long_500k SKIPPED: pure full attention (DESIGN.md §5).",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab=512,
        param_dtype="float32", compute_dtype="float32", remat=False)
