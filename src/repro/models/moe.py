"""Mixture-of-Experts with MapReduce-shuffle dispatch.

The paper's shuffle (§III-A.3/4) is ``hash(key) % R`` → pack records into
per-reducer spill buffers → exchange → merge.  MoE dispatch is the same
pipeline with ``route(token) → expert`` as the partition function
(DESIGN.md §5): tokens are sorted by expert id, packed into fixed-capacity
per-expert buffers (the spill files — static shapes, as TPU requires), run
through batched expert GEMMs, and combined back with the gate weights
(the weighted 'reduce').  Over an expert-parallel mesh axis the exchange is
the same ``all_to_all`` the data shuffle uses.

Token dropping on capacity overflow matches both the paper's bounded spill
buffers and standard TPU MoE practice (GShard/Switch); capacity_factor
controls the slack.  Aux losses: Switch load-balance + router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Params, _act, dense_init, linear
from .shardctx import shard_act


def moe_init(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    dt = cfg.param_dtype_
    ks = jax.random.split(key, 8)
    e, f = cfg.n_experts, cfg.expert_d_ff
    p: Params = {
        "router": dense_init(ks[0], d, e, jnp.float32, scale=d ** -0.5),
        # expert weights stacked: (E, d, f) / (E, f, d) — shardable over E
        "w_gate": dense_init(ks[1], d, e * f, dt).reshape(d, e, f)
                  .transpose(1, 0, 2),
        "w_up": dense_init(ks[2], d, e * f, dt).reshape(d, e, f)
                .transpose(1, 0, 2),
        "w_down": dense_init(ks[3], f, e * d, dt, scale=f ** -0.5)
                  .reshape(f, e, d).transpose(1, 0, 2),
    }
    if cfg.n_shared_experts > 0:
        sf = cfg.shared_expert_d_ff * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], d, sf, dt),
            "w_up": dense_init(ks[5], d, sf, dt),
            "w_down": dense_init(ks[6], sf, d, dt, scale=sf ** -0.5),
        }
        p["shared_gate"] = dense_init(ks[7], d, 1, jnp.float32)
    return p


def _route(router_w: jax.Array, x_flat: jax.Array, cfg: ModelConfig):
    """Router logits → (weights (T,k), experts (T,k), aux losses)."""
    logits = (x_flat.astype(jnp.float32) @ router_w)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.top_k)      # (T, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # Switch load-balance loss: E · Σ_e f_e · P_e
    onehot = jax.nn.one_hot(experts[:, 0], cfg.n_experts)   # top-1 fraction
    f_e = jnp.mean(onehot, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(f_e * p_e)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return weights, experts, cfg.router_aux_weight * aux + \
        cfg.router_z_weight * z


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    """Per-expert buffer size — the 'spill file' bound, MXU-aligned."""
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _pack_one_shard(x_flat, weights, experts, e: int, cap: int):
    """Spill-buffer packing for one token shard (cf.
    core.shuffle.build_send_buffers): sort by expert, position-in-group via
    offsets, scatter into (E, cap, d).  Returns (xb, buf_tok, buf_valid,
    buf_w) with buffer rows local to this shard's tokens."""
    t, d = x_flat.shape
    k = weights.shape[-1]
    flat_expert = experts.reshape(t * k)                  # the partition key
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = weights.reshape(t * k)
    order = jnp.argsort(flat_expert, stable=True)         # sort by key
    se = flat_expert[order]
    st = flat_token[order]
    sw = flat_w[order]
    counts = jnp.bincount(se, length=e)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos = jnp.arange(t * k, dtype=jnp.int32) - offsets[se]
    in_cap = pos < cap                                    # overflow → dropped
    slot = jnp.where(in_cap, se * cap + pos, e * cap)

    buf_tok = jnp.full((e * cap + 1,), 0, jnp.int32).at[slot].set(
        jnp.where(in_cap, st, 0))
    buf_valid = jnp.zeros((e * cap + 1,), bool).at[slot].set(in_cap)
    buf_w = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(in_cap, sw, 0.0))
    xb = jnp.take(x_flat, buf_tok[:-1], axis=0)           # (E*cap, d)
    xb = jnp.where(buf_valid[:-1, None], xb, jnp.zeros_like(xb))
    return (xb.reshape(e, cap, d), buf_tok[:-1], buf_valid[:-1], buf_w[:-1])


def moe_forward(p: Params, x: jax.Array, cfg: ModelConfig
                ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, aux_loss).

    Dispatch = the MapReduce spill packing, performed *per data shard* (the
    paper's mapper-local combine, DESIGN.md §4): tokens are grouped into
    ``dp_size`` contiguous shards matching the batch sharding, each shard
    sorts/packs its own (E, cap_local, d) spill buffer with zero cross-shard
    traffic, and only the expert GEMMs see the concatenated buffers.
    Single-device / test runs have dp_size=1 → identical global behaviour.
    """
    from .shardctx import dp_shards
    b, s, d = x.shape
    cd = cfg.compute_dtype_
    x_flat = x.reshape(b * s, d)
    t = b * s
    e = cfg.n_experts
    ns = dp_shards()
    if t % ns:
        ns = 1
    t_loc = t // ns
    cap = expert_capacity(cfg, t_loc)

    weights, experts, aux = _route(p["router"], x_flat, cfg)

    # ---- per-shard spill packing (vmapped; batch axis rides the dp axes) ----
    xb, buf_tok, buf_valid, buf_w = jax.vmap(
        lambda xs, ws, es: _pack_one_shard(xs, ws, es, e, cap))(
        x_flat.reshape(ns, t_loc, d),
        weights.reshape(ns, t_loc, cfg.top_k),
        experts.reshape(ns, t_loc, cfg.top_k))
    # (ns, E, cap, d) → (E, ns·cap, d): the global expert buffers, capacity
    # rows still owned by their shard
    xb = shard_act(jnp.transpose(xb, (1, 0, 2, 3)).reshape(e, ns * cap, d),
                   "moe_buf")

    # ---- per-expert GEMMs: (E, C, d) × (E, d, f) — MoE as batched matmul ----
    g = jnp.einsum("ecd,edf->ecf", xb.astype(cd), p["w_gate"].astype(cd),
                   preferred_element_type=jnp.float32).astype(cd)
    u = jnp.einsum("ecd,edf->ecf", xb.astype(cd), p["w_up"].astype(cd),
                   preferred_element_type=jnp.float32).astype(cd)
    h = _act(cfg.activation, g) * u
    yb = shard_act(jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cd),
                              preferred_element_type=jnp.float32),
                   "moe_buf")                             # (E, C, d) fp32

    # ---- combine: per-shard weighted scatter-add back (the 'reduce') ----
    yb = jnp.transpose(yb.reshape(e, ns, cap, d), (1, 0, 2, 3)) \
        .reshape(ns, e * cap, d)

    def _combine_one(yb_s, tok_s, valid_s, w_s):
        yb_s = yb_s * w_s[:, None]
        seg = jnp.where(valid_s, tok_s, t_loc)
        return jax.ops.segment_sum(yb_s, seg, num_segments=t_loc + 1)[:t_loc]

    y = jax.vmap(_combine_one)(
        yb, buf_tok, buf_valid,
        buf_w).reshape(t, d)

    if cfg.n_shared_experts > 0:
        sp = p["shared"]
        sg = _act(cfg.activation, linear(sp["w_gate"], x_flat, cd))
        su = linear(sp["w_up"], x_flat, cd)
        sy = linear(sp["w_down"], sg * su, cd).astype(jnp.float32)
        gate = jax.nn.sigmoid(x_flat.astype(jnp.float32) @ p["shared_gate"])
        y = y + gate * sy

    return y.reshape(b, s, d).astype(cd), aux
