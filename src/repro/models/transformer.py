"""Model assembly: init / forward / loss / prefill / decode for every family.

Layer stacks are homogeneous per architecture (dense-attn, moe-attn, mamba1,
mamba2), stored with a leading L axis and executed with ``jax.lax.scan``
(+ optional remat) — small HLO, long pipelines, the standard big-model shape.
zamba2's shared attention block (one param set, invoked every
``shared_attn_every`` layers) runs *between* scanned segments, so sharing is
literal (same tensors) and the mamba stack still scans.

Serving state (KV caches / SSM states / lengths) is a pytree with leading L
axes, carried through the same scan.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .attention import (attn_decode, attn_forward, attn_init, window_schedule)
from .config import ModelConfig
from .layers import (Params, embed, embed_init, glu_mlp, glu_mlp_init,
                     layernorm, rmsnorm, unembed)
from .mamba import (mamba1_decode, mamba1_forward, mamba1_init,
                    mamba1_init_cache, mamba2_decode, mamba2_forward,
                    mamba2_init, mamba2_init_cache)
from .moe import moe_forward, moe_init
from .shardctx import shard_act


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_init(cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((cfg.d_model,), cfg.param_dtype_),
                "b": jnp.zeros((cfg.d_model,), cfg.param_dtype_)}
    base = jnp.zeros if cfg.norm_offset else jnp.ones
    return {"w": base((cfg.d_model,), cfg.param_dtype_)}


def _apply_norm(p, x, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return layernorm(p["w"], p["b"], x, cfg.norm_eps)
    return rmsnorm(p["w"], x, cfg.norm_eps, cfg.norm_offset)


def _layer_init(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": _norm_init(cfg)}
    if cfg.layer_kind == "attn":
        p["attn"] = attn_init(ks[0], cfg)
        p["norm2"] = _norm_init(cfg)
        p["ffn"] = moe_init(ks[1], cfg) if cfg.is_moe else \
            glu_mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.param_dtype_)
        if cfg.post_block_norm:
            p["post_norm1"] = _norm_init(cfg)
            p["post_norm2"] = _norm_init(cfg)
    elif cfg.layer_kind == "mamba1":
        p["mixer"] = mamba1_init(ks[0], cfg)
    elif cfg.layer_kind == "mamba2":
        p["mixer"] = mamba2_init(ks[0], cfg)
    else:
        raise ValueError(cfg.layer_kind)
    return p


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    k_emb, k_layers, k_shared, k_head = jax.random.split(key, 4)
    p: Params = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.param_dtype_),
        "final_norm": _norm_init(cfg),
    }
    # stacked layers: vmap init over layer keys → leading L axis on every leaf
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    p["layers"] = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    if not cfg.tie_embeddings:
        # (d_model, vocab) projection head
        p["lm_head"] = jnp.transpose(
            embed_init(k_head, cfg.vocab, cfg.d_model, cfg.param_dtype_))
    if cfg.shared_attn_every > 0:
        sa_cfg = cfg.replace(layer_kind="attn", n_experts=0)
        p["shared_attn"] = {
            "norm1": _norm_init(cfg),
            "attn": attn_init(jax.random.split(k_shared)[0], sa_cfg),
            "norm2": _norm_init(cfg),
            "ffn": glu_mlp_init(jax.random.split(k_shared)[1], cfg.d_model,
                                cfg.d_ff, cfg.param_dtype_),
        }
    return p


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attn_block(lp: Params, x, cfg: ModelConfig, *, window,
                positions=None) -> tuple[jax.Array, jax.Array]:
    h = _apply_norm(lp["norm1"], x, cfg)
    a = attn_forward(lp["attn"], h, cfg, window=window, positions=positions)
    if cfg.post_block_norm:
        a = _apply_norm(lp["post_norm1"], a, cfg)
    x = x + a
    h = _apply_norm(lp["norm2"], x, cfg)
    aux = jnp.float32(0.0)
    if cfg.is_moe:
        f, aux = moe_forward(lp["ffn"], h, cfg)
    else:
        f = glu_mlp(lp["ffn"], h, cfg.activation, cfg.compute_dtype_)
    if cfg.post_block_norm:
        f = _apply_norm(lp["post_norm2"], f, cfg)
    return x + f, aux


def _mamba_block(lp: Params, x, cfg: ModelConfig) -> jax.Array:
    h = _apply_norm(lp["norm1"], x, cfg)
    if cfg.layer_kind == "mamba1":
        return x + mamba1_forward(lp["mixer"], h, cfg)
    return x + mamba2_forward(lp["mixer"], h, cfg)


def _shared_attn_positions(cfg: ModelConfig) -> list[int]:
    """zamba2: layers after which the shared attention block runs."""
    if cfg.shared_attn_every <= 0:
        return []
    return list(range(cfg.shared_attn_every - 1, cfg.n_layers,
                      cfg.shared_attn_every))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params: Params, inputs: jax.Array, cfg: ModelConfig,
            *, return_hidden: bool = False):
    """inputs: (B, S) int32 token ids, or (B, S, d) embeddings when
    ``cfg.input_mode == 'embeddings'`` (VLM/audio frontend stubs).
    Returns (logits (B, S, vocab) fp32, aux_loss)."""
    if cfg.input_mode == "embeddings":
        x = inputs.astype(cfg.compute_dtype_)
    else:
        scale = cfg.d_model ** 0.5 if cfg.embed_scale else None
        x = embed(params["embed"], inputs, scale, cfg.compute_dtype_)
    x = shard_act(x)

    windows = window_schedule(cfg) if cfg.layer_kind == "attn" else None

    def layer_fn(carry, inp):
        x, aux = carry
        lp, w = inp
        if cfg.layer_kind == "attn":
            x, a = _attn_block(lp, x, cfg, window=w)
            aux = aux + a
        else:
            x = _mamba_block(lp, x, cfg)
        return (shard_act(x), aux), None

    scan_fn = jax.checkpoint(layer_fn) if cfg.remat else layer_fn

    aux0 = jnp.float32(0.0)
    sa_pos = _shared_attn_positions(cfg)
    if not sa_pos:
        xs = (params["layers"], windows) if windows is not None \
            else (params["layers"], jnp.zeros((cfg.n_layers,), jnp.int32))
        (x, aux), _ = jax.lax.scan(scan_fn, (x, aux0), xs,
                                   unroll=cfg.n_layers if cfg.unroll_layers
                                   else 1)
    else:
        # zamba2: scan mamba segments, run the shared attn block between them
        bounds = [0] + [i + 1 for i in sa_pos]
        if bounds[-1] != cfg.n_layers:
            bounds.append(cfg.n_layers)
        aux = aux0
        sa_cfg = cfg.replace(layer_kind="attn", n_experts=0)
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            seg = jax.tree.map(lambda a: a[lo:hi], params["layers"])
            zw = jnp.zeros((hi - lo,), jnp.int32)
            (x, aux), _ = jax.lax.scan(scan_fn, (x, aux), (seg, zw),
                                       unroll=(hi - lo) if cfg.unroll_layers
                                       else 1)
            if hi - 1 in sa_pos:  # shared attention after this segment
                x, _ = _attn_block(params["shared_attn"], x, sa_cfg, window=0)

    x = _apply_norm(params["final_norm"], x, cfg)
    if return_hidden:
        return x
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = shard_act(unembed(table, x, tied=cfg.tie_embeddings,
                               softcap=cfg.final_softcap), "logits")
    return logits, aux


def loss_fn(params: Params, batch: dict[str, jax.Array], cfg: ModelConfig):
    """batch: {'inputs': (B,S)[,d], 'labels': (B,S)} — labels < 0 ignored.
    Returns (loss, metrics)."""
    logits, aux = forward(params, batch["inputs"], cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux,
                  "tokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict[str, Any]:
    """Serving state pytree.  Attention KV caches have leading L axis for the
    layer scan; zamba2's shared block gets one cache per invocation."""
    cache: dict[str, Any] = {"lengths": jnp.zeros((batch,), jnp.int32)}
    hd = cfg.head_dim_
    if cfg.layer_kind == "attn":
        shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, hd)
        cache["k"] = jnp.zeros(shape, cfg.compute_dtype_)
        cache["v"] = jnp.zeros(shape, cfg.compute_dtype_)
    else:
        one = (mamba1_init_cache(cfg, batch) if cfg.layer_kind == "mamba1"
               else mamba2_init_cache(cfg, batch))
        cache["mamba"] = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)
    n_sa = len(_shared_attn_positions(cfg))
    if n_sa:
        shape = (n_sa, batch, cfg.n_kv_heads, max_len, hd)
        cache["sa_k"] = jnp.zeros(shape, cfg.compute_dtype_)
        cache["sa_v"] = jnp.zeros(shape, cfg.compute_dtype_)
    return cache


def decode_step(params: Params, cache: dict[str, Any], token: jax.Array,
                cfg: ModelConfig):
    """One serving step: token (B, 1) int32 (or (B, 1, d) embeddings) →
    (logits (B, vocab), new_cache)."""
    if cfg.input_mode == "embeddings" and token.ndim == 3:
        x = token.astype(cfg.compute_dtype_)
    else:
        scale = cfg.d_model ** 0.5 if cfg.embed_scale else None
        x = embed(params["embed"], token, scale, cfg.compute_dtype_)
    lengths = cache["lengths"]
    windows = window_schedule(cfg) if cfg.layer_kind == "attn" else None

    if cfg.layer_kind == "attn":
        def layer_fn(x, inp):
            lp, w, kc, vc = inp
            h = _apply_norm(lp["norm1"], x, cfg)
            a, kc, vc = attn_decode(lp["attn"], h, cfg, window=w,
                                    k_cache=kc, v_cache=vc, lengths=lengths)
            if cfg.post_block_norm:
                a = _apply_norm(lp["post_norm1"], a, cfg)
            x = x + a
            h = _apply_norm(lp["norm2"], x, cfg)
            if cfg.is_moe:
                f, _ = moe_forward(lp["ffn"], h, cfg)
            else:
                f = glu_mlp(lp["ffn"], h, cfg.activation, cfg.compute_dtype_)
            if cfg.post_block_norm:
                f = _apply_norm(lp["post_norm2"], f, cfg)
            return x + f, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            layer_fn, x, (params["layers"], windows, cache["k"], cache["v"]),
            unroll=cfg.n_layers if cfg.unroll_layers else 1)
        cache = dict(cache, k=k_new, v=v_new)
    else:
        def layer_fn(x, inp):
            lp, mc = inp
            h = _apply_norm(lp["norm1"], x, cfg)
            if cfg.layer_kind == "mamba1":
                y, mc = mamba1_decode(lp["mixer"], h, mc, cfg)
            else:
                y, mc = mamba2_decode(lp["mixer"], h, mc, cfg)
            return x + y, mc

        sa_pos = _shared_attn_positions(cfg)
        if not sa_pos:
            x, mcache = jax.lax.scan(layer_fn, x,
                                     (params["layers"], cache["mamba"]),
                                     unroll=cfg.n_layers if cfg.unroll_layers
                                     else 1)
            cache = dict(cache, mamba=mcache)
        else:
            bounds = [0] + [i + 1 for i in sa_pos]
            if bounds[-1] != cfg.n_layers:
                bounds.append(cfg.n_layers)
            sa_cfg = cfg.replace(layer_kind="attn", n_experts=0)
            mparts = []
            sak, sav = cache["sa_k"], cache["sa_v"]
            for si, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
                seg = jax.tree.map(lambda a: a[lo:hi], params["layers"])
                mseg = jax.tree.map(lambda a: a[lo:hi], cache["mamba"])
                x, mseg = jax.lax.scan(layer_fn, x, (seg, mseg),
                                       unroll=(hi - lo) if cfg.unroll_layers
                                       else 1)
                mparts.append(mseg)
                if si < len(sa_pos):
                    sp = params["shared_attn"]
                    h = _apply_norm(sp["norm1"], x, cfg)
                    a, kc, vc = attn_decode(sp["attn"], h, sa_cfg, window=0,
                                            k_cache=sak[si], v_cache=sav[si],
                                            lengths=lengths)
                    sak = sak.at[si].set(kc)
                    sav = sav.at[si].set(vc)
                    x = x + a
                    h = _apply_norm(sp["norm2"], x, cfg)
                    x = x + glu_mlp(sp["ffn"], h, cfg.activation,
                                    cfg.compute_dtype_)
            mcache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                  *mparts)
            cache = dict(cache, mamba=mcache, sa_k=sak, sa_v=sav)

    x = _apply_norm(params["final_norm"], x, cfg)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(table, x[:, 0], tied=cfg.tie_embeddings,
                     softcap=cfg.final_softcap)
    cache["lengths"] = lengths + 1
    return logits, cache


def prefill_forward(params: Params, inputs: jax.Array, cfg: ModelConfig,
                    max_len: int):
    """Fused full-sequence prefill: one forward pass over the prompt that
    also emits the serving cache (KV tensors / SSM states).  This is what the
    ``prefill_32k`` dry-run cells lower.

    inputs: (B, S) tokens or (B, S, d) embeddings.  Returns
    (last_logits (B, vocab), cache) with caches padded to ``max_len``.
    """
    b = inputs.shape[0]
    s = inputs.shape[1]
    if cfg.input_mode == "embeddings":
        x = inputs.astype(cfg.compute_dtype_)
    else:
        scale = cfg.d_model ** 0.5 if cfg.embed_scale else None
        x = embed(params["embed"], inputs, scale, cfg.compute_dtype_)

    cache = init_cache(cfg, b, max_len)
    pad = max_len - s

    if cfg.layer_kind == "attn":
        windows = window_schedule(cfg)

        def layer_fn(x, inp):
            lp, w = inp
            h = _apply_norm(lp["norm1"], x, cfg)
            a, (k, v) = attn_forward(lp["attn"], h, cfg, window=w,
                                     return_kv=True)
            if cfg.post_block_norm:
                a = _apply_norm(lp["post_norm1"], a, cfg)
            x = x + a
            h = _apply_norm(lp["norm2"], x, cfg)
            if cfg.is_moe:
                f, _ = moe_forward(lp["ffn"], h, cfg)
            else:
                f = glu_mlp(lp["ffn"], h, cfg.activation, cfg.compute_dtype_)
            if cfg.post_block_norm:
                f = _apply_norm(lp["post_norm2"], f, cfg)
            return shard_act(x + f), (k, v)

        fn = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
        x, (ks, vs) = jax.lax.scan(fn, x, (params["layers"], windows),
                                   unroll=cfg.n_layers if cfg.unroll_layers
                                   else 1)
        # (L, B, Hkv, S, hd) → pad the sequence axis to max_len
        cache["k"] = jnp.pad(ks, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        cache["v"] = jnp.pad(vs, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        def layer_fn(x, lp):
            h = _apply_norm(lp["norm1"], x, cfg)
            if cfg.layer_kind == "mamba1":
                y, st = mamba1_forward(lp["mixer"], h, cfg, return_state=True)
            else:
                y, st = mamba2_forward(lp["mixer"], h, cfg, return_state=True)
            return shard_act(x + y), st

        fn = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
        sa_pos = _shared_attn_positions(cfg)
        if not sa_pos:
            x, states = jax.lax.scan(fn, x, params["layers"],
                                     unroll=cfg.n_layers if cfg.unroll_layers
                                     else 1)
            cache["mamba"] = states
        else:
            bounds = [0] + [i + 1 for i in sa_pos]
            if bounds[-1] != cfg.n_layers:
                bounds.append(cfg.n_layers)
            sa_cfg = cfg.replace(layer_kind="attn", n_experts=0)
            parts, si = [], 0
            for lo, hi in zip(bounds[:-1], bounds[1:]):
                seg = jax.tree.map(lambda a: a[lo:hi], params["layers"])
                x, st = jax.lax.scan(fn, x, seg,
                                     unroll=(hi - lo) if cfg.unroll_layers
                                     else 1)
                parts.append(st)
                if hi - 1 in sa_pos:
                    sp = params["shared_attn"]
                    h = _apply_norm(sp["norm1"], x, sa_cfg)
                    a, (k, v) = attn_forward(sp["attn"], h, sa_cfg, window=0,
                                             return_kv=True)
                    cache["sa_k"] = cache["sa_k"].at[si, :, :, :s].set(k)
                    cache["sa_v"] = cache["sa_v"].at[si, :, :, :s].set(v)
                    si += 1
                    x = x + a
                    h = _apply_norm(sp["norm2"], x, sa_cfg)
                    x = x + glu_mlp(sp["ffn"], h, cfg.activation,
                                    cfg.compute_dtype_)
            cache["mamba"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *parts)

    x = _apply_norm(params["final_norm"], x, cfg)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(table, x[:, -1], tied=cfg.tie_embeddings,
                     softcap=cfg.final_softcap)
    cache["lengths"] = jnp.full((b,), s, jnp.int32)
    return logits, cache


def prefill(params: Params, cache: dict[str, Any], tokens: jax.Array,
            cfg: ModelConfig):
    """Fill the cache by running decode_step over the prompt via lax.scan.
    tokens: (B, S).  Returns (last_logits, cache).  (A fused full-sequence
    prefill exists on the dry-run path; this one is the simple serving API.)
    """
    def step(cache, tok):
        logits, cache = decode_step(params, cache, tok[:, None], cfg)
        return cache, logits

    cache, logits = jax.lax.scan(step, cache, tokens.T)
    return logits[-1], cache
