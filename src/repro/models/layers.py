"""Primitive layers — functional style: explicit param pytrees, pure applies.

Conventions:
  * params are nested dicts of jax.Arrays; leading ``L`` axis when stacked
    for ``lax.scan`` over layers;
  * weights stored in ``param_dtype``; matmuls run in ``compute_dtype``
    (bf16 on TPU) with fp32 accumulation (``preferred_element_type``);
    norms/softmax/rope always fp32;
  * Linear weights are (d_in, d_out) so TP column/row parallelism maps to
    sharding the last/first axis respectively (launch/shardings.py).
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]

# Cross-shard partial-sum dtype for TP contractions (see ModelConfig.
# matmul_reduce).  A contextvar so the launcher flips it without threading a
# parameter through every block; default fp32.
_REDUCE_DTYPE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_matmul_reduce", default=jnp.float32)


@contextlib.contextmanager
def matmul_reduce_dtype(dtype):
    token = _REDUCE_DTYPE.set(dtype)
    try:
        yield
    finally:
        _REDUCE_DTYPE.reset(token)


# -- init ---------------------------------------------------------------------

def dense_init(key: jax.Array, d_in: int, d_out: int,
               dtype=jnp.float32, scale: float | None = None) -> jax.Array:
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int,
               dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# -- linear / embedding -------------------------------------------------------

def linear(w: jax.Array, x: jax.Array,
           compute_dtype=jnp.bfloat16,
           reduce_dtype=None) -> jax.Array:
    """x: (..., d_in) @ w: (d_in, d_out).  In-shard accumulation is always
    fp32 on the MXU; ``reduce_dtype`` (default from the matmul_reduce_dtype
    context, fp32) sets the *partial-sum* dtype that crosses shards under TP
    (bf16 halves that wire traffic)."""
    y = jax.lax.dot_general(
        x.astype(compute_dtype), w.astype(compute_dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=reduce_dtype or _REDUCE_DTYPE.get())
    return y.astype(compute_dtype)


def embed(table: jax.Array, ids: jax.Array, scale: float | None = None,
          compute_dtype=jnp.bfloat16) -> jax.Array:
    x = jnp.take(table, ids, axis=0).astype(compute_dtype)
    if scale is not None:
        x = x * jnp.asarray(scale, compute_dtype)
    return x


def unembed(table_or_head: jax.Array, x: jax.Array, *, tied: bool,
            softcap: float | None = None) -> jax.Array:
    """Project to vocab logits (fp32).  ``tied=True`` uses the embedding
    table transposed; otherwise a (d, vocab) head."""
    xf = x.astype(jnp.float32)
    w = table_or_head.astype(jnp.float32)
    logits = xf @ (w.T if tied else w)
    if softcap is not None and softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# -- norms ---------------------------------------------------------------------

def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-6,
            weight_offset: float = 0.0) -> jax.Array:
    """RMSNorm in fp32.  ``weight_offset=1.0`` gives the Gemma convention
    (stored weights are centred at zero, applied as (1 + w))."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    return (xf * (w.astype(jnp.float32) + weight_offset)).astype(x.dtype)


def layernorm(w: jax.Array, b: jax.Array, x: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# -- rotary embeddings -----------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Apply rotary position embeddings.

    x: (..., S, D) with D even; positions: broadcastable to (..., S).
    Uses the split-halves convention (LLaMA / most OSS checkpoints).
    """
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- activations / MLPs -----------------------------------------------------------

def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


def glu_mlp_init(key: jax.Array, d_model: int, d_ff: int,
                 dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype,
                             scale=d_ff ** -0.5),
    }


def glu_mlp(p: Params, x: jax.Array, activation: str = "silu",
            compute_dtype=jnp.bfloat16) -> jax.Array:
    """Gated-linear-unit MLP (SwiGLU/GeGLU per ``activation``)."""
    g = _act(activation, linear(p["w_gate"], x, compute_dtype))
    u = linear(p["w_up"], x, compute_dtype)
    return linear(p["w_down"], g * u, compute_dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None or cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)
