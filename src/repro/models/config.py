"""ModelConfig — the single description every subsystem consumes.

One instance per assigned architecture lives in ``repro/configs/<id>.py``;
``reduced()`` derives the CPU-smoke-test variant (same family, tiny dims).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None      # None → d_model // n_heads

    # attention flavour
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0            # stablelm: partial rotary
    qk_norm: bool = False            # qwen3 / stablelm
    attn_softcap: float | None = None    # gemma2
    final_softcap: float | None = None   # gemma2
    sliding_window: int | None = None
    window_pattern: str = "none"     # none | all | alternate (gemma2)
    post_block_norm: bool = False    # gemma2 sandwich norms

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0
    shared_expert_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3

    # SSM / hybrid
    layer_kind: str = "attn"         # attn | mamba1 | mamba2 (homogeneous stack)
    ssm_state: int = 0
    d_inner: int = 0                 # 0 → 2 * d_model
    conv_kernel: int = 4
    mamba_head_dim: int = 64         # mamba2 heads = d_inner / mamba_head_dim
    shared_attn_every: int = 0       # zamba2: shared attn block period (0 = off)

    # io / numerics
    input_mode: str = "tokens"       # tokens | embeddings (vlm/audio stub)
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-6
    norm_offset: float = 0.0         # gemma: weights applied as (1 + w)
    activation: str = "silu"
    embed_scale: bool = False        # gemma: × sqrt(d_model)
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True

    # accounting knobs (launch/dryrun): XLA's cost model counts while-loop
    # bodies once, so the roofline "account" variant unrolls the layer scan
    # and widens attention chunks to make every FLOP visible in the HLO.
    unroll_layers: bool = False
    attn_chunk: int = 1024
    # matmul partial-sum dtype: 'float32' (default) or 'bfloat16' — bf16
    # halves the TP all-reduce bytes of every row-parallel contraction at the
    # cost of bf16 cross-shard summation (16 terms); see §Perf.
    matmul_reduce: str = "float32"

    notes: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner_(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def param_dtype_(self):
        return getattr(jnp, self.param_dtype)

    @property
    def compute_dtype_(self):
        return getattr(jnp, self.compute_dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attn_free(self) -> bool:
        return self.layer_kind in ("mamba1", "mamba2") and \
            self.shared_attn_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape: SSM/hybrid, or SWA on every
        attention layer (bounded KV window)."""
        return self.layer_kind != "attn" or self.window_pattern in (
            "all", "alternate") and (self.sliding_window or 0) > 0

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, h = self.d_model, self.head_dim_
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.layer_kind == "attn":
            attn = d * h * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * h * d
            if self.is_moe:
                ff = self.n_experts * 3 * d * self.expert_d_ff \
                    + self.n_shared_experts * 3 * d * self.shared_expert_d_ff \
                    + d * self.n_experts  # router
                if self.n_shared_experts:
                    ff += d  # shared-expert gate
            else:
                ff = 3 * d * self.d_ff
            per_layer = attn + ff + 2 * d
        elif self.layer_kind == "mamba1":
            di, n = self.d_inner_, self.ssm_state
            per_layer = (d * 2 * di            # in_proj
                         + di * self.conv_kernel
                         + di * (2 * n + di // 16)  # x_proj(Δ,B,C) low-rank dt
                         + di // 16 * di       # dt_proj
                         + di * n + di         # A, D
                         + di * d + d)         # out_proj + norm
        elif self.layer_kind == "mamba2":
            di, n = self.d_inner_, self.ssm_state
            nh = di // self.mamba_head_dim
            per_layer = (d * (2 * di + 2 * n + nh)  # in_proj (x,z,B,C,dt)
                         + (di + 2 * n) * self.conv_kernel
                         + nh * 2               # A, D per head
                         + di * d + d + di)     # out_proj, norms
        total = emb + self.n_layers * per_layer
        if self.shared_attn_every > 0:
            h_ = self.head_dim_
            total += (d * h_ * (self.n_heads + 2 * self.n_kv_heads)
                      + self.n_heads * h_ * d + 3 * d * self.d_ff + 2 * d)
        return total

    def n_active_params(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        routed_all = self.n_experts * 3 * d * self.expert_d_ff
        routed_active = self.top_k * 3 * d * self.expert_d_ff
        return self.n_params() - self.n_layers * (routed_all - routed_active)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape: which step it lowers and its dims."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The runnable cells for an arch: long_500k only for sub-quadratic
    architectures (DESIGN.md §5); everything else runs all four."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return out
