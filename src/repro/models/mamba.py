"""Mamba blocks: Mamba-1 (falcon-mamba) and Mamba-2/SSD (zamba2).

Mamba-1 runs through ``kernels/mamba_scan`` (XLA scan ref by default, Pallas
on TPU).  Mamba-2 uses the chunked SSD matrix form (Mamba-2 [arXiv:2405.21060]
§6) — block-diagonal attention-like intra-chunk matmuls + inter-chunk state
recurrence — which is MXU-shaped by construction, so it stays in jnp/XLA
(DESIGN.md §4: the SSD reformulation *is* the TPU adaptation of the scan).

Both provide O(1)-state decode steps for the long_500k serving shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.mamba_scan import ops as scan_ops
from .config import ModelConfig
from .layers import Params, dense_init, rmsnorm, linear


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba-7b)
# ---------------------------------------------------------------------------

def mamba1_init(key: jax.Array, cfg: ModelConfig) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner_, cfg.ssm_state
    dt_rank = max(1, d // 16)
    dt_ = cfg.param_dtype_
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dt_),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, di), jnp.float32)
                   * (cfg.conv_kernel * di) ** -0.5).astype(dt_),
        "conv_b": jnp.zeros((di,), dt_),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * n, dt_),
        "dt_proj": dense_init(ks[3], dt_rank, di, dt_),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        # S4D-real init: A = -(1..N) per channel
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dt_, scale=di ** -0.5),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv1d.  x: (B, L, D); w: (K, D); state: (B, K-1, D)
    carries the last K-1 inputs for decode.  Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)              # (B, K-1+L, D)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return y + b[None, None], new_state


def mamba1_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                   use_pallas: bool = False, return_state: bool = False):
    """x: (B, L, d) → (B, L, d) [, final cache state for prefill]."""
    cd = cfg.compute_dtype_
    d, di, n = cfg.d_model, cfg.d_inner_, cfg.ssm_state
    dt_rank = max(1, d // 16)
    xz = linear(p["in_proj"], x, cd)
    xi_raw, z = jnp.split(xz, 2, axis=-1)                 # (B, L, di) ×2
    xi, conv_state = _causal_conv(xi_raw, p["conv_w"].astype(cd),
                                  p["conv_b"].astype(cd))
    xi = jax.nn.silu(xi)
    dbc = linear(p["x_proj"], xi, cd)
    dt, B, C = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(
        linear(p["dt_proj"], dt, cd).astype(jnp.float32)
        + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y, h_final = scan_ops.scan(xi.astype(jnp.float32), delta, A,
                               B.astype(jnp.float32), C.astype(jnp.float32),
                               p["D"], use_pallas=use_pallas)
    y = y.astype(cd) * jax.nn.silu(z)
    out = linear(p["out_proj"], y, cd)
    if return_state:
        return out, {"conv": conv_state, "ssm": h_final}
    return out


def mamba1_init_cache(cfg: ModelConfig, batch: int):
    di, n = cfg.d_inner_, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, di), cfg.compute_dtype_),
        "ssm": jnp.zeros((batch, di, n), jnp.float32),
    }


def mamba1_decode(p: Params, x: jax.Array, cache: dict, cfg: ModelConfig):
    """x: (B, 1, d) one token; cache: {conv (B,K-1,di), ssm (B,di,N)}."""
    cd = cfg.compute_dtype_
    d, di, n = cfg.d_model, cfg.d_inner_, cfg.ssm_state
    dt_rank = max(1, d // 16)
    xz = linear(p["in_proj"], x, cd)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_state = _causal_conv(xi, p["conv_w"].astype(cd),
                                  p["conv_b"].astype(cd), cache["conv"])
    xi = jax.nn.silu(xi)
    dbc = linear(p["x_proj"], xi, cd)
    dt, B, C = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(
        linear(p["dt_proj"], dt, cd).astype(jnp.float32)
        + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y_t, h = scan_ops.decode_step(
        cache["ssm"], xi[:, 0].astype(jnp.float32), delta[:, 0], A,
        B[:, 0].astype(jnp.float32), C[:, 0].astype(jnp.float32), p["D"])
    y = (y_t[:, None].astype(cd)) * jax.nn.silu(z)
    return linear(p["out_proj"], y, cd), {"conv": conv_state, "ssm": h}


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2)
# ---------------------------------------------------------------------------

def mamba2_init(key: jax.Array, cfg: ModelConfig) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner_, cfg.ssm_state
    nh = di // cfg.mamba_head_dim
    dt_ = cfg.param_dtype_
    ks = jax.random.split(key, 4)
    return {
        # in_proj emits [x (di), z (di), B (n), C (n), dt (nh)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * n + nh, dt_),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, di + 2 * n),
                                     jnp.float32)
                   * (cfg.conv_kernel * di) ** -0.5).astype(dt_),
        "conv_b": jnp.zeros((di + 2 * n,), dt_),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((di,), dt_),
        "out_proj": dense_init(ks[2], di, d, dt_, scale=di ** -0.5),
    }


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD (Mamba-2 'matrix transformer' form), fp32.

    x: (b, l, h, p); dt: (b, l, h); A: (h,) negative; B, C: (b, l, n).
    Returns y: (b, l, h, p).
    """
    b, slen, h, p = x.shape
    n = B.shape[-1]
    assert slen % chunk == 0
    nc = slen // chunk
    x = x.reshape(b, nc, chunk, h, p)
    dt = dt.reshape(b, nc, chunk, h)
    B_ = B.reshape(b, nc, chunk, n)
    C_ = C.reshape(b, nc, chunk, n)

    dA = dt * A[None, None, None]                       # (b, nc, c, h) ≤ 0
    dA_cum = jnp.cumsum(dA, axis=2)
    # intra-chunk: L[i,j] = exp(dA_cum[i] - dA_cum[j]) for i ≥ j
    seg = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]  # (b,nc,c,c,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bzin,bzjn->bzij", C_, B_)      # (b, nc, c, c)
    y_diag = jnp.einsum("bzij,bzijh,bzjh,bzjhp->bzihp",
                        scores, Lmat, dt, x)

    # chunk-final states: S_z = Σ_j exp(dA_cum[last]-dA_cum[j])·dt_j·B_j⊗x_j
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # (b, nc, c, h)
    S = jnp.einsum("bzjh,bzjh,bzjn,bzjhp->bzhnp",
                   decay_to_end, dt, B_, x)                 # per-chunk state

    # inter-chunk recurrence over nc (sequential scan over chunks)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])              # (b, nc, h)

    def step(carry, inp):
        s_prev = carry                                      # (b, h, n, p)
        s_z, decay_z = inp                                  # (b,h,n,p),(b,h)
        s_new = decay_z[..., None, None] * s_prev + s_z
        return s_new, s_prev

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    s_final, states_in = jax.lax.scan(
        step, s0, (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)               # (b, nc, h, n, p)

    # contribution of the carried state within each chunk
    decay_from_start = jnp.exp(dA_cum)                      # (b, nc, c, h)
    y_off = jnp.einsum("bzin,bzih,bzhnp->bzihp",
                       C_, decay_from_start, states_in)
    y = (y_diag + y_off).reshape(b, slen, h, p)
    return y, s_final


def mamba2_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                   chunk: int = 64, return_state: bool = False):
    cd = cfg.compute_dtype_
    di, n = cfg.d_inner_, cfg.ssm_state
    hd = cfg.mamba_head_dim
    nh = di // hd
    b, slen, _ = x.shape
    proj = linear(p["in_proj"], x, cd)
    xi, z, B, C, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    xbc = jnp.concatenate([xi, B, C], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(cd),
                                   p["conv_b"].astype(cd))
    xbc = jax.nn.silu(xbc)
    xi, B, C = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    if slen % chunk:
        pad = chunk - slen % chunk
        xi_p = jnp.pad(xi, ((0, 0), (0, pad), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_p = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C_p = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    else:
        xi_p, dt_p, B_p, C_p = xi, dt, B, C
    y, s_final = _ssd_chunked(
        xi_p.astype(jnp.float32).reshape(b, -1, nh, hd), dt_p, A,
        B_p.astype(jnp.float32), C_p.astype(jnp.float32), chunk)
    y = y[:, :slen] + xi.astype(jnp.float32).reshape(b, slen, nh, hd) \
        * p["D"][None, None, :, None]
    y = y.reshape(b, slen, di).astype(cd) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = linear(p["out_proj"], y, cd)
    if return_state:
        return out, {"conv": conv_state, "ssm": s_final}
    return out


def mamba2_init_cache(cfg: ModelConfig, batch: int):
    di, n = cfg.d_inner_, cfg.ssm_state
    nh = di // cfg.mamba_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, di + 2 * n),
                          cfg.compute_dtype_),
        "ssm": jnp.zeros((batch, nh, n, cfg.mamba_head_dim), jnp.float32),
    }


def mamba2_decode(p: Params, x: jax.Array, cache: dict, cfg: ModelConfig):
    """One-token SSD recurrence: h ← exp(dtA)·h + dt·B⊗x ; y = C·h."""
    cd = cfg.compute_dtype_
    di, n = cfg.d_inner_, cfg.ssm_state
    hd = cfg.mamba_head_dim
    nh = di // hd
    b = x.shape[0]
    proj = linear(p["in_proj"], x, cd)
    xi, z, B, C, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    xbc = jnp.concatenate([xi, B, C], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(cd),
                                   p["conv_b"].astype(cd), cache["conv"])
    xbc = jax.nn.silu(xbc)
    xi, B, C = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    xh = xi[:, 0].astype(jnp.float32).reshape(b, nh, hd)
    dt0 = dt[:, 0]                                       # (b, nh)
    decay = jnp.exp(dt0 * A[None])                       # (b, nh)
    dBx = jnp.einsum("bh,bn,bhp->bhnp", dt0, B[:, 0].astype(jnp.float32), xh)
    h = decay[..., None, None] * cache["ssm"] + dBx
    y = jnp.einsum("bn,bhnp->bhp", C[:, 0].astype(jnp.float32), h)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, 1, di).astype(cd) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return linear(p["out_proj"], y, cd), {"conv": conv_state, "ssm": h}
