"""Attention blocks: GQA with RoPE, qk-norm, sliding windows, softcaps.

Supports the whole assigned-pool attention zoo:
  gemma2   — alternating local/global windows, attn softcap, sandwich norms
  qwen3    — per-head-dim RMS qk-norm
  stablelm — partial rotary (rope_pct), layernorm
  mixtral  — SWA on all layers
  yi/qwen3/stablelm/musicgen/internvl2 — plain GQA/MHA variants

Window handling under the layer scan: the per-layer window is a *traced*
int32 scalar (0 = global) so a single scanned program serves alternating
patterns; the mask math treats window<=0 as no window.  The Pallas kernels
take static windows and are used on the unrolled/serving paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.ops import chunked_attention, decode_ref
from .config import ModelConfig
from .layers import Params, dense_init, linear, rmsnorm, rope

NEG_INF = -1e30


def attn_init(key: jax.Array, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.head_dim_
    dt = cfg.param_dtype_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dt,
                         scale=(cfg.n_heads * hd) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _project_qkv(p: Params, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array):
    """x: (B, S, d) → q (B, Hq, S, hd), k/v (B, Hkv, S, hd) with norm+rope."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    cd = cfg.compute_dtype_
    q = linear(p["wq"], x, cd).reshape(b, s, cfg.n_heads, hd)
    k = linear(p["wk"], x, cd).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x, cd).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = q.transpose(0, 2, 1, 3)   # (B, H, S, hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if cfg.rope_pct > 0:
        r = int(hd * cfg.rope_pct)
        r -= r % 2
        pos = positions[:, None, :]   # (B, 1, S) broadcast over heads
        q = q.at[..., :r].set(rope(q[..., :r], pos, cfg.rope_theta)) \
            if r < hd else rope(q, pos, cfg.rope_theta)
        k = k.at[..., :r].set(rope(k[..., :r], pos, cfg.rope_theta)) \
            if r < hd else rope(k, pos, cfg.rope_theta)
    return q, k, v


def _masked_attention(q, k, v, *, window, softcap, scale, q_offset=0,
                      chunk=1024):
    """chunked_attention wrapper accepting a traced window (0 = global)."""
    if isinstance(window, (int, type(None))):
        w = window if (window or 0) > 0 else None
        return chunked_attention(q, k, v, causal=True, window=w,
                                 softcap=softcap, scale=scale, chunk=chunk,
                                 q_offset=q_offset)
    # traced window: inline online-softmax with dynamic mask
    return _traced_window_attention(q, k, v, window=window, softcap=softcap,
                                    scale=scale, q_offset=q_offset,
                                    chunk=chunk)


def _traced_window_attention(q, k, v, *, window, softcap, scale, q_offset,
                             chunk):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale_ = scale if scale is not None else d ** -0.5
    chunk = min(chunk, skv)
    assert skv % chunk == 0
    n_chunks = skv // chunk
    qf = q.astype(jnp.float32) * scale_
    kf = k.astype(jnp.float32).reshape(b, hkv, n_chunks, chunk, d)
    vf = v.astype(jnp.float32).reshape(b, hkv, n_chunks, chunk, d)
    q_pos = jnp.arange(sq) + q_offset

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kc, vc, c_idx = inp
        kc = jnp.repeat(kc, group, axis=1)
        vc = jnp.repeat(vc, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc)
        if softcap is not None and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = c_idx * chunk + jnp.arange(chunk)
        mask = q_pos[:, None] >= k_pos[None, :]
        win_ok = (window <= 0) | (q_pos[:, None] - k_pos[None, :] < window)
        mask &= win_ok
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p_ = jnp.where(mask[None, None], jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p_, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p_, vc)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, hq, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, hq, sq), jnp.float32),
            jnp.zeros((b, hq, sq, d), jnp.float32))
    (m, lsum, acc), _ = jax.lax.scan(
        step, init, (jnp.moveaxis(kf, 2, 0), jnp.moveaxis(vf, 2, 0),
                     jnp.arange(n_chunks)))
    denom = jnp.where(lsum > 0, lsum, 1.0)
    return (acc / denom[..., None]).astype(q.dtype)


def attn_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                 window, positions: jax.Array | None = None,
                 return_kv: bool = False):
    """Full-sequence causal attention (train / prefill).

    window: static int/None or traced int32 scalar (0 = global).
    Returns y (B, S, d) and optionally the (k, v) tensors for cache fill.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = _masked_attention(q, k, v, window=window, softcap=cfg.attn_softcap,
                          scale=None, chunk=cfg.attn_chunk)
    y = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim_)
    y = linear(p["wo"], y, cfg.compute_dtype_)
    if return_kv:
        return y, (k, v)
    return y


def attn_decode(p: Params, x: jax.Array, cfg: ModelConfig, *,
                window, k_cache: jax.Array, v_cache: jax.Array,
                lengths: jax.Array):
    """One-token decode: x (B, 1, d); caches (B, Hkv, S, hd); lengths (B,).

    Writes the new token's k/v at position ``lengths`` and attends over
    [0, lengths].  Returns (y (B, 1, d), k_cache, v_cache).
    """
    b = x.shape[0]
    positions = lengths[:, None]           # the new token's position
    q, k, v = _project_qkv(p, x, cfg, positions)
    # scatter the new kv into the cache at per-sequence positions
    def write(cache, new):
        def one(c, n, i):
            return jax.lax.dynamic_update_slice(c, n, (0, i, 0))
        return jax.vmap(one)(cache, new, lengths)
    k_cache = write(k_cache, k)            # k: (B, Hkv, 1, hd)
    v_cache = write(v_cache, v)
    new_len = lengths + 1
    if isinstance(window, (int, type(None))):
        w = window if (window or 0) > 0 else None
        o = decode_ref(q[:, :, 0], k_cache, v_cache, new_len, window=w,
                       softcap=cfg.attn_softcap)
    else:
        o = _traced_window_decode(q[:, :, 0], k_cache, v_cache, new_len,
                                  window=window, softcap=cfg.attn_softcap)
    y = o.reshape(b, 1, cfg.n_heads * cfg.head_dim_)
    y = linear(p["wo"], y, cfg.compute_dtype_)
    return y, k_cache, v_cache


def _traced_window_decode(q, k_cache, v_cache, lengths, *, window, softcap):
    b, hq, d = q.shape
    _, hkv, s_max, _ = k_cache.shape
    group = hq // hkv
    # grouped (repeat-free) form — see kernels/flash_attention/ref.decode_ref
    qg = (q.astype(jnp.float32) * d ** -0.5).reshape(b, hkv, group, d)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, kf)
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = jnp.arange(s_max)[None, None, None, :]
    valid = k_pos < lengths[:, None, None, None]
    valid &= (window <= 0) | (k_pos >= lengths[:, None, None, None] - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, vf)
    return o.reshape(b, hq, d).astype(q.dtype)


def window_schedule(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer window sizes as an (L,) int32 array (0 = global attention).

    gemma2 'alternate': even layers local (SWA), odd layers global.
    mixtral 'all': every layer windowed.
    """
    w = cfg.sliding_window or 0
    if cfg.window_pattern == "all":
        arr = [w] * cfg.n_layers
    elif cfg.window_pattern == "alternate":
        arr = [w if i % 2 == 0 else 0 for i in range(cfg.n_layers)]
    else:
        arr = [0] * cfg.n_layers
    return jnp.asarray(arr, jnp.int32)
