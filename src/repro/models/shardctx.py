"""Activation-sharding context.

Models are pure functions; distribution policy belongs to the launcher.  The
launcher opens ``activation_sharding(...)`` around lowering, and the model
calls ``shard_act(x, kind)`` at block boundaries — a no-op when no context is
set (tests, single-device runs), a ``with_sharding_constraint`` under the
production mesh.  This pins the two tensors GSPMD otherwise leaves fat:
per-layer residuals (B, S, d) and the fp32 logits (B, S, vocab).
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

_CTX: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "repro_act_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(batch_axes, tp_axis: str, tp_size: int,
                        batch_size: int, d_model: int, vocab: int,
                        seq_axis: str | None = None, dp_size: int = 1):
    """batch_axes: axis (or tuple) for the batch dim (None when the batch
    cannot shard, e.g. long_500k's batch=1); tp_axis for hidden/vocab.
    Divisibility decided here, once."""
    ctx = {
        "batch": batch_axes,
        "tp": (tp_axis if d_model % tp_size == 0 else None) if tp_axis
        else None,
        "tp_vocab": (tp_axis if vocab % tp_size == 0 else None) if tp_axis
        else None,
        "seq": seq_axis,
        "dp_size": dp_size if batch_axes is not None else 1,
    }
    token = _CTX.set(ctx)
    try:
        yield
    finally:
        _CTX.reset(token)


def dp_shards() -> int:
    """Number of data shards for locality-aware token dispatch (MoE).
    1 when no sharding context is active (tests / single device)."""
    ctx = _CTX.get()
    return int(ctx.get("dp_size", 1)) if ctx else 1


def shard_act(x: jax.Array, kind: str = "act") -> jax.Array:
    """kind: 'act' (B, S, d) | 'logits' (B, S, V) or (B, V)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    try:
        if kind == "act" and x.ndim == 3:
            spec = P(ctx["batch"], ctx["seq"], ctx["tp"])
        elif kind == "logits" and x.ndim == 3:
            spec = P(ctx["batch"], ctx["seq"], ctx["tp_vocab"])
        elif kind == "logits" and x.ndim == 2:
            spec = P(ctx["batch"], ctx["tp_vocab"])
        elif kind == "moe_buf" and x.ndim == 3:
            # (E, C, d) dispatch buffer: capacity rows over the batch axes;
            # hidden dim replicated so the expert GEMM contracts locally
            # (sharding d forced a gather of the buffer per einsum — §Perf)
            spec = P(None, ctx["batch"], None)
        else:
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x  # no mesh in scope → leave unconstrained
