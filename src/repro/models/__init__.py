from .config import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                     SHAPES_BY_NAME, TRAIN_4K, ModelConfig, ShapeConfig,
                     shapes_for)
from .transformer import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill, prefill_forward)

__all__ = [
    "ALL_SHAPES", "DECODE_32K", "LONG_500K", "PREFILL_32K", "SHAPES_BY_NAME",
    "TRAIN_4K", "ModelConfig", "ShapeConfig", "shapes_for", "decode_step",
    "forward", "init_cache", "init_params", "loss_fn", "prefill",
    "prefill_forward",
]
