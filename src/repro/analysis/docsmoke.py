"""docsmoke — execute the documentation's Python snippets.

Documentation that cannot run is documentation that has already
drifted.  This module extracts every fenced ```python`` block from the
repo's markdown (README plus ``docs/``) and executes it, so the CI
``analysis`` job fails the moment a quickstart or runbook snippet stops
matching the code — on both JAX pins, since the snippets import the
real package.

Contract:

* Blocks in one file run **in order and share one namespace**, so a
  document can build state across snippets the way a reader would type
  them (imports in the first block, usage in the next).
* A block is skipped when the line *immediately above its opening
  fence* is ``<!-- docsmoke: skip -->`` — for illustrative fragments
  (shell output, pseudo-code, intentionally-failing examples).
* Only ```` ```python ```` fences run; bare ``` fences and other
  languages are prose.
* Any exception fails the run with the markdown file and the line the
  block opened on, plus the traceback — exit 1 from the CLI.

CLI::

    PYTHONPATH=src python -m repro.analysis.docsmoke            # README + docs/
    PYTHONPATH=src python -m repro.analysis.docsmoke docs/operations.md
"""

from __future__ import annotations

import argparse
import pathlib
import re
import sys
import traceback
from dataclasses import dataclass

__all__ = ["Snippet", "extract_snippets", "run_file", "run_paths", "main"]

_FENCE_OPEN = re.compile(r"^\s*```python\s*$")
_FENCE_CLOSE = re.compile(r"^\s*```\s*$")
_SKIP_MARK = re.compile(r"<!--\s*docsmoke:\s*skip\s*-->")

#: default corpus: the quickstart plus the whole documentation tree
DEFAULT_PATHS = ("README.md", "docs")


@dataclass(frozen=True)
class Snippet:
    """One fenced ```python`` block: its source text, the markdown file
    it came from, and the 1-based line of its opening fence (what the
    failure report points at)."""

    path: str
    line: int
    source: str


def extract_snippets(text: str, path: str) -> list[Snippet]:
    """All runnable ```python`` blocks of one markdown document, in
    order.  A ``<!-- docsmoke: skip -->`` on the line directly above a
    fence drops that block."""
    snippets: list[Snippet] = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        if _FENCE_OPEN.match(lines[i]):
            skipped = i > 0 and bool(_SKIP_MARK.search(lines[i - 1]))
            start = i + 1
            j = start
            while j < len(lines) and not _FENCE_CLOSE.match(lines[j]):
                j += 1
            if not skipped:
                snippets.append(Snippet(path=path, line=i + 1,
                                        source="\n".join(lines[start:j])))
            i = j + 1
        else:
            i += 1
    return snippets


def run_file(path: pathlib.Path, verbose: bool = False) -> list[str]:
    """Execute one document's snippets in a shared namespace; returns
    failure reports (empty when the document runs clean)."""
    snippets = extract_snippets(path.read_text(), str(path))
    namespace: dict = {"__name__": f"docsmoke:{path}"}
    failures: list[str] = []
    for snip in snippets:
        if verbose:
            print(f"[docsmoke] {snip.path}:{snip.line}")
        try:
            code = compile(snip.source, f"{snip.path}:{snip.line}", "exec")
            exec(code, namespace)  # noqa: S102 — executing our own docs is the point
        except Exception:
            failures.append(f"{snip.path}:{snip.line}: snippet raised\n"
                            f"{traceback.format_exc()}")
    return failures


def run_paths(paths, verbose: bool = False) -> tuple[int, list[str]]:
    """Run every markdown file under ``paths`` (files pass through,
    directories recurse over ``*.md``); returns (snippet-bearing file
    count, failure reports)."""
    files: list[pathlib.Path] = []
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.suffix == ".md":
            files.append(p)
    failures: list[str] = []
    n = 0
    for f in files:
        if not f.exists():
            failures.append(f"{f}: no such file")
            continue
        reports = run_file(f, verbose=verbose)
        n += 1
        failures.extend(reports)
    return n, failures


def main(argv=None) -> int:
    """CLI entry point: 0 when every snippet ran, 1 otherwise."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.docsmoke",
        description="run the fenced ```python blocks in the docs")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="markdown files or directories "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print each snippet as it runs")
    args = ap.parse_args(argv)
    n, failures = run_paths(args.paths, verbose=args.verbose)
    for report in failures:
        print(report, file=sys.stderr)
    print(f"docsmoke: {n} file(s), {len(failures)} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
