"""repro.analysis — static checks for plans and for the tree.

Two passes share the :class:`~repro.analysis.diagnostics.Diagnostic`
currency:

* **planlint** (:mod:`repro.analysis.planlint`) — semantic rules over a
  lowered ``BuiltPipeline`` (ring depth, hash-collision odds, group
  capacity, watermark wiring, sink prefixes, donation).  Runs at
  ``Pipeline.build()`` (warnings), ``JobServer.submit()`` (errors
  reject), and on demand via ``BuiltPipeline.check()`` / ``explain()``.
* **reprolint** (:mod:`repro.analysis.reprolint`) — stdlib-``ast`` lint
  of repo invariants (shard_map confinement, lane safety, SPMD purity,
  donation rebinding, documented exports), driven by ``python -m
  repro.analysis.lint``.
* **docsmoke** (:mod:`repro.analysis.docsmoke`) — executes the fenced
  ```python`` blocks in README + ``docs/`` so documentation cannot
  drift from the code; ``python -m repro.analysis.docsmoke``.

Submodules resolve lazily so the jax-free lint CLI never drags in the
plan layer (``diagnostics`` imports ``pipeline.graph`` for the
``PipelineError`` base, nothing heavier).
"""

from __future__ import annotations

from .lanes import LANES, lane

_LAZY = {
    "Diagnostic": "diagnostics", "PlanLintWarning": "diagnostics",
    "PlanRejected": "diagnostics", "ERROR": "diagnostics",
    "WARNING": "diagnostics", "INFO": "diagnostics",
    "errors": "diagnostics", "format_report": "diagnostics",
    "check_plan": "planlint", "explain_plan": "planlint",
    "min_slots_required": "planlint", "collision_probability": "planlint",
    "lint_source": "reprolint", "lint_file": "reprolint",
    "lint_paths": "reprolint",
    "extract_snippets": "docsmoke", "run_paths": "docsmoke",
}

__all__ = ["LANES", "lane", *sorted(_LAZY)]


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
