"""planlint — semantic checks over lowered ``BuiltPipeline`` DAGs.

The build validator (``pipeline.lower``) rejects grammar violations; this
pass goes after the failure modes that today only surface **mid-stream**,
after a job already holds pool replicas: ring-slot exhaustion, silent
hashed-key merging, group-buffer overflow, stalled watermarks, sinks that
collide with sources or the checkpoint namespace, and donation misuse.
Each rule emits structured :class:`~repro.analysis.diagnostics.Diagnostic`
records; ``Pipeline.build`` surfaces warnings, ``JobServer.submit``
rejects errors (:class:`~repro.analysis.diagnostics.PlanRejected`) before
the job registers — the admission layer the paper's declared-job story
implies.

Rules (stable ids — tests pin them):

======  ====================================================================
PL001   the window ring must hold the full span: ``n_slots >=``
        ``min_slots_required(size, slide, lateness)``; below it, a
        sustained stream MUST raise ``streaming.state``'s "window ring
        full" at runtime
PL002   hashed key spaces fold labels to 24-bit raw ids; the birthday
        bound on ``num_buckets`` expected keys estimates the odds two
        distinct keys silently merge — warn above 1%
PL003   group-mode ``capacity`` bounds one partition's record buffer; a
        single skewed micro-batch can stage ``ceil(batch_records /
        n_workers)`` rows into one (slot, partition) cell — warn when
        capacity is below that floor (overflow counts, then drops)
PL004   watermark wiring: every stage side needs an input channel
        (external stream or in-edge) or its watermark pins at -inf and no
        window ever finalizes; carry-fed stages receive finalized windows
        in watermark order, so lateness slack there is dead config; a
        join over sides with different upstream window sizes holds
        windows open to the slower side (min-over-inputs)
PL005   sink prefixes must not overlap each other, any source log prefix
        (the pipeline would re-ingest its own output), or the reserved
        ``jobs/`` checkpoint namespace (restore scans would list the
        carry blob as a persisted window)
PL006   donation: ``RunOptions.donate_carry`` under a ``jit=False`` build
        is silently unavailable; a join's two side plans donate one
        shared carry, so any hand-rolled driver must rebind between side
        folds
======  ====================================================================
"""

from __future__ import annotations

import math

from .diagnostics import ERROR, INFO, WARNING, Diagnostic

#: width of the hashed wire key ids (``engine.stages.fold_key24``) — kept
#: in sync by a test against ``engine.stages.RAW_KEY_BITS`` rather than an
#: import, so the lint CLI never pays (or requires) the jax import chain
RAW_KEY_BITS = 24

#: PL002 threshold: warn when the birthday bound crosses 1%
COLLISION_WARN_P = 0.01

#: PL005: store namespaces the runtime owns (``_carry_key`` writes
#: ``jobs/<job_id>/stream/carry`` through the same store as the sinks)
RESERVED_PREFIXES = ("jobs/",)

RULES = {
    "PL001": "window ring too small for the window span (+ lateness)",
    "PL002": "hashed fold_key24 collision probability above threshold",
    "PL003": "group-mode capacity below one micro-batch's worst-case load",
    "PL004": "watermark wiring: unfed side / dead lateness / lagging join",
    "PL005": "sink prefix overlaps a sink, a source, or a reserved namespace",
    "PL006": "carry donation unavailable or shared across join sides",
}

__all__ = ["RULES", "check_plan", "explain_plan", "min_slots_required",
           "collision_probability", "RAW_KEY_BITS", "COLLISION_WARN_P",
           "RESERVED_PREFIXES"]


def min_slots_required(size: float, slide: float | None = None,
                       lateness: float = 0.0) -> int:
    """Minimum ring depth for fixed windows: every window whose span
    ``[start, end + lateness)`` can contain one event time must be
    admissible at one instant, plus one slot for the window the next event
    opens while the oldest is still closing.  The single source of truth —
    ``pipeline.lower`` validates builds with it, ``streaming.state``
    validates direct tracker construction, PL001 re-derives it for
    hand-assembled plans."""
    step = slide or size
    return math.ceil((size + lateness) / step) + 1


def collision_probability(n_keys: int, bits: int = RAW_KEY_BITS) -> float:
    """Birthday bound: odds that ``n_keys`` uniform draws from a
    ``2**bits`` id space contain at least one collision."""
    if n_keys < 2:
        return 0.0
    return -math.expm1(-n_keys * (n_keys - 1) / 2.0 / float(1 << bits))


def _record_stages(built):
    return [st for st in built.stages if st.window is not None]


def _check_ring_slots(built, out: list) -> None:
    """PL001 — a config below the slot floor cannot survive a sustained
    stream: the watermark trails the newest window by the full span, so
    eventually two live windows share a modular slot and ``slot_for``
    raises mid-batch with the job already admitted."""
    for st in _record_stages(built):
        w = st.window
        if w.is_session:
            if st.n_slots < 2:
                out.append(Diagnostic(
                    "PL001", ERROR,
                    f"session ring n_slots={st.n_slots}: one slot cannot "
                    f"hold a closing session and an opening one — need "
                    f">= 2", loc=f"stage {st.index}"))
            continue
        need = min_slots_required(w.size, w.slide, st.allowed_lateness)
        step = w.slide or w.size
        if st.n_slots < need:
            out.append(Diagnostic(
                "PL001", ERROR,
                f"n_slots={st.n_slots} cannot hold the window span; need "
                f">= {need} for size={w.size}, slide={step}, "
                f"lateness={st.allowed_lateness} — a sustained stream "
                f"must raise \"window ring full\" mid-batch",
                loc=f"stage {st.index}"))


def _check_hash_collisions(built, out: list) -> None:
    """PL002 — hashed mode folds arbitrary labels into 24-bit raw ids;
    two keys sharing a raw id merge silently (bucket collisions are
    counted, raw-id collisions are not observable).  ``num_buckets`` is
    the declared key-cardinality budget, so it bounds the estimate."""
    if built.key_space != "hashed":
        return
    seen: set[int] = set()
    for st in _record_stages(built):
        n = st.num_buckets
        if n in seen:
            continue
        seen.add(n)
        p = collision_probability(n)
        level = WARNING if p >= COLLISION_WARN_P else INFO
        out.append(Diagnostic(
            "PL002", level,
            f"hashed key space: ~{p:.2%} odds that {n} distinct keys "
            f"collide in the {RAW_KEY_BITS}-bit raw-id space (silent "
            f"merge)" + (" — use key_space='dense' or fewer expected keys"
                         if level == WARNING else ""),
            loc=f"stage {st.index}"))


def _check_group_capacity(built, out: list) -> None:
    """PL003 — group mode buffers each partition's records per window
    slot up to ``capacity`` and **drops** the overflow (counted in
    ``capacity_dropped``).  The static floor: one micro-batch can stage
    ``ceil(batch_records / n_workers)`` rows into a single partition
    (every key hashing together), and a window spanning several batches
    accumulates further — capacity must at least clear the single-batch
    floor."""
    for st in _record_stages(built):
        if st.mode != "group" or st.window.is_session:
            continue
        floor = math.ceil(built.batch_records / built.n_workers)
        if st.capacity < floor:
            out.append(Diagnostic(
                "PL003", WARNING,
                f"group capacity={st.capacity} is below the "
                f"{floor} records one micro-batch can stage into a "
                f"single partition (batch_records={built.batch_records} "
                f"/ n_workers={built.n_workers}); a skewed batch "
                f"overflows the buffer (dropped, counted in "
                f"capacity_dropped) — size capacity for window span × "
                f"per-partition rate", loc=f"stage {st.index}"))


def _check_watermarks(built, out: list) -> None:
    """PL004 — watermark monotonicity is wired, not assumed: a stage
    side's watermark is the min over its input channels, so a side with
    no channel pins the stage at -inf forever, and lateness slack on a
    carry-only stage can never admit anything (finalized windows arrive
    in watermark order)."""
    ext: dict[int, set[int]] = {}
    for si, side in built.inputs:
        ext.setdefault(si, set()).add(side)
    in_edges: dict[int, list] = {}
    for e in built.edges:
        in_edges.setdefault(e.dst, []).append(e)
    for st in _record_stages(built):
        fed_sides = set(ext.get(st.index, ()))
        for e in in_edges.get(st.index, ()):
            fed_sides.add(e.dst_side)
        for side in range(len(st.sides)):
            if side not in fed_sides:
                name = st.sides[side].name
                out.append(Diagnostic(
                    "PL004", ERROR,
                    f"side {side} ({name}) has no input channel — no "
                    f"external stream and no in-edge feeds it, so the "
                    f"stage watermark (min over inputs) stays at -inf "
                    f"and no window ever finalizes",
                    loc=f"stage {st.index}"))
        carry_only = st.index not in ext and in_edges.get(st.index)
        if carry_only and st.allowed_lateness > 0:
            out.append(Diagnostic(
                "PL004", WARNING,
                f"allowed_lateness={st.allowed_lateness} on a stage fed "
                f"only through the carry: finalized windows arrive in "
                f"watermark order, so the slack admits nothing and only "
                f"delays finalization", loc=f"stage {st.index}"))
        if st.is_join and len(in_edges.get(st.index, ())) == 2:
            sizes = {built.stages[e.src].window.size
                     for e in in_edges[st.index]
                     if built.stages[e.src].window is not None}
            if len(sizes) > 1:
                out.append(Diagnostic(
                    "PL004", INFO,
                    f"join over upstream window sizes {sorted(sizes)}: "
                    f"the min-over-inputs watermark holds windows open "
                    f"until the slower side catches up — size n_slots "
                    f"for the skew", loc=f"stage {st.index}"))


def _check_sink_prefixes(built, out: list,
                         source_prefixes=()) -> None:
    """PL005 — ``collect_outputs`` and restore scans are prefix
    *listings*, so overlap (not just equality) is the collision
    condition; the build-time distinctness check only catches exact
    duplicates.  Also rejected: sinks under a source log (the pipeline
    would ingest its own output on replay) and sinks under the reserved
    checkpoint namespace."""
    prefixes = built.output_prefixes()
    for i, a in enumerate(prefixes):
        for b in prefixes[i + 1:]:
            if a.startswith(b) or b.startswith(a):
                out.append(Diagnostic(
                    "PL005", ERROR,
                    f"sink prefixes {a!r} and {b!r} overlap — a prefix "
                    f"listing of one would see the other's windows",
                    loc="program"))
    srcs = {sp.source.prefix for st in built.stages for sp in st.sides
            if sp.source.kind == "log" and sp.source.prefix}
    srcs.update(p for p in source_prefixes if p)
    for pfx in prefixes:
        for src in sorted(srcs):
            s_norm = src.rstrip("/") + "/"
            if pfx.startswith(s_norm) or s_norm.startswith(pfx):
                out.append(Diagnostic(
                    "PL005", ERROR,
                    f"sink prefix {pfx!r} overlaps source log prefix "
                    f"{s_norm!r}: the job would ingest its own output "
                    f"on replay", loc="program"))
        for reserved in RESERVED_PREFIXES:
            if pfx.startswith(reserved) or reserved.startswith(pfx):
                out.append(Diagnostic(
                    "PL005", ERROR,
                    f"sink prefix {pfx!r} falls under the reserved "
                    f"{reserved!r} namespace — the carry checkpoint "
                    f"lives at jobs/<job_id>/stream/carry on the same "
                    f"store, so restore scans would list it as a "
                    f"persisted window", loc="program"))


def _check_donation(built, options, out: list) -> None:
    """PL006 — donation hazards are invisible at runtime: ``jit=False``
    skips donation *silently* (the perf knob does nothing), and a join's
    two side plans donate one shared carry — the previous buffer is dead
    the moment either side folds."""
    if options is None or not getattr(options, "donate_carry", False):
        return
    if not getattr(built, "jit", True):
        out.append(Diagnostic(
            "PL006", WARNING,
            "donate_carry=True under a jit=False build: donation is "
            "silently unavailable (an un-jitted body cannot alias "
            "buffers), so the option buys nothing — build with jit=True "
            "or drop the flag", loc="program"))
    for st in built.stages:
        if st.is_join:
            out.append(Diagnostic(
                "PL006", INFO,
                f"join stage {st.index}: both side plans donate one "
                f"shared carry — every fold invalidates the previous "
                f"buffer, so a driver must rebind the carry before the "
                f"sibling side folds (the built-in coordinator does; "
                f"hand-rolled compiled.step drivers must too)",
                loc=f"stage {st.index}"))


def check_plan(built, options=None, *, source_prefixes=()) -> list:
    """Run every planlint rule over a lowered program.  ``options`` (a
    ``RunOptions``) enables the donation checks; ``source_prefixes`` adds
    run-time source bindings (e.g. a submit's ``source_prefix=``) to the
    PL005 overlap set.  Returns ``Diagnostic`` records — empty means
    clean."""
    out: list = []
    _check_ring_slots(built, out)
    _check_hash_collisions(built, out)
    _check_group_capacity(built, out)
    _check_watermarks(built, out)
    _check_sink_prefixes(built, out, source_prefixes)
    _check_donation(built, options, out)
    return out


def _describe_stage(built, st) -> str:
    w = st.window
    if w is None:
        shape = "array"
    elif w.is_session:
        shape = f"session(gap={w.gap})"
    elif w.slide:
        shape = f"sliding({w.size}/{w.slide})"
    else:
        shape = f"tumbling({w.size})"
    need = ""
    if w is not None and not w.is_session:
        need = (f" (min "
                f"{min_slots_required(w.size, w.slide, st.allowed_lateness)})")
    sides = "+".join(sp.name for sp in st.sides)
    sink = ""
    if st.index in built.final_stages:
        sink = f" → sink {built.stage_prefix(st.index)!r}"
    return (f"stage {st.index} [{sides}]: {shape} mode={st.mode} "
            f"buckets={st.num_buckets} slots={st.n_slots}{need} "
            f"lateness={st.allowed_lateness}{sink}")


def explain_plan(built, options=None, *, source_prefixes=()) -> str:
    """Human-readable program summary + the full diagnostic report (all
    levels, info included) — ``BuiltPipeline.explain()``."""
    lines = [f"BuiltPipeline job_id={built.job_id} "
             f"key_space={built.key_space} n_workers={built.n_workers} "
             f"batch_records={built.batch_records} backend={built.backend}"]
    for st in built.stages:
        lines.append("  " + _describe_stage(built, st))
    for e in built.edges:
        transport = "device" if e.device else "host"
        eager = " eager" if e.eager else ""
        lines.append(f"  edge {e.src}→{e.dst} side={e.dst_side} "
                     f"[{transport}{eager}]")
    diags = check_plan(built, options, source_prefixes=source_prefixes)
    if not diags:
        lines.append("planlint: clean")
    else:
        lines.append("planlint:")
        lines.extend("  " + d.format() for d in diags)
    return "\n".join(lines)


def _load_module(path):
    import importlib.util
    import pathlib
    p = pathlib.Path(path)
    name = f"_planlint_{p.stem}"
    spec = importlib.util.spec_from_file_location(name, p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def main(argv=None) -> int:
    """``python -m repro.analysis.planlint <files-or-dirs>`` — build every
    example module's pipelines (the ``build_pipelines()`` convention) and
    check them; error-level findings fail the run (the CI analysis
    gate)."""
    import argparse
    import pathlib

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.planlint",
        description="planlint over example pipeline modules")
    ap.add_argument("paths", nargs="*", default=["examples"],
                    help="modules (or directories of modules) exposing "
                         "build_pipelines() -> {name: BuiltPipeline}")
    args = ap.parse_args(argv)
    files: list = []
    for raw in args.paths:
        p = pathlib.Path(raw)
        if p.is_dir():
            files.extend(sorted(p.glob("*.py")))
        else:
            files.append(p)
    failed = 0
    checked = 0
    for f in files:
        mod = _load_module(f)
        build = getattr(mod, "build_pipelines", None)
        if build is None:
            print(f"{f}: skipped (no build_pipelines())")
            continue
        programs = build()
        if not isinstance(programs, dict):
            programs = {getattr(p, "job_id", str(i)): p
                        for i, p in enumerate(programs)}
        for name, prog in programs.items():
            diags = check_plan(prog)
            errs = [d for d in diags if d.level == ERROR]
            warns = [d for d in diags if d.level == WARNING]
            checked += 1
            status = "clean" if not (errs or warns) else \
                f"{len(errs)} error(s), {len(warns)} warning(s)"
            print(f"{f}:{name}: {status}")
            for d in errs + warns:
                print(f"  {d.format()}")
            failed += len(errs)
    print(f"planlint: {checked} program(s) checked, {failed} error(s)")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
