"""reprolint — repo-invariant lint over the tree, on stdlib ``ast``.

The container ships no ruff plugin toolchain, so the invariants that keep
this repo correct-by-construction are enforced by a small custom linter:
the shard_map version shim must stay behind one chokepoint, the PR 6
three-lane scheduler's byte-identity contract must hold (no host syncs in
hot lanes, no shared-state mutation off its declared lane), SPMD bodies
that get traced/``vmap``'d/``shard_map``'d must stay pure, and a donated
buffer must never be read after the donating call.

Rules (stable ids — tests pin them; all findings are error-level):

======  ====================================================================
RL101   ``jax.experimental.shard_map`` / ``jax.shard_map`` imported or
        referenced outside ``engine/compile.py`` (all callers go through
        ``make_shard_map`` — the version shim has one home)
RL102   host-sync call inside an ``@lane("driver")`` / ``@lane("prefetch")``
        function: ``jax.device_get``, ``np.asarray``, ``.block_until_ready``,
        ``.item()``, or ``int()``/``float()`` over a name in the module's
        ``LANE_DEVICE_STATE`` set — each stalls the async dispatch pipeline
        per call instead of per barrier
RL103   mutation of an attribute declared in the module's ``LANE_SHARED``
        table from a lane outside its allowed set (assignment, augmented
        assignment, or any method call through the attribute) — the static
        form of the scheduler's byte-identity invariant
RL104   impurity in an SPMD body file (``engine/stages.py``, ``kernels/``):
        ``print``, ``global``/``nonlocal``, host-sync calls, or branching
        (``if``/``while``) on a traced reduction (``.any()``/``.all()``/
        ``jnp.any``/``jnp.all``)
RL105   donated buffer read after the donating call: a call passing
        ``donate=<truthy>`` must have its result assigned back over at
        least one of the argument expressions it donated (``x, s = f(x,
        donate=flag)``); anything else leaves a dead buffer reachable
RL106   exported name without a docstring: a class/function defined in
        this module and listed in its ``__all__`` must carry a docstring
        — the public API surface is the documented surface (re-exports
        are checked where they are defined, not where they are listed)
======  ====================================================================

Suppressions: trailing ``# reprolint: disable=RL102`` (comma-separated
ids, or bare ``disable`` for all rules) silences that line; ``# reprolint:
disable-file=RL104`` anywhere in the file silences the rule file-wide.
A checked-in allowlist (``.reprolint-allow``: ``glob::RULE`` lines,
``*`` wildcards both sides) records intentional exceptions so the CLI
stays blocking.
"""

from __future__ import annotations

import ast
import fnmatch
import pathlib
import re

from .diagnostics import ERROR, Diagnostic

RULES = {
    "RL101": "shard_map import/reference outside engine/compile.py",
    "RL102": "host-sync call in a driver/prefetch lane function",
    "RL103": "LANE_SHARED attribute mutated from an undeclared lane",
    "RL104": "impure construct in an SPMD body file",
    "RL105": "donated buffer not rebound by the donating call's result",
    "RL106": "name exported in __all__ has no docstring",
}

#: lanes where host syncs are part of the design (RL102 does not apply)
SYNC_OK_LANES = frozenset({"barrier"})

_SHARD_MAP_CHAINS = ("jax.shard_map", "jax.experimental.shard_map")

_DISABLE_RE = re.compile(
    r"#\s*reprolint:\s*disable(?P<scope>-file)?"
    r"(?:\s*=\s*(?P<rules>[A-Za-z0-9_,\s]+))?")

__all__ = ["RULES", "lint_source", "lint_file", "lint_paths",
           "load_allowlist", "iter_python_files"]


def _chain(node) -> str | None:
    """Dotted name for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _lane_of(fn) -> str | None:
    for dec in fn.decorator_list:
        if not (isinstance(dec, ast.Call) and dec.args):
            continue
        name = None
        if isinstance(dec.func, ast.Name):
            name = dec.func.id
        elif isinstance(dec.func, ast.Attribute):
            name = dec.func.attr
        if name == "lane" and isinstance(dec.args[0], ast.Constant):
            return dec.args[0].value
    return None


def _literal_table(tree, name):
    """Module-level ``NAME = <literal>`` (the declared-state convention:
    the tables must be literals so the linter can read them)."""
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == name):
            try:
                return ast.literal_eval(node.value)
            except ValueError:
                return None
    return None


def _flat_targets(node):
    out = []
    stack = (list(node.targets) if isinstance(node, ast.Assign)
             else [node.target])
    while stack:
        t = stack.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            stack.extend(t.elts)
        else:
            out.append(t)
    return out


def _names_in(node) -> set:
    """Every bare name and attribute name referenced under ``node``."""
    out = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


def _is_sync_call(node: ast.Call, device_state) -> str | None:
    """Classify a host-sync call; returns a short description or None."""
    chain = _chain(node.func)
    if chain == "jax.device_get":
        return "jax.device_get"
    if chain in ("np.asarray", "numpy.asarray"):
        return chain
    if isinstance(node.func, ast.Attribute):
        if node.func.attr == "block_until_ready":
            return ".block_until_ready()"
        if node.func.attr == "item":
            return ".item()"
    if (isinstance(node.func, ast.Name) and node.func.id in ("int", "float")
            and node.args):
        touched = set()
        for arg in node.args:
            touched |= _names_in(arg)
        hit = touched & set(device_state)
        if hit:
            return f"{node.func.id}() over device state {sorted(hit)}"
    return None


def _truthy_donate(node: ast.Call):
    """The ``donate=`` keyword value if it could be truthy, else None."""
    for kw in node.keywords:
        if kw.arg == "donate":
            v = kw.value
            if isinstance(v, ast.Constant) and v.value in (False, None):
                return None
            return v
    return None


_CTX_RE = re.compile(r"ctx=(?:Load|Store|Del)\(\)")


def _expr_key(node) -> str:
    """Structural identity for target-vs-argument matching (RL105),
    ignoring the Load/Store context that differs by position."""
    return _CTX_RE.sub("ctx=_", ast.dump(node))


class _Suppressions:
    def __init__(self, src: str):
        self.lines: dict = {}
        self.file_rules: set = set()
        self.file_all = False
        for i, line in enumerate(src.splitlines(), start=1):
            m = _DISABLE_RE.search(line)
            if not m:
                continue
            rules = m.group("rules")
            ids = ({r.strip().upper() for r in rules.split(",") if r.strip()}
                   if rules else None)
            if m.group("scope"):
                if ids is None:
                    self.file_all = True
                else:
                    self.file_rules |= ids
            else:
                self.lines[i] = ids      # None means "all rules"

    def active(self, rule: str, line: int) -> bool:
        if self.file_all or rule in self.file_rules:
            return True
        if line in self.lines:
            ids = self.lines[line]
            return ids is None or rule in ids
        return False


def lint_source(src: str, path: str) -> list:
    """Lint one file's source; returns non-suppressed error Diagnostics."""
    norm = path.replace("\\", "/")
    tree = ast.parse(src, filename=path)
    supp = _Suppressions(src)
    findings: list = []

    def emit(rule, message, node):
        line = getattr(node, "lineno", 0)
        if not supp.active(rule, line):
            findings.append(Diagnostic(rule, ERROR, message,
                                       path=path, line=line))

    is_compile = norm.endswith("engine/compile.py")
    is_spmd = (norm.endswith("engine/stages.py")
               or "kernels" in norm.split("/")[:-1])
    lane_shared = _literal_table(tree, "LANE_SHARED") or {}
    device_state = _literal_table(tree, "LANE_DEVICE_STATE") or set()

    # ---- RL101: shard_map confinement -------------------------------
    if not is_compile:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.startswith("jax.experimental.shard_map"):
                        emit("RL101",
                             f"import {alias.name}: shard_map is "
                             f"version-gated behind "
                             f"engine.compile.make_shard_map", node)
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod.startswith("jax.experimental.shard_map") or (
                        mod in ("jax", "jax.experimental")
                        and any(a.name == "shard_map"
                                for a in node.names)):
                    emit("RL101",
                         f"from {mod} import shard_map: route through "
                         f"engine.compile.make_shard_map", node)
            elif isinstance(node, ast.Attribute):
                if _chain(node) in _SHARD_MAP_CHAINS:
                    emit("RL101",
                         f"{_chain(node)} referenced directly: route "
                         f"through engine.compile.make_shard_map", node)

    # ---- RL105: donate rebinding ------------------------------------
    rebound: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            call = node.value
            if _truthy_donate(call) is None:
                continue
            targets = {_expr_key(t) for t in _flat_targets(node)}
            args = {_expr_key(a) for a in call.args}
            if targets & args:
                rebound.add(id(call))
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _truthy_donate(node) is not None:
            if id(node) not in rebound:
                emit("RL105",
                     "call donates a buffer (donate=...) but its result "
                     "is not assigned back over any donated argument — "
                     "the stale buffer stays reachable after donation",
                     node)

    # ---- RL106: exported names are documented ------------------------
    exported = _literal_table(tree, "__all__") or ()
    if exported:
        defs = {n.name: n for n in tree.body
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef))}
        for name in exported:
            node = defs.get(name)
            if node is not None and ast.get_docstring(node) is None:
                emit("RL106",
                     f"{name!r} is exported in __all__ but carries no "
                     f"docstring — the public surface is the documented "
                     f"surface", node)

    # ---- lane + SPMD walk -------------------------------------------
    def check_stmt(node, lane):
        if isinstance(node, ast.Call):
            sync = _is_sync_call(node, device_state)
            if sync is not None:
                if lane is not None and lane not in SYNC_OK_LANES:
                    emit("RL102",
                         f"{sync} inside an @lane({lane!r}) function: "
                         f"host syncs belong to the barrier lane "
                         f"(stalls the async dispatch pipeline)", node)
                if is_spmd:
                    emit("RL104",
                         f"{sync} in an SPMD body file: traced bodies "
                         f"must not force host syncs", node)
            if is_spmd and isinstance(node.func, ast.Name) \
                    and node.func.id == "print":
                emit("RL104", "print() in an SPMD body file: traced "
                              "bodies must be side-effect free", node)
            if lane is not None and lane_shared \
                    and isinstance(node.func, ast.Attribute):
                for attr_node in ast.walk(node.func.value):
                    if isinstance(attr_node, ast.Attribute) \
                            and attr_node.attr in lane_shared:
                        allowed = tuple(lane_shared[attr_node.attr])
                        if lane not in allowed:
                            emit("RL103",
                                 f"method call through shared attribute "
                                 f".{attr_node.attr} from lane {lane!r}; "
                                 f"LANE_SHARED allows {allowed}", node)
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            if lane is not None and lane_shared:
                for t in _flat_targets(node):
                    for attr_node in ast.walk(t):
                        if isinstance(attr_node, ast.Attribute) \
                                and attr_node.attr in lane_shared:
                            allowed = tuple(lane_shared[attr_node.attr])
                            if lane not in allowed:
                                emit("RL103",
                                     f"assignment to shared attribute "
                                     f".{attr_node.attr} from lane "
                                     f"{lane!r}; LANE_SHARED allows "
                                     f"{allowed}", node)
        elif isinstance(node, (ast.Global, ast.Nonlocal)):
            if is_spmd:
                kw = "global" if isinstance(node, ast.Global) else "nonlocal"
                emit("RL104", f"{kw} in an SPMD body file: traced bodies "
                              f"must be side-effect free", node)
        elif isinstance(node, (ast.If, ast.While)):
            if is_spmd:
                for sub in ast.walk(node.test):
                    if isinstance(sub, ast.Call):
                        c = _chain(sub.func)
                        traced = (c in ("jnp.any", "jnp.all")
                                  or (isinstance(sub.func, ast.Attribute)
                                      and sub.func.attr in ("any", "all")))
                        if traced:
                            emit("RL104",
                                 f"Python branch on a traced reduction "
                                 f"({c or '.' + sub.func.attr + '()'}): "
                                 f"use lax.cond / jnp.where", node)

    def walk_scope(node, lane):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                walk_scope(child, _lane_of(child) or lane)
            else:
                check_stmt(child, lane)
                walk_scope(child, lane)

    walk_scope(tree, None)
    return findings


def lint_file(path) -> list:
    """Lint one file from disk; unreadable or unparsable files become a
    single ``RL000`` diagnostic instead of raising."""
    p = pathlib.Path(path)
    try:
        src = p.read_text()
    except (OSError, UnicodeDecodeError) as exc:
        return [Diagnostic("RL000", ERROR, f"unreadable: {exc}",
                           path=str(p), line=0)]
    try:
        return lint_source(src, str(p))
    except SyntaxError as exc:
        return [Diagnostic("RL000", ERROR, f"syntax error: {exc.msg}",
                           path=str(p), line=exc.lineno or 0)]


def load_allowlist(path):
    """``glob::RULE`` lines (``*`` rule matches everything); ``#`` comments."""
    entries = []
    p = pathlib.Path(path)
    if not p.exists():
        return entries
    for raw in p.read_text().splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        glob, _, rule = line.partition("::")
        entries.append((glob.strip(), (rule.strip() or "*")))
    return entries


def _allowed(diag, allowlist) -> bool:
    norm = (diag.path or "").replace("\\", "/")
    for glob, rule in allowlist:
        if rule not in ("*", diag.rule_id):
            continue
        if fnmatch.fnmatch(norm, glob):
            return True
    return False


def iter_python_files(paths):
    """Yield every ``.py`` file under ``paths`` (files pass through,
    directories recurse, ``__pycache__`` is skipped), sorted per tree."""
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" not in f.parts:
                    yield f
        elif p.suffix == ".py":
            yield p


def lint_paths(paths, allowlist=()) -> list:
    """Lint files/trees; allowlisted findings are dropped."""
    findings: list = []
    for f in iter_python_files(paths):
        for d in lint_file(f):
            if not _allowed(d, allowlist):
                findings.append(d)
    return findings
