"""Scheduler-lane annotations for the pipelined streaming coordinator.

The PR 6 drive loop runs three lanes with a byte-identity contract:

* ``prefetch`` — the background prepare thread.  May touch only the
  immutable program (source reads, fused map chains); key-id assignment
  and every piece of mutable stage state stay off-limits, or output bytes
  would depend on thread timing.
* ``driver`` — the main thread's fold path.  Owns key tables, ring
  admission, carries.  Must not force device→host syncs mid-batch
  (``np.asarray`` on a step result, ``.block_until_ready``), or the
  async dispatch pipeline stalls per fold instead of per barrier.
* ``barrier`` — the micro-batch boundary: deferred stats drains, batched
  sink flushes, checkpoints.  The only lane where host syncs are part of
  the design.

``@lane(name)`` is a **no-op at runtime** — it tags the function (and
sets ``__lane__`` for introspection) so ``repro.analysis.reprolint`` can
enforce the contract statically: host-sync calls inside ``driver``/
``prefetch`` functions are RL102 errors, and mutations of attributes
declared in the module's ``LANE_SHARED`` table from a lane outside the
attribute's allowed set are RL103 errors.  The convention this replaces
was a docstring.
"""

from __future__ import annotations

from typing import Callable, TypeVar

LANES = ("driver", "prefetch", "barrier")

_F = TypeVar("_F", bound=Callable)

__all__ = ["LANES", "lane"]


def lane(name: str) -> Callable[[_F], _F]:
    """Tag a coordinator method with the scheduler lane it runs on."""
    if name not in LANES:
        raise ValueError(f"unknown lane {name!r}; lanes are {LANES}")

    def mark(fn: _F) -> _F:
        fn.__lane__ = name
        return fn

    return mark
