"""Structured diagnostics — the shared currency of planlint and reprolint.

Both analyzers report ``Diagnostic`` records instead of raising on first
sight: a plan check surfaces *every* finding in one pass (the paper's
admission story — reject a bad job before it holds pool replicas, with a
message naming each problem), and the AST lint aggregates findings across
a whole tree for one CLI report.

Severity levels:

* ``error`` — the program must misbehave (ring overflow, colliding sinks,
  a mutation off its declared lane).  ``JobServer.submit`` rejects on
  these; the lint CLI exits non-zero.
* ``warning`` — probabilistically or configuration-dependently wrong
  (hash-collision odds above threshold, a group buffer a skewed batch can
  overflow).  ``Pipeline.build`` surfaces these as ``PlanLintWarning``.
* ``info`` — advisory context shown by ``BuiltPipeline.explain()`` only.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass

from ..pipeline.graph import PipelineError

ERROR = "error"
WARNING = "warning"
INFO = "info"

_LEVEL_RANK = {INFO: 0, WARNING: 1, ERROR: 2}


@dataclass(frozen=True)
class Diagnostic:
    """One analyzer finding: a stable rule id, a severity, a message, and
    where — a plan location (``stage 1``, ``edge 0→1``, ``program``) for
    planlint, a ``path``/``line`` pair for reprolint."""

    rule_id: str
    level: str                      # "error" | "warning" | "info"
    message: str
    loc: str = "program"            # planlint: stage/edge/program location
    path: str | None = None         # reprolint: offending file
    line: int = 0                   # reprolint: 1-based line in ``path``

    def format(self) -> str:
        where = f"{self.path}:{self.line}" if self.path else self.loc
        return f"{where}: {self.rule_id} {self.level}: {self.message}"


class PlanLintWarning(UserWarning):
    """A build-time planlint finding.  ``Pipeline.build`` warns (the graph
    may be headed somewhere that fixes it — a test rig, a doc snippet);
    admission (``JobServer.submit``) rejects error-level findings."""


class PlanRejected(PipelineError):
    """A program failed planlint at admission — the plan-level twin of
    ``core.storage.QuotaExceeded``: raised before the job registers, so
    only the offending tenant's submit fails."""

    def __init__(self, diagnostics) -> None:
        self.diagnostics = tuple(diagnostics)
        detail = "; ".join(d.format() for d in self.diagnostics)
        super().__init__(
            f"planlint rejected the program ({len(self.diagnostics)} "
            f"error{'s' if len(self.diagnostics) != 1 else ''}): {detail}")


def errors(diagnostics) -> list[Diagnostic]:
    return [d for d in diagnostics if d.level == ERROR]


def max_level(diagnostics) -> str | None:
    """The most severe level present, or None for an empty report."""
    if not diagnostics:
        return None
    return max(diagnostics, key=lambda d: _LEVEL_RANK[d.level]).level


def format_report(diagnostics, *, min_level: str = INFO) -> str:
    """Human-readable multi-line report, most severe first."""
    floor = _LEVEL_RANK[min_level]
    rows = sorted((d for d in diagnostics
                   if _LEVEL_RANK[d.level] >= floor),
                  key=lambda d: -_LEVEL_RANK[d.level])
    if not rows:
        return "no findings"
    return "\n".join(d.format() for d in rows)


def warn_diagnostics(diagnostics, *, stacklevel: int = 3) -> None:
    """Surface warning- and error-level findings as ``PlanLintWarning``s —
    the build-time integration (builds stay usable; admission rejects)."""
    for d in diagnostics:
        if _LEVEL_RANK[d.level] >= _LEVEL_RANK[WARNING]:
            warnings.warn(d.format(), PlanLintWarning, stacklevel=stacklevel)
