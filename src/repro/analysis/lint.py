"""``python -m repro.analysis.lint`` — run reprolint over files/trees.

Deliberately jax-free: the CLI imports only stdlib + the analysis
package, so the CI analysis job (and a pre-commit hook) pays no device
runtime startup.  Exit status 1 iff any non-allowlisted finding remains.

Usage::

    python -m repro.analysis.lint src tests benchmarks examples
    python -m repro.analysis.lint --allowlist .reprolint-allow src
    python -m repro.analysis.lint --list-rules
"""

from __future__ import annotations

import argparse
import pathlib

from .reprolint import RULES, iter_python_files, lint_paths, load_allowlist

DEFAULT_ALLOWLIST = ".reprolint-allow"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="reprolint: repo-invariant AST lint")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--allowlist", default=None,
                    help=f"allowlist file of glob::RULE lines "
                         f"(default: {DEFAULT_ALLOWLIST} if present)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule_id in sorted(RULES):
            print(f"{rule_id}  {RULES[rule_id]}")
        return 0

    allow_path = args.allowlist
    if allow_path is None and pathlib.Path(DEFAULT_ALLOWLIST).exists():
        allow_path = DEFAULT_ALLOWLIST
    allowlist = load_allowlist(allow_path) if allow_path else []

    findings = lint_paths(args.paths, allowlist)
    n_files = sum(1 for _ in iter_python_files(args.paths))
    for d in findings:
        print(d.format())
    print(f"reprolint: {n_files} file(s), {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
