# Pallas TPU kernels for the compute hot-spots the paper optimizes.
#
# The paper's measured bottleneck is the Mapper's buffer sort + combiner
# (Figs. 7-8) -> kernels/hash_combine re-expresses it as one-hot MXU matmul
# bucket reduction (see DESIGN.md section 4.1).  kernels/fused_fold
# generalizes it to the streaming engine's whole per-batch fold — hash,
# window fan-out, and (slot, bucket) scatter-accumulate in one kernel, the
# `backend="pallas"` substrate of ExecutionPlan.compile.  flash_attention
# and mamba_scan cover the serving/training hot-spots of the assigned
# architectures.
#
# Each kernel package: <name>/kernel.py (pl.pallas_call + explicit BlockSpec
# VMEM tiling), <name>/ops.py (jit'd wrapper with interpret switch),
# <name>/ref.py (pure-jnp oracle).  Validated on CPU via interpret=True;
# compiled for TPU (Mosaic) on real hardware.
