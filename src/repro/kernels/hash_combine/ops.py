"""jit'd public wrapper for the hash_combine kernel.

``combine(..., use_pallas=False)`` routes to the XLA segment-sum reference —
the default on this CPU container and inside the dry-run (so cost_analysis
reflects the XLA graph); ``use_pallas=True`` targets the TPU kernel
(``interpret=True`` executes the kernel body on CPU for validation).

The signature matches the ``combine_fn`` hook of
``repro.core.shuffle.shuffle_aggregate``.
"""

from __future__ import annotations

import jax

from .kernel import hash_combine as hash_combine_pallas
from .ref import hash_combine_ref


def combine(keys: jax.Array, values: jax.Array, num_buckets: int,
            valid: jax.Array | None = None, *, use_pallas: bool = False,
            interpret: bool = True, block_n: int = 512) -> jax.Array:
    if use_pallas:
        return hash_combine_pallas(keys, values, valid,
                                   num_buckets=num_buckets, block_n=block_n,
                                   interpret=interpret)
    return hash_combine_ref(keys, values, num_buckets, valid)


def make_combine_fn(use_pallas: bool = False, interpret: bool = True,
                    block_n: int = 512):
    """Factory returning a ``combine_fn(keys, values, num_buckets, valid)``
    for ``shuffle_aggregate`` / ``core.mapreduce``."""

    def fn(keys, values, num_buckets, valid=None):
        return combine(keys, values, num_buckets, valid,
                       use_pallas=use_pallas, interpret=interpret,
                       block_n=block_n)

    return fn
