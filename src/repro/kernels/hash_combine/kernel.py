"""hash_combine — the Mapper's combiner as a Pallas TPU kernel.

Paper (§III-A.3, Figs. 7-8): the Mapper's dominant cost is sorting each output
buffer by key and running the combiner before spilling.  A comparison sort is
the right tool on CPU containers; on TPU it serializes on the VPU while the
MXU idles.  DESIGN.md §4.1: re-express sort+combine as *bucket accumulation
via one-hot matmul* —

    out[b, d] = Σ_n  [keys[n] == b] · values[n, d]
             ⇔ one_hot(keys)ᵀ @ values          (a (B×N)·(N×D) matmul)

which runs at MXU rate, needs no data-dependent control flow, and emits the
per-bucket partials already grouped ("born sorted") — the property the paper's
sorted spills exist to provide.

Tiling: grid over record tiles of ``block_n``; each step builds the
(block_n × num_buckets) one-hot in VMEM via broadcasted_iota comparison and
accumulates ``one_hotᵀ @ values`` into the (num_buckets × D) output block,
which stays resident in VMEM across grid steps (same block for every i —
Pallas keeps it and the accumulation is sequential on TPU).

VMEM budget per step: block_n·num_buckets (one-hot) + block_n·D (values)
+ num_buckets·D (accumulator), all fp32.  Defaults (block_n=512, B≤4096,
D≤256) stay well under 16 MB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hash_combine_kernel(keys_ref, values_ref, valid_ref, out_ref, *,
                         num_buckets: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]          # (block_n,)
    vals = values_ref[...]        # (block_n, D)
    valid = valid_ref[...]        # (block_n,)

    # one-hot via iota comparison — MXU-friendly, no gather/scatter
    buckets = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[0], num_buckets), 1)
    onehot = (keys[:, None] == buckets).astype(vals.dtype)
    onehot = onehot * valid[:, None].astype(vals.dtype)

    # (B, block_n) @ (block_n, D) on the MXU, accumulated in fp32
    out_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("num_buckets", "block_n", "interpret"))
def hash_combine(keys: jax.Array, values: jax.Array,
                 valid: jax.Array | None = None, *, num_buckets: int,
                 block_n: int = 512, interpret: bool = False) -> jax.Array:
    """Bucket-accumulate ``values`` by ``keys`` → (num_buckets, D) sums.

    keys : (N,) int32 in [0, num_buckets); values : (N,) or (N, D) float;
    valid: (N,) bool (None = all valid).  N is padded to block_n internally.
    """
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    n, d = values.shape
    if valid is None:
        valid = jnp.ones((n,), dtype=jnp.bool_)

    n_pad = (-n) % block_n
    if n_pad:
        keys = jnp.pad(keys, (0, n_pad))
        values = jnp.pad(values, ((0, n_pad), (0, 0)))
        valid = jnp.pad(valid, (0, n_pad))
    n_total = n + n_pad
    grid = (n_total // block_n,)

    out = pl.pallas_call(
        functools.partial(_hash_combine_kernel, num_buckets=num_buckets),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_buckets, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_buckets, d), values.dtype),
        interpret=interpret,
    )(keys, values, valid)
    return out[:, 0] if squeeze else out
