"""Pure-jnp oracle for the hash-combine (Mapper combiner) kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def hash_combine_ref(keys: jax.Array, values: jax.Array, num_buckets: int,
                     valid: jax.Array | None = None) -> jax.Array:
    """Dense bucket accumulation: ``out[b] = sum(values[keys == b])``.

    keys   : (N,) int32 in [0, num_buckets)
    values : (N,) or (N, D) float
    valid  : (N,) bool, optional
    returns: (num_buckets,) or (num_buckets, D), dtype of values
    """
    if valid is not None:
        vmask = valid.reshape((-1,) + (1,) * (values.ndim - 1))
        values = jnp.where(vmask, values, jnp.zeros_like(values))
        keys = jnp.where(valid, keys, 0)
    return jax.ops.segment_sum(values, keys.astype(jnp.int32),
                               num_segments=num_buckets)
