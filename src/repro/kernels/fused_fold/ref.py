"""XLA reference for the fused streaming fold — the bit-parity oracle.

Pure jnp re-statement of what one worker's slice of the engine's streaming
aggregate step computes per micro-batch, without collectives: decode the
wire rows, hash raw keys into buckets (murmur3 finalizer, bit-identical to
``engine.stages.device_hash``), fan each record out to its 1..fanout
overlapping window slots, mask + count pairs below the watermark bound,
and scatter-accumulate ``[value, 1]`` pairs into the flattened
``(n_slots * carry_buckets, channels)`` carry.  The Pallas kernel in
``kernel.py`` must match this byte-for-byte (integer-valued float32 sums
are order-independent, so sequential-tile vs segment-sum accumulation
cannot drift).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: streaming wire widths (mirrors engine.plan HOST_FANOUT_ROW / DEVICE_FANOUT_ROW)
HOST_ROW = 4    # [window_slot, key, value, valid]
DEVICE_ROW = 5  # [last_window_index, n_windows, key, value, valid]

#: fold kinds the fused kernel accumulates (count folds as sum-of-ones;
#: mean is emission-side sum/count and needs no kind of its own)
FOLD_KINDS = ("sum", "count", "min", "max")


def murmur_bucket(keys: jax.Array, num_buckets: int,
                  hashed: bool) -> jax.Array:
    """Raw int32 keys → bucket ids; bit-exact mirror of
    ``stages.bucketize`` (murmur3 finalizer, duplicated here so the kernel
    package stays free of engine imports — parity is test-enforced)."""
    keys = keys.astype(jnp.int32)
    if not hashed:
        return keys
    h = keys.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(num_buckets)).astype(jnp.int32)


def _decode(rows, min_window, *, fanout, n_slots, num_buckets,
            carry_buckets, hashed, host_wire):
    """Wire rows → flattened (slot, bucket, value, live) pairs + counters.

    Device wire: each record replicates ``fanout`` ways; copy j covers
    window ``last - j``, is live when ``j < n_windows`` and the window is
    still admissible (``>= min_window``); late pairs are counted, not
    folded.  Host wire: the host already expanded records (fan-out 1) and
    never ships late rows, so ``live == valid`` and late is 0.
    """
    if host_wire:
        slots = rows[:, 0].astype(jnp.int32)[:, None]
        bucket = murmur_bucket(rows[:, 1], num_buckets, hashed)[:, None]
        vals = rows[:, 2][:, None]
        live = (rows[:, 3] > 0)[:, None]
        late = jnp.zeros((), jnp.int32)
    else:
        last = rows[:, 0].astype(jnp.int32)
        n_windows = rows[:, 1].astype(jnp.int32)
        bucket = murmur_bucket(rows[:, 2], num_buckets, hashed)[:, None]
        vals = rows[:, 3][:, None]
        valid = rows[:, 4] > 0
        j = jax.lax.broadcasted_iota(jnp.int32, (rows.shape[0], fanout), 1)
        widx = last[:, None] - j
        covers = valid[:, None] & (j < n_windows[:, None])
        live = covers & (widx >= min_window)
        late = jnp.sum((covers & (widx < min_window)).astype(jnp.int32))
        slots = jnp.mod(widx, n_slots)
    # flatten (slot, bucket) over the carry's bucket width — wider than the
    # plan's own key space when several plans share one carry
    flat = slots * jnp.int32(carry_buckets) + bucket   # broadcast (n, F)
    return flat, jnp.broadcast_to(vals, flat.shape), live, late


def fused_streaming_fold_ref(rows, carry, min_window=None, *, fanout,
                             n_slots, num_buckets, carry_buckets,
                             channel_base=0, hashed=False, host_wire=False,
                             kind="sum"):
    """Oracle fold: ``(carry', stats)`` with stats int32 ``[late, folded,
    0]`` — the single-worker contract of ``CompiledStreamAggregate.step``.

    ``carry`` is the flattened ``(n_slots * carry_buckets, channels)``
    slab.  ``sum``/``count`` accumulate into channels ``[channel_base,
    channel_base + 1]`` (value-or-one, one); ``min``/``max`` keep the
    running extremum in the value channel (empty cells stay 0 — the count
    channel says whether the extremum is populated) and the count in the
    next.
    """
    if kind not in FOLD_KINDS:
        raise ValueError(f"unknown fold kind {kind!r}")
    if min_window is None:
        min_window = -(2 ** 31)
    flat, vals, live, late = _decode(
        rows, jnp.int32(min_window), fanout=fanout, n_slots=n_slots,
        num_buckets=num_buckets, carry_buckets=carry_buckets, hashed=hashed,
        host_wire=host_wire)
    size, channels = carry.shape
    # park dead pairs on an overflow row past the carry
    seg = jnp.where(live, flat, size).reshape(-1)
    vals = vals.reshape(-1)
    ones = live.astype(carry.dtype).reshape(-1)
    folded = jnp.sum(live.astype(jnp.int32))
    ch = jax.lax.broadcasted_iota(jnp.int32, (1, channels), 1)

    if kind in ("sum", "count"):
        v = ones if kind == "count" else jnp.where(live.reshape(-1), vals, 0.0)
        sums = jax.ops.segment_sum(v, seg, num_segments=size + 1)[:size]
        cnts = jax.ops.segment_sum(ones, seg, num_segments=size + 1)[:size]
        add = (jnp.where(ch == channel_base, sums[:, None], 0.0)
               + jnp.where(ch == channel_base + 1, cnts[:, None], 0.0))
        new = carry + add.astype(carry.dtype)
    else:
        neutral = jnp.inf if kind == "min" else -jnp.inf
        masked = jnp.where(live.reshape(-1), vals, neutral)
        if kind == "min":
            ext = jax.ops.segment_min(masked, seg, num_segments=size + 1)
        else:
            ext = jax.ops.segment_max(masked, seg, num_segments=size + 1)
        ext = ext[:size]
        cnts = jax.ops.segment_sum(ones, seg, num_segments=size + 1)[:size]
        old_v = carry[:, channel_base]
        old_c = carry[:, channel_base + 1]
        eff = jnp.where(old_c > 0, old_v, neutral)
        comb = jnp.minimum(eff, ext) if kind == "min" \
            else jnp.maximum(eff, ext)
        new_c = old_c + cnts
        new_v = jnp.where(new_c > 0, comb, 0.0)
        new = jnp.where(ch == channel_base, new_v[:, None],
                        jnp.where(ch == channel_base + 1, new_c[:, None],
                                  carry))
    stats = jnp.stack([late, folded, jnp.zeros((), jnp.int32)])
    return new.astype(carry.dtype), stats
