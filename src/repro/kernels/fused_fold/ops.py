"""jit'd public wrapper for the fused streaming-fold kernel.

``fold(..., use_pallas=False)`` routes to the XLA segment-sum reference
(``ref.py``); ``use_pallas=True`` targets the Pallas kernel.  ``interpret``
defaults to auto: compiled lowering on TPU, interpret mode (the kernel body
as jax ops) everywhere else — the switch every kernel caller in the engine
routes through, so one env answers "can this host run Mosaic?" in one
place.

``make_fold_step`` builds the streaming-step callable
``CompiledStreamAggregate`` dispatches to for ``backend="pallas"``: the
plan's static geometry is closed over once, the result is jit'd (with the
carry optionally donated — ``input_output_aliases`` in the kernel turns
donation into a true in-place carry update), and the call signature
matches the lowered XLA step exactly, so the coordinator cannot tell the
backends apart except by speed.
"""

from __future__ import annotations

import jax

from .kernel import fused_streaming_fold
from .ref import fused_streaming_fold_ref


def default_interpret() -> bool:
    """Interpret the kernel body unless a real TPU can compile it."""
    return jax.default_backend() != "tpu"


def fold(rows, carry, min_window=None, *, fanout, n_slots, num_buckets,
         carry_buckets, channel_base=0, hashed=False, host_wire=False,
         kind="sum", use_pallas=True, interpret=None, block_n=256,
         block_s=None):
    if use_pallas:
        if interpret is None:
            interpret = default_interpret()
        return fused_streaming_fold(
            rows, carry, min_window, fanout=fanout, n_slots=n_slots,
            num_buckets=num_buckets, carry_buckets=carry_buckets,
            channel_base=channel_base, hashed=hashed, host_wire=host_wire,
            kind=kind, block_n=block_n, block_s=block_s,
            interpret=interpret)
    return fused_streaming_fold_ref(
        rows, carry, min_window, fanout=fanout, n_slots=n_slots,
        num_buckets=num_buckets, carry_buckets=carry_buckets,
        channel_base=channel_base, hashed=hashed, host_wire=host_wire,
        kind=kind)


def make_fold_step(*, fanout, n_slots, num_buckets, carry_buckets,
                   channel_base=0, hashed=False, host_wire=False,
                   kind="sum", use_pallas=True, interpret=None, block_n=256,
                   block_s=None, donate_argnums=()):
    """Factory for the pallas-backend streaming step.

    Returns ``step(rows, carry, min_window) -> (carry', stats)`` for the
    device wire, or ``step(rows, carry)`` for the host wire — the exact
    signatures ``CompiledStreamAggregate.step`` calls on its lowered fn.
    """
    kw = dict(fanout=fanout, n_slots=n_slots, num_buckets=num_buckets,
              carry_buckets=carry_buckets, channel_base=channel_base,
              hashed=hashed, host_wire=host_wire, kind=kind,
              use_pallas=use_pallas, interpret=interpret, block_n=block_n,
              block_s=block_s)
    if host_wire:
        def step(rows, carry):
            return fold(rows, carry, None, **kw)
    else:
        def step(rows, carry, min_window):
            return fold(rows, carry, min_window, **kw)
    return jax.jit(step, donate_argnums=donate_argnums or ())
