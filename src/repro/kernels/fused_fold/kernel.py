"""fused_fold — the engine's streaming fold as one Pallas TPU kernel.

The streaming hot loop (``engine/plan._stream_agg_device_body``) lowers
today through four XLA ops per micro-batch: ``device_hash``/``bucketize``
→ ``window_fanout`` (broadcast + iota) → ``segment_sum`` over the
flattened (slot, bucket) id space → carry add.  Each materializes its
fanout-expanded intermediates in HBM.  This kernel fuses the chain: rows
stream through VMEM once per record tile, the hash / fan-out / watermark
masking happen in registers, and values scatter-accumulate straight into
the resident carry block — the carry is read from and written to HBM once
per batch instead of once per op.

Generalizes ``kernels/hash_combine`` (one-hot × MXU matmul bucket
accumulation, grid over record tiles, out block resident across steps) in
three directions:

* the id space is (window slot × bucket), flattened over the carry's
  bucket width, with the 1..fanout sliding-window replication and the
  ``min_window`` late-pair masking computed in-kernel from iota
  arithmetic (a late or uncovered pair gets flat id −1, whose one-hot row
  is all zeros — masking is free);
* the accumulator is the streaming *carry*: the output block seeds from
  the carry input at the first record tile (``input_output_aliases``
  makes the update in-place under donation) and the kernel returns the
  folded ``[late, folded, 0]`` counters the watermark books need;
* fold kinds ``sum``/``count`` take the MXU matmul path; ``min``/``max``
  keep a masked running extremum on the VPU (count channel still summed,
  so emptiness stays observable).

Grid: ``(carry tiles, record tiles)`` — record tiles iterate innermost,
so each carry tile stays resident in VMEM while every record tile streams
past it.  VMEM per step: block_n·width rows + m·block_s one-hot +
block_s·channels carry (m = block_n·fanout), fp32; defaults (block_n=256,
fanout ≤ 8, carry tiles ≤ 4096 ids) stay well under 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import FOLD_KINDS, HOST_ROW, DEVICE_ROW

INT32_MIN = -(2 ** 31)


def _bucketize(keys, num_buckets: int, hashed: bool):
    """In-kernel murmur3 finalizer — mirrors ``ref.murmur_bucket``."""
    keys = keys.astype(jnp.int32)
    if not hashed:
        return keys
    h = keys.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(num_buckets)).astype(jnp.int32)


def _fused_fold_kernel(rows_ref, carry_ref, minw_ref, out_ref, stats_ref, *,
                       fanout: int, n_slots: int, num_buckets: int,
                       carry_buckets: int, channel_base: int, hashed: bool,
                       host_wire: bool, kind: str, block_s: int):
    s = pl.program_id(0)            # carry (flat id) tile
    i = pl.program_id(1)            # record tile — innermost, accumulates

    @pl.when(i == 0)
    def _seed():                    # out block = carry block + batch delta
        out_ref[...] = carry_ref[...]

    @pl.when((s == 0) & (i == 0))
    def _zero_stats():
        stats_ref[...] = jnp.zeros_like(stats_ref)

    rows = rows_ref[...]            # (block_n, width) float32 wire rows
    n = rows.shape[0]

    # -- decode + fan-out + watermark masking (registers, no HBM traffic) --
    if host_wire:                   # host already expanded; fan-out 1
        slot = rows[:, 0].astype(jnp.int32)[:, None]
        bucket = _bucketize(rows[:, 1], num_buckets, hashed)[:, None]
        val = rows[:, 2][:, None]
        live = (rows[:, 3] > 0)[:, None]
        late = jnp.zeros((), jnp.int32)
    else:
        last = rows[:, 0].astype(jnp.int32)
        n_windows = rows[:, 1].astype(jnp.int32)
        bucket = _bucketize(rows[:, 2], num_buckets, hashed)[:, None]
        val = rows[:, 3][:, None]
        valid = rows[:, 4] > 0
        j = jax.lax.broadcasted_iota(jnp.int32, (n, fanout), 1)
        widx = last[:, None] - j
        covers = valid[:, None] & (j < n_windows[:, None])
        minw = minw_ref[0, 0]
        live = covers & (widx >= minw)
        late = jnp.sum((covers & (widx < minw)).astype(jnp.int32))
        slot = jnp.mod(widx, n_slots)
    flat = slot * carry_buckets + bucket            # (n, F) fan-out pairs
    flat = jnp.where(live, flat, -1)                # dead pair → no one-hot
    folded = jnp.sum(live.astype(jnp.int32))

    m = n * (1 if host_wire else fanout)
    rel = flat.reshape(m, 1) - s * block_s          # id within this tile
    ids = jax.lax.broadcasted_iota(jnp.int32, (m, block_s), 1)
    hit = rel == ids                                # (m, block_s) one-hot
    valf = jnp.broadcast_to(val, (n, m // n)).reshape(m, 1)
    channels = out_ref.shape[1]
    ch = jax.lax.broadcasted_iota(jnp.int32, (1, channels), 1)

    if kind in ("sum", "count"):
        onehot = hit.astype(jnp.float32)
        contrib = jnp.ones((m, 1), jnp.float32) if kind == "count" else valf
        # [Σ value-or-one, Σ 1] per flat id — one (block_s × m)·(m × 2)
        # matmul on the MXU; dead pairs have all-zero one-hot rows
        pair = jnp.concatenate([contrib, jnp.ones((m, 1), jnp.float32)],
                               axis=1)
        acc = jax.lax.dot_general(onehot, pair, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        out_ref[...] += (
            jnp.where(ch == channel_base, acc[:, 0:1], 0.0)
            + jnp.where(ch == channel_base + 1, acc[:, 1:2], 0.0)
        ).astype(out_ref.dtype)
    else:                           # min / max: masked running extremum
        neutral = jnp.float32(jnp.inf if kind == "min" else -jnp.inf)
        cand = jnp.where(hit, jnp.broadcast_to(valf, (m, block_s)), neutral)
        ext = cand.min(axis=0) if kind == "min" else cand.max(axis=0)
        cnt = jnp.sum(hit.astype(jnp.float32), axis=0)
        old = out_ref[...]
        old_v = old[:, channel_base]
        old_c = old[:, channel_base + 1]
        eff = jnp.where(old_c > 0, old_v, neutral)
        comb = jnp.minimum(eff, ext) if kind == "min" \
            else jnp.maximum(eff, ext)
        new_c = old_c + cnt
        new_v = jnp.where(new_c > 0, comb, 0.0)
        out_ref[...] = jnp.where(
            ch == channel_base, new_v[:, None],
            jnp.where(ch == channel_base + 1, new_c[:, None], old)
        ).astype(out_ref.dtype)

    @pl.when(s == 0)                # each record tile counted exactly once
    def _count():
        stats_ref[...] += jnp.concatenate(
            [late.reshape(1, 1), folded.reshape(1, 1),
             jnp.zeros((1, 1), jnp.int32)], axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("fanout", "n_slots", "num_buckets", "carry_buckets",
                     "channel_base", "hashed", "host_wire", "kind",
                     "block_n", "block_s", "interpret"))
def fused_streaming_fold(rows, carry, min_window=None, *, fanout: int,
                         n_slots: int, num_buckets: int, carry_buckets: int,
                         channel_base: int = 0, hashed: bool = False,
                         host_wire: bool = False, kind: str = "sum",
                         block_n: int = 256, block_s: int | None = None,
                         interpret: bool = False):
    """One fused streaming fold: ``(rows, carry[, min_window]) →
    (carry', [late, folded, 0])``.

    rows : (N, 5) float32 device wire ``[last_window_index, n_windows,
    key, value, valid]`` (or (N, 4) host wire ``[window_slot, key, value,
    valid]`` with ``host_wire=True``); carry : the flattened
    ``(n_slots * carry_buckets, channels)`` slab.  N pads to ``block_n``
    internally (pad rows are invalid); ``block_s`` tiles the flat id space
    (default: one resident tile).  Bit-parity oracle:
    ``ref.fused_streaming_fold_ref``.
    """
    if kind not in FOLD_KINDS:
        raise ValueError(f"unknown fold kind {kind!r}")
    size, channels = carry.shape
    if size != n_slots * carry_buckets:
        raise ValueError(f"carry has {size} rows, expected "
                         f"n_slots*carry_buckets = {n_slots * carry_buckets}")
    width = HOST_ROW if host_wire else DEVICE_ROW
    if rows.shape[1] != width:
        raise ValueError(f"expected width-{width} wire rows, got "
                         f"{rows.shape}")
    block_s = block_s or size
    if size % block_s:
        raise ValueError("block_s must divide n_slots * carry_buckets")
    minw = INT32_MIN if min_window is None else min_window
    minw = jnp.asarray(minw, jnp.int32).reshape(1, 1)

    n = rows.shape[0]
    n_pad = (-n) % block_n
    if n_pad:                       # zero rows decode as invalid
        rows = jnp.pad(rows, ((0, n_pad), (0, 0)))
    grid = (size // block_s, (n + n_pad) // block_n)

    new_carry, stats = pl.pallas_call(
        functools.partial(
            _fused_fold_kernel, fanout=fanout, n_slots=n_slots,
            num_buckets=num_buckets, carry_buckets=carry_buckets,
            channel_base=channel_base, hashed=hashed, host_wire=host_wire,
            kind=kind, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, width), lambda s, i: (i, 0)),
            pl.BlockSpec((block_s, channels), lambda s, i: (s, 0)),
            pl.BlockSpec((1, 1), lambda s, i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, channels), lambda s, i: (s, 0)),
            pl.BlockSpec((1, 3), lambda s, i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((size, channels), carry.dtype),
            jax.ShapeDtypeStruct((1, 3), jnp.int32),
        ],
        input_output_aliases={1: 0},    # carry updates in place when donated
        interpret=interpret,
    )(rows, carry, minw)
    return new_carry, stats[0]
