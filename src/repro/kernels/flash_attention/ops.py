"""Public attention ops with kernel/XLA routing and a differentiable wrapper.

``attention(...)`` / ``decode_attention(...)`` are what ``repro.models`` call.
Routing:

  * ``use_pallas=False`` (default here — CPU container, and the dry-run wants
    the XLA graph for cost analysis): a *chunked* jnp implementation that, like
    the kernel, never materializes the full score matrix (lax.scan over KV
    chunks with online softmax) — same memory behaviour, XLA-visible FLOPs.
  * ``use_pallas=True``: the Pallas kernel (interpret=True on CPU).

Training differentiates through the chunked XLA path (flash backward on real
TPU would be a custom_vjp pairing; the forward kernels here are the
serving-critical surface the paper's workloads exercise).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention as _fa_pallas
from .kernel import flash_decode as _fd_pallas
from .ref import decode_ref, mha_ref

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "chunk", "q_offset"))
def chunked_attention(q, k, v, *, causal=True, window=None, softcap=None,
                      scale=None, chunk=1024, q_offset=0):
    """Flash-style online-softmax attention in pure jnp: lax.scan over KV
    chunks.  O(Sq·chunk) live memory.  GQA via kv-head repeat at the einsum
    (XLA fuses the broadcast; no HBM duplication)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale_ = scale if scale is not None else d ** -0.5
    chunk = min(chunk, skv)
    if skv % chunk:
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        skv_p = skv + pad
    else:
        skv_p = skv
    n_chunks = skv_p // chunk

    qf = q.astype(jnp.float32) * scale_
    kf = k.astype(jnp.float32).reshape(b, hkv, n_chunks, chunk, d)
    vf = v.astype(jnp.float32).reshape(b, hkv, n_chunks, chunk, d)
    q_pos = jnp.arange(sq) + q_offset

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kc, vc, c_idx = inp                      # (b, hkv, chunk, d) ×2
        kc = jnp.repeat(kc, group, axis=1)
        vc = jnp.repeat(vc, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc)
        if softcap is not None and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = c_idx * chunk + jnp.arange(chunk)
        mask = (k_pos[None, :] < skv) & jnp.ones((sq, 1), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None and window > 0:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(mask[None, None], jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, hq, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, hq, sq), jnp.float32),
            jnp.zeros((b, hq, sq, d), jnp.float32))
    (m, lsum, acc), _ = jax.lax.scan(
        step, init,
        (jnp.moveaxis(kf, 2, 0), jnp.moveaxis(vf, 2, 0),
         jnp.arange(n_chunks)))
    denom = jnp.where(lsum > 0, lsum, 1.0)
    return (acc / denom[..., None]).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=None, softcap=None, scale=None,
              use_pallas=False, interpret=True, chunk=1024,
              block_q=128, block_k=128, q_offset=0):
    """(B, Hq, Sq, D) × (B, Hkv, Skv, D)² → (B, Hq, Sq, D)."""
    if use_pallas and q_offset == 0:
        return _fa_pallas(q, k, v, causal=causal, window=window,
                          softcap=softcap, scale=scale, block_q=block_q,
                          block_k=block_k, interpret=interpret)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, chunk=chunk,
                             q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, lengths, *, window=None,
                     softcap=None, scale=None, use_pallas=False,
                     interpret=True, block_k=512):
    """(B, Hq, D) × (B, Hkv, S, D)² + lengths (B,) → (B, Hq, D)."""
    if use_pallas:
        return _fd_pallas(q, k_cache, v_cache, lengths, window=window,
                          softcap=softcap, scale=scale, block_k=block_k,
                          interpret=interpret)
    return decode_ref(q, k_cache, v_cache, lengths, window=window,
                      softcap=softcap, scale=scale)


__all__ = ["attention", "decode_attention", "chunked_attention", "mha_ref",
           "decode_ref"]
