"""Flash attention (fwd + split-K decode) as Pallas TPU kernels.

Online-softmax tiling (Flash-Attention [arXiv:2205.14135], adapted to TPU per
the jax pallas TPU ops): the (Sq × Skv) score matrix never leaves VMEM; the
grid streams KV blocks while running max/sum/accumulator live in VMEM scratch.
TPU adaptations:

  * grid = (batch, q_head, q_block, kv_block) with the KV dimension innermost
    — TPU grids execute sequentially, so scratch carries the online-softmax
    state between kv steps (no atomics, unlike the CUDA formulation);
  * m/l scratch kept (block_q, 128)-shaped, broadcast across lanes, matching
    the fp32 (8, 128) VREG tile;
  * GQA handled in the BlockSpec index map (q head h reads kv head
    h // group) — no KV duplication in HBM or VMEM;
  * causal/sliding-window blocks that are fully masked are skipped with
    ``pl.when`` (the grid still visits them; the MXU work is gated off);
  * optional logit softcap (Gemma-2): s ← c·tanh(s/c) before masking.

Block defaults (128, 128) keep the working set ≈
block_q·D + 2·block_k·D + block_q·block_k fp32 ≈ 0.3 MB ≪ VMEM, and both
matmul shapes MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                causal: bool, window: int | None, softcap: float | None,
                scale: float, block_q: int, block_k: int, n_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # visit the block only if any (q, k) pair in it is unmasked
    block_live = jnp.bool_(True)
    if causal:
        block_live &= q_start + block_q - 1 >= k_start
    if window is not None and window > 0:
        # newest query in the block must reach back to this kv block
        block_live &= q_start - (k_start + block_k - 1) < window

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, d)

        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None and window > 0:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]                          # (bq,)
        l_prev = l_scr[:, 0]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)

        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        lsum = l_scr[:, 0]
        denom = jnp.where(lsum > 0.0, lsum, 1.0)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                              "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) → (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, \
        "pad sequence lengths to block multiples"
    n_kv_blocks = skv // block_k

    grid = (b, hq, sq // block_q, n_kv_blocks)
    kernel = functools.partial(
        _fwd_kernel, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k,
        n_kv_blocks=n_kv_blocks)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Split-K decode: one query token against a long KV cache
# ---------------------------------------------------------------------------

def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, window: int | None, softcap: float | None, scale: float,
                   block_k: int, n_kv_blocks: int, group: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = ki * block_k
    lo = (length - window) if (window is not None and window > 0) else 0
    block_live = jnp.logical_and(k_start < length,
                                 k_start + block_k > lo)

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # (group, d) — q-head group
        k = k_ref[0, 0].astype(jnp.float32)      # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)

        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (group, block_k), 1)
        mask = k_pos < length
        if window is not None and window > 0:
            mask &= k_pos >= length - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]
        l_prev = l_scr[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        lsum = l_scr[:, 0]
        denom = jnp.where(lsum > 0.0, lsum, 1.0)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "scale", "block_k",
                              "interpret"))
def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 lengths: jax.Array, *, window: int | None = None,
                 softcap: float | None = None, scale: float | None = None,
                 block_k: int = 512, interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); caches: (B, Hkv, S, D); lengths: (B,) → (B, Hq, D).

    Grid (B, Hkv, S/block_k): the q-head *group* sharing one kv head rides the
    sublane dimension, so GQA decode is one (group × block_k) matmul per step
    — the flash-decoding split-K layout with the group as the M dimension.
    """
    b, hq, d = q.shape
    _, hkv, s_max, _ = k_cache.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    block_k = min(block_k, s_max)
    assert s_max % block_k == 0, "pad cache length to block multiple"
    n_kv_blocks = s_max // block_k

    # (B, Hq, D) → (B, Hkv, group, D) so each grid step owns one kv head's group
    qg = q.reshape(b, hkv, group, d)

    grid = (b, hkv, n_kv_blocks)
    kernel = functools.partial(
        _decode_kernel, window=window, softcap=softcap, scale=scale,
        block_k=block_k, n_kv_blocks=n_kv_blocks, group=group)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h, ki: (b_,)),
            pl.BlockSpec((1, 1, group, d), lambda b_, h, ki: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, ki: (b_, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, ki: (b_, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda b_, h, ki: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, hq, d)
