"""Pure-jnp oracle for flash attention (fwd + decode).

Materializes the full score matrix — O(Sq·Skv) memory — so it is only usable
at test scales, which is exactly its job.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(sq: int, skv: int, causal: bool, window: int | None,
          q_offset: int) -> jax.Array:
    """(sq, skv) boolean mask. ``q_offset`` positions query row 0 at absolute
    position q_offset (decode: q_offset = cache_len - 1 for the single row)."""
    q_pos = jnp.arange(sq)[:, None] + q_offset
    k_pos = jnp.arange(skv)[None, :]
    m = jnp.ones((sq, skv), dtype=bool)
    if causal:
        m &= q_pos >= k_pos
    if window is not None and window > 0:
        m &= q_pos - k_pos < window
    return m


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
            causal: bool = True, window: int | None = None,
            softcap: float | None = None, scale: float | None = None,
            q_offset: int = 0) -> jax.Array:
    """Grouped-query attention reference.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); Hq % Hkv == 0.
    Returns (B, Hq, Sq, D) in q's dtype; softmax in fp32.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to match q heads
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)

    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    m = _mask(sq, skv, causal, window, q_offset)
    s = jnp.where(m[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows: softmax of all NEG_INF ≈ uniform; zero them instead
    any_valid = m.any(axis=-1)
    p = jnp.where(any_valid[None, None, :, None], p, 0.0)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return o.astype(q.dtype)


def decode_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
               lengths: jax.Array, *, window: int | None = None,
               softcap: float | None = None,
               scale: float | None = None) -> jax.Array:
    """Single-token decode reference.

    q: (B, Hq, D) — the new token's query; caches: (B, Hkv, S, D);
    lengths: (B,) int32 — valid cache entries per sequence (the new token is
    at position lengths-1 and may attend to [0, lengths)).
    Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    _, hkv, s_max, _ = k_cache.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    # grouped form: q-heads sharing a kv head ride a 'g' axis so the cache is
    # contracted directly — no jnp.repeat, whose materialization forces an
    # all-gather of seq-sharded caches under SPMD (EXPERIMENTS.md §Perf)
    qg = (q.astype(jnp.float32) * scale).reshape(b, hkv, group, d)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)

    s = jnp.einsum("bhgd,bhkd->bhgk", qg, kf)
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = jnp.arange(s_max)[None, None, None, :]
    valid = k_pos < lengths[:, None, None, None]
    if window is not None and window > 0:
        valid &= k_pos >= (lengths[:, None, None, None] - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, vf)
    return o.reshape(b, hq, d).astype(q.dtype)
