"""Public selective-scan op with kernel/XLA routing.

``scan(...)`` is what ``repro.models.mamba`` calls.  Routing mirrors the other
kernels: XLA path (``jax.lax.scan`` reference — also the differentiable
training path) by default on CPU/dry-run, Pallas kernel on TPU
(``interpret=True`` validates the kernel body on CPU).

``decode_step`` is the O(1) single-token state update used by serve_step /
the long_500k shape — no kernel needed, it is a handful of VPU ops.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import selective_scan as _scan_pallas
from .ref import selective_scan_ref


def scan(u, delta, A, B, C, D, *, use_pallas=False, interpret=True,
         block_d=256, block_l=256):
    """Selective scan over a full sequence, routed to the Pallas kernel
    (``use_pallas=True``; ``interpret=True`` runs the kernel body on CPU)
    or the ``jax.lax.scan`` reference — identical numerics either way.
    Shapes follow the S6 convention: ``u``/``delta`` are (batch, L, D),
    ``A`` is (D, N), ``B``/``C`` are (batch, L, N), ``D`` is (D,);
    returns (batch, L, D)."""
    if use_pallas:
        return _scan_pallas(u, delta, A, B, C, D, block_d=block_d,
                            block_l=block_l, interpret=interpret)
    return selective_scan_ref(u, delta, A, B, C, D)


def decode_step(h, u_t, delta_t, A, B_t, C_t, D):
    """One recurrence step for decoding.

    h: (batch, D, N) carried state; u_t, delta_t: (batch, D);
    B_t, C_t: (batch, N).  Returns (y_t, h_new): (batch, D), (batch, D, N).
    """
    dA = jnp.exp(delta_t[..., None] * A[None].astype(jnp.float32))
    dBu = (delta_t * u_t)[..., None] * B_t[:, None, :]
    h_new = dA * h + dBu
    y = jnp.einsum("bdn,bn->bd", h_new, C_t) + u_t * D[None]
    return y.astype(u_t.dtype), h_new


__all__ = ["scan", "decode_step", "selective_scan_ref"]
