"""Mamba-1 selective scan as a Pallas TPU kernel.

The CUDA selective-scan (Mamba [arXiv:2312.00752]) parallelizes over threads
with a work-efficient block scan in shared memory.  TPU has no warp shuffles;
the TPU-native decomposition (DESIGN.md §4) is:

  * channels are embarrassingly parallel → grid dimension over D blocks,
  * time is sequential *within* the kernel, with the (block_d × N) state
    resident in VMEM scratch — never touching HBM between steps,
  * long sequences stream through the grid's innermost (sequential) dimension
    in chunks of ``block_l``; the state scratch carries across chunks,
    exactly like the flash-attention accumulator carries across KV blocks.

Per time step the update is pure VPU element-wise work on (block_d, N) tiles
(N = 16 for falcon-mamba) plus a (block_d, N)·(N,) contraction — the MXU is
idle, which is intrinsic to Mamba-1's recurrence (Mamba-2/SSD exists to feed
the matrix units; models/mamba.py implements that variant as chunked einsums).

VMEM per step: state block_d·N + chunk slabs block_l·(2·block_d + 2·N) fp32.
Defaults (block_d=256, block_l=256, N≤16) ≈ 0.7 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(u_ref, delta_ref, A_ref, B_ref, C_ref, y_ref, hout_ref, h_scr,
                 *, block_l: int, n_l_blocks: int):
    li = pl.program_id(2)

    @pl.when(li == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = A_ref[...]                     # (block_d, N)

    def step(t, h):
        u_t = u_ref[0, t, :]           # (block_d,)
        d_t = delta_ref[0, t, :]       # (block_d,)
        b_t = B_ref[0, t, :]           # (N,)
        c_t = C_ref[0, t, :]           # (N,)
        dA = jnp.exp(d_t[:, None] * A)                   # (block_d, N)
        dBu = (d_t * u_t)[:, None] * b_t[None, :]        # (block_d, N)
        h = dA * h + dBu
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_l, step, h_scr[...])
    h_scr[...] = h

    @pl.when(li == n_l_blocks - 1)
    def _emit_state():
        hout_ref[0] = h_scr[...].astype(hout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_d", "block_l", "interpret"))
def selective_scan(u: jax.Array, delta: jax.Array, A: jax.Array,
                   B: jax.Array, C: jax.Array, D: jax.Array, *,
                   block_d: int = 256, block_l: int = 256,
                   interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """u, delta: (batch, L, D); A: (D, N); B, C: (batch, L, N); D: (D,).

    Returns (y, h_final): (batch, L, D) and (batch, D, N).
    The D·u skip is applied outside the kernel (one fused VPU multiply-add).
    """
    bsz, L, d = u.shape
    n = A.shape[1]
    block_d = min(block_d, d)
    block_l = min(block_l, L)
    assert d % block_d == 0, "pad channels to block_d"
    assert L % block_l == 0, "pad sequence to block_l"
    n_l_blocks = L // block_l

    grid = (bsz, d // block_d, n_l_blocks)
    kernel = functools.partial(_scan_kernel, block_l=block_l,
                               n_l_blocks=n_l_blocks)

    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_l, block_d), lambda b, di, li: (b, li, di)),
            pl.BlockSpec((1, block_l, block_d), lambda b, di, li: (b, li, di)),
            pl.BlockSpec((block_d, n), lambda b, di, li: (di, 0)),
            pl.BlockSpec((1, block_l, n), lambda b, di, li: (b, li, 0)),
            pl.BlockSpec((1, block_l, n), lambda b, di, li: (b, li, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_l, block_d), lambda b, di, li: (b, li, di)),
            pl.BlockSpec((1, block_d, n), lambda b, di, li: (b, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, L, d), u.dtype),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(u, delta, A, B, C)
    y = y + u * D.astype(u.dtype)[None, None]
    return y, h_final
