"""Pure-jnp oracle for the Mamba-1 selective scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(u: jax.Array, delta: jax.Array, A: jax.Array,
                       B: jax.Array, C: jax.Array, D: jax.Array,
                       h0: jax.Array | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Sequential-in-time reference.

    u, delta : (batch, L, D)      (delta already softplus'd + bias'd)
    A        : (D, N)             (the real-valued log-spaced S4D-style A)
    B, C     : (batch, L, N)      (input-dependent projections)
    D        : (D,)               (skip)
    h0       : (batch, D, N) initial state (None = zeros)

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t u_t) ⊗ B_t ;  y_t = ⟨h_t, C_t⟩ + D u_t
    Returns (y, h_final): (batch, L, D), (batch, D, N).
    """
    bsz, L, d = u.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), jnp.float32)

    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def step(h, inp):
        u_t, d_t, b_t, c_t = inp          # (batch,d),(batch,d),(batch,n),(batch,n)
        dA = jnp.exp(d_t[..., None] * Af[None])            # (batch, d, n)
        dBu = (d_t * u_t)[..., None] * b_t[:, None, :]     # (batch, d, n)
        h = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    inputs = (jnp.moveaxis(uf, 1, 0), jnp.moveaxis(df, 1, 0),
              jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1) + uf * D.astype(jnp.float32)[None, None]
    return y.astype(u.dtype), h_final
