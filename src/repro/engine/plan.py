"""Execution plans — one declarative layer over batch and streaming.

A MapReduce job on the device plane is a point in a small product space:

    ``KeySpace``  ×  ``WindowSpec``  ×  ``ReduceSpec``  →  backend lowering

``KeySpace`` says how raw keys become bucket ids (dense pre-assigned ids, or
hashed open domains with exact collision accounting).  ``WindowSpec`` says
whether records carry event-time windows and whether the sliding-window
fan-out happens on-device (broadcast + iota in ``stages.window_fanout``) or
was already done by the host.  ``ReduceSpec`` says how values reduce: the
*aggregate* mode (commutative/associative — combiner fused into one
``reduce_scatter``) or the *group* mode (arbitrary ``reduce_fn`` over each
key's full value list via the fixed-capacity ``all_to_all``).

``ExecutionPlan.compile`` lowers one plan to one of three backends
(``vmap`` — simulated workers on one device, ``shard_map`` — a real mesh
axis, ``pallas`` — the streaming aggregate fold as one fused
``kernels/fused_fold`` kernel over a single flat carry slab) and returns a
compiled object: ``run`` for one-shot batch jobs, or
``init_carry`` / ``step`` / ``read_slot`` / ``finalize_slot`` /
``clear_slot`` for streaming.  Batch one-shot, streaming incremental,
aggregate, and group are all lowerings of this one layer — there is no
second engine.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import stages
from .compile import default_pallas_interpret, lower
from .stages import ShuffleStats

P = jax.sharding.PartitionSpec

INT32_MIN = -(2 ** 31)
INT32_MAX = 2 ** 31 - 1

#: host→device wire formats for streaming micro-batch rows
HOST_FANOUT_ROW = 4     # [window_slot, key, value, valid]
DEVICE_FANOUT_ROW = 5   # [last_window_index, n_windows, key, value, valid]


# ---------------------------------------------------------------------------
# The plan vocabulary
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KeySpace:
    """How raw map keys become bucket ids in ``[0, num_buckets)``.

    ``dense`` — keys already are bucket ids (the data layer assigned them);
    exceeding ``num_buckets`` is a caller error.  ``hashed`` — keys come
    from an open, unbounded domain and are folded in with ``device_hash``;
    distinct keys may collide, and with ``track_collisions`` the engine
    counts them exactly per bucket (``ShuffleStats.bucket_collisions``), so
    unbounded key sets degrade gracefully instead of raising.
    """

    num_buckets: int
    mode: str = "dense"             # "dense" | "hashed"
    track_collisions: bool = True

    @classmethod
    def dense(cls, num_buckets: int) -> "KeySpace":
        return cls(num_buckets, "dense")

    @classmethod
    def hashed(cls, num_buckets: int,
               track_collisions: bool = True) -> "KeySpace":
        return cls(num_buckets, "hashed", track_collisions)

    @property
    def is_hashed(self) -> bool:
        return self.mode == "hashed"

    def padded(self, n_workers: int) -> int:
        """Bucket space padded to a multiple of the axis size so the tiled
        reduce_scatter divides evenly; pad rows stay zero."""
        return -(-self.num_buckets // n_workers) * n_workers


@dataclass(frozen=True)
class WindowSpec:
    """Event-time windowing as the device engine sees it.

    ``kind="fixed"`` (tumbling/sliding): ``slide=None`` means tumbling
    (fan-out 1).  ``fanout_on_device=True`` ships one 5-column row per
    record and replicates it into its ``ceil(size/slide)`` windows on-chip;
    ``False`` is the legacy host fan-out wire format (one 4-column row per
    record × window).  Ring slots are addressed modularly — window ``w``
    lives in slot ``w % n_slots`` on host and device alike.  Window
    *indices* on the wire are caller-rebased (the coordinator subtracts a
    per-batch base that is a multiple of ``n_slots``), so they stay exact
    in float32 regardless of absolute event time; the fan-out stage only
    ever sees the rebased values.

    ``kind="session"``: data-dependent gap windows.  Session boundaries are
    inherently host-side (they depend on the observed event times per key),
    so session plans use the host wire format with fan-out 1; the host maps
    each open session to a carry *cell* — a (ring slot, bucket) pair — and
    merges bridged sessions with the cell ops on the compiled plan
    (``merge_cell`` / ``clear_cell``).  ``gap`` is the inactivity gap that
    closes a session.
    """

    size: float
    slide: float | None = None
    n_slots: int = 2
    fanout_on_device: bool = True
    kind: str = "fixed"             # "fixed" | "session"
    gap: float = 0.0

    @classmethod
    def session(cls, gap: float, n_slots: int = 8) -> "WindowSpec":
        """Gap-based session windows — a new plan variant, not a new
        engine: the aggregate fold and carry are unchanged, only cell
        addressing and finalization differ."""
        return cls(size=0.0, slide=None, n_slots=n_slots,
                   fanout_on_device=False, kind="session", gap=gap)

    @property
    def is_session(self) -> bool:
        return self.kind == "session"

    @property
    def fanout(self) -> int:
        """Max windows per record — the on-chip replication factor."""
        if self.slide is None:
            return 1
        return math.ceil(self.size / self.slide)


@dataclass(frozen=True)
class ReduceSpec:
    """How values reduce within a (window ×) key group.

    ``aggregate`` — commutative/associative; ``combine_fn(keys, values,
    num_buckets, valid)`` pre-reduces locally (dense jnp combiner by
    default, the Pallas kernel slots in here) and one ``reduce_scatter``
    finishes.  ``group`` — arbitrary ``reduce_fn`` (a segment-reducer kind
    name or a ``(keys, values, starts) -> (gk, gv, gvalid)`` callable) over
    each key's full, exchanged value list; ``capacity`` bounds the
    per-partition record buffers (the spill-file size bound).  ``top_k`` —
    the aggregate fold plus a fixed-capacity heavy-hitters selection at
    finalization (``stages.top_k_buckets``); ``k`` bounds the output.

    ``channels`` / ``channel_base`` let several plans share one aggregate
    carry: each plan folds its ``[value, 1]`` pair into channels
    ``[channel_base, channel_base + 1]`` of a ``channels``-wide carry and
    leaves the rest untouched — the windowed-join wiring, where the left
    and right stream are two compiled plans over disjoint channel pairs of
    the same carry.  ``carry_buckets`` widens the carry's *bucket* axis
    past the plan's own key space (0 → the key space width): plans with
    asymmetric per-side key spaces (join key-space asymmetry) each
    bucketize within their own ``KeySpace.num_buckets`` but flatten window
    slots over the shared ``carry_buckets`` width, so both sides address
    one carry without their bucket ranges drifting.
    """

    mode: str = "aggregate"         # "aggregate" | "group" | "top_k"
    reduce_fn: str | Callable = "sum"
    combine_fn: str | Callable | None = None  # "pallas" names the kernel
    capacity: int = 0
    k: int = 0                      # top_k mode: selection capacity
    channels: int = 2               # carry width (2 per resident plan)
    channel_base: int = 0           # this plan's [sum, count] offset
    carry_buckets: int = 0          # shared carry bucket width (0 → own)

    @classmethod
    def top_k(cls, k: int) -> "ReduceSpec":
        return cls(mode="top_k", k=k)

    @property
    def folds_as_aggregate(self) -> bool:
        """top_k folds with the aggregate machinery; only finalization
        differs."""
        return self.mode in ("aggregate", "top_k")


@dataclass(frozen=True)
class ExecutionPlan:
    """One device MapReduce job, declaratively.  ``compile()`` lowers it."""

    key_space: KeySpace
    reduce: ReduceSpec
    n_workers: int
    window: WindowSpec | None = None
    axis_name: str = "workers"

    @property
    def carry_buckets(self) -> int:
        """Bucket width of the carry this plan folds into — the plan's own
        key space unless ``ReduceSpec.carry_buckets`` widens it (per-side
        key-space asymmetry over one shared carry)."""
        return self.reduce.carry_buckets or self.key_space.num_buckets

    def compile(self, map_fn: Callable | None = None, *,
                backend: str = "vmap",
                mesh: jax.sharding.Mesh | None = None,
                data_spec=None, finalize: bool = True, jit: bool = True):
        """Lower to an executable.  Batch plans (``window=None``) return a
        ``CompiledBatchPlan``; windowed plans return a streaming plan with a
        carry (``CompiledStreamAggregate`` or ``CompiledStreamGroup``)."""
        rs = self.reduce
        if rs.mode not in ("aggregate", "group", "top_k"):
            raise ValueError(f"unknown reduce mode {rs.mode!r}")
        if rs.mode == "group" and rs.capacity <= 0:
            raise ValueError("grouping mode needs a positive capacity")
        if rs.mode == "top_k" and rs.k < 1:
            raise ValueError("top_k mode needs k >= 1")
        if rs.mode == "top_k" and rs.channel_base != 0:
            raise ValueError("top_k ranks channels [0, 2) — it cannot "
                             "share a carry at a nonzero channel_base")
        if rs.channels < 2 or rs.channel_base + 2 > rs.channels:
            raise ValueError("channel window [base, base+2) must fit the "
                             "carry's channel count")
        if rs.carry_buckets and rs.carry_buckets < self.key_space.num_buckets:
            raise ValueError("carry_buckets must cover the plan's own key "
                             "space (carry width >= num_buckets)")
        if self.window is not None and self.window.is_session:
            if self.window.gap <= 0:
                raise ValueError("session windows need a positive gap")
            if self.window.fanout_on_device or rs.mode != "aggregate":
                raise ValueError("session windows lower to the host-wire "
                                 "aggregate fold (fan-out 1) only")
        if backend == "pallas" and self.window is not None:
            if rs.mode == "group":
                raise ValueError("backend='pallas' fuses the aggregate "
                                 "fold; group-mode plans (record buffers + "
                                 "all_to_all) lower via vmap/shard_map")
            if rs.combine_fn is not None:
                raise ValueError("backend='pallas' is already the fused "
                                 "combiner; combine_fn does not apply")
        if self.window is None:
            if map_fn is None:
                raise ValueError("batch plans need a map_fn")
            if rs.mode == "top_k" and not finalize:
                raise ValueError("batch top_k selects over the finalized "
                                 "bucket vector; finalize=False is "
                                 "contradictory")
            return CompiledBatchPlan(self, map_fn, backend, mesh, data_spec,
                                     finalize, jit)
        if self.window.fanout_on_device and self.window.size <= 0:
            raise ValueError("on-device fan-out needs a positive window size")
        if rs.mode == "group":
            if self.window.fanout_on_device is False:
                raise ValueError("windowed group mode runs with on-device "
                                 "fan-out only")
            return CompiledStreamGroup(self, backend, mesh, jit)
        return CompiledStreamAggregate(self, map_fn, backend, mesh, jit)


def streaming_record_map(shard):
    """Host-fan-out wire decode: shard is a (records, 4) float32 array of
    [window_slot, key, value, valid] rows.  Emits (sum, count) value
    channels so count / sum / mean all come out of one carried state."""
    slots = shard[:, 0].astype(jnp.int32)
    keys = shard[:, 1].astype(jnp.int32)
    valid = shard[:, 3] > 0
    values = jnp.stack([shard[:, 2], jnp.ones_like(shard[:, 2])], axis=-1)
    return slots, keys, values, valid


def _decode_device_rows(rows):
    """Device-fan-out wire decode: (records, 5) float32 rows of
    [last_window_index, n_windows, key, value, valid]."""
    return (rows[:, 0].astype(jnp.int32), rows[:, 1].astype(jnp.int32),
            rows[:, 2].astype(jnp.int32), rows[:, 3], rows[:, 4] > 0)


# ---------------------------------------------------------------------------
# Batch lowering (one-shot jobs)
# ---------------------------------------------------------------------------

def _batch_body(shard, *, plan: ExecutionPlan, map_fn, finalize: bool):
    ks, rs = plan.key_space, plan.reduce
    keys, values, valid = map_fn(shard)
    raw = keys.astype(jnp.int32)
    buckets = stages.bucketize(raw, ks.num_buckets, hashed=ks.is_hashed)
    if ks.is_hashed and ks.track_collisions:
        distinct = stages.distinct_keys_per_bucket(
            raw, valid, plan.axis_name, plan.n_workers, ks.num_buckets)
        collisions = jnp.maximum(distinct - 1, 0)
    else:
        collisions = None

    if rs.folds_as_aggregate:
        part = stages.shuffle_aggregate(
            buckets, values, plan.axis_name, ks.padded(plan.n_workers),
            valid=valid, combine_fn=rs.combine_fn)
        sent = jax.lax.psum(jnp.sum(valid.astype(jnp.int32)), plan.axis_name)
        stats = ShuffleStats(sent, jnp.zeros((), jnp.int32), collisions)
        if finalize:
            # Finalizer: concatenate every reducer's slice into one object —
            # all_gather is the collective form of §III-A.5's stream-concat.
            return jax.lax.all_gather(part, plan.axis_name, tiled=True), stats
        return part, stats

    out_k, out_v, starts, xstats = stages.shuffle_group(
        buckets, values, plan.axis_name, plan.n_workers, rs.capacity,
        valid=valid)
    gk, gv, gvalid = stages.apply_reduce_fn(rs.reduce_fn, out_k, out_v, starts)
    stats = ShuffleStats(jax.lax.psum(xstats.sent, plan.axis_name),
                         jax.lax.psum(xstats.dropped, plan.axis_name),
                         collisions)
    if finalize:
        gather = partial(jax.lax.all_gather, axis_name=plan.axis_name,
                         tiled=True)
        return (gather(gk), gather(gv), gather(gvalid)), stats
    return (gk, gv, gvalid), stats


class CompiledBatchPlan:
    """One-shot lowering: ``run(data) -> (result, ShuffleStats)``.

    Aggregate result is the (padded) dense bucket vector; group result is
    the ``(group_keys, group_values, group_valid)`` triple.  ``finalize``
    gathers every reducer's slice into one replicated object.
    """

    def __init__(self, plan, map_fn, backend, mesh, data_spec, finalize, jit):
        self.plan = plan
        body = partial(_batch_body, plan=plan, map_fn=map_fn,
                       finalize=finalize)
        axis = plan.axis_name
        in_spec = data_spec if data_spec is not None else P(axis)
        rspec = P() if finalize else P(axis)
        if plan.reduce.folds_as_aggregate:
            out_specs = (rspec, P())
        else:
            out_specs = ((rspec, rspec, rspec), P())
        self._fn = lower(body, axis_name=axis, in_specs=(in_spec,),
                         out_specs=out_specs, backend=backend, mesh=mesh,
                         jit=jit)

    def run(self, data):
        out, stats = self._fn(data)
        if self.plan.reduce.mode == "top_k":
            # the heavy-hitters selection over the (unpadded) bucket vector
            rank_kind = self.plan.reduce.reduce_fn \
                if isinstance(self.plan.reduce.reduce_fn, str) else "sum"
            out = _select_top_k(out, self.plan.key_space.num_buckets,
                                self.plan.reduce.k, rank_kind)
        return out, stats


# ---------------------------------------------------------------------------
# Streaming lowerings (carried window state, one fused collective per batch)
# ---------------------------------------------------------------------------

def _stream_agg_host_body(shard, carry_slice, *, plan: ExecutionPlan, map_fn):
    """Legacy wire format: the host already expanded records into (slot,
    key) rows; the device folds one micro-batch into the carry."""
    ks = plan.key_space
    slots, keys, values, valid = map_fn(shard)
    buckets = stages.bucketize(keys, ks.num_buckets, hashed=ks.is_hashed)
    part = stages.shuffle_aggregate_windowed(
        slots, buckets, values, plan.axis_name, plan.window.n_slots,
        plan.carry_buckets, valid=valid, combine_fn=plan.reduce.combine_fn)
    folded = jax.lax.psum(jnp.sum(valid.astype(jnp.int32)), plan.axis_name)
    stats = jnp.stack([jnp.zeros((), jnp.int32), folded,
                       jnp.zeros((), jnp.int32)])
    return carry_slice + part, stats


def _embed_channels(vals: jax.Array, n_channels: int,
                    base: int) -> jax.Array:
    """Place a record's ``[value, 1]`` pair at channels ``[base, base+1]``
    of an ``n_channels``-wide value vector, zero elsewhere — how plans
    sharing a carry (windowed joins) stay out of each other's channels."""
    cols = [jnp.zeros_like(vals)] * n_channels
    cols[base] = vals
    cols[base + 1] = jnp.ones_like(vals)
    return jnp.stack(cols, axis=-1)


def _stream_agg_device_body(rows, carry_slice, min_window, *,
                            plan: ExecutionPlan):
    """Fan-out-on-device wire format: one row per record; the stage
    replicates it into its windows on-chip and folds in the same fused
    reduce_scatter."""
    ks, ws = plan.key_space, plan.window
    last, nw, keys, vals, valid = _decode_device_rows(rows)
    buckets = stages.bucketize(keys, ks.num_buckets, hashed=ks.is_hashed)
    values = _embed_channels(vals, plan.reduce.channels,
                             plan.reduce.channel_base)
    slots, keys_f, vals_f, live, late, expanded = stages.window_fanout(
        last, nw, buckets, values, valid, ws.fanout, ws.n_slots, min_window)
    part = stages.shuffle_aggregate_windowed(
        slots, keys_f, vals_f, plan.axis_name, ws.n_slots, plan.carry_buckets,
        valid=live, combine_fn=plan.reduce.combine_fn)
    stats = jnp.stack([jax.lax.psum(late, plan.axis_name),
                       jax.lax.psum(expanded, plan.axis_name),
                       jnp.zeros((), jnp.int32)])
    return carry_slice + part, stats


@partial(jax.jit, static_argnums=(2,))
def _gather_flat_slot(flat: jax.Array, slot, num_buckets: int) -> jax.Array:
    start = (slot * num_buckets,) + (0,) * (flat.ndim - 1)
    return jax.lax.dynamic_slice(flat, start,
                                 (num_buckets,) + flat.shape[1:])


@partial(jax.jit, static_argnums=(2,))
def _clear_flat_slot(flat: jax.Array, slot, num_buckets: int) -> jax.Array:
    zeros = jnp.zeros((num_buckets,) + flat.shape[1:], flat.dtype)
    start = (slot * num_buckets,) + (0,) * (flat.ndim - 1)
    return jax.lax.dynamic_update_slice(flat, zeros, start)


@partial(jax.jit, static_argnums=(3,))
def _gather_flat_cell(flat: jax.Array, slot, bucket,
                      num_buckets: int) -> jax.Array:
    start = (slot * num_buckets + bucket,) + (0,) * (flat.ndim - 1)
    return jax.lax.dynamic_slice(flat, start, (1,) + flat.shape[1:])[0]


@partial(jax.jit, static_argnums=(4,))
def _merge_flat_cell(flat: jax.Array, src_slot, dst_slot, bucket,
                     num_buckets: int) -> jax.Array:
    src = (src_slot * num_buckets + bucket,) + (0,) * (flat.ndim - 1)
    dst = (dst_slot * num_buckets + bucket,) + (0,) * (flat.ndim - 1)
    row_shape = (1,) + flat.shape[1:]
    src_row = jax.lax.dynamic_slice(flat, src, row_shape)
    dst_row = jax.lax.dynamic_slice(flat, dst, row_shape)
    flat = jax.lax.dynamic_update_slice(flat, src_row + dst_row, dst)
    return jax.lax.dynamic_update_slice(
        flat, jnp.zeros(row_shape, flat.dtype), src)


@partial(jax.jit, static_argnums=(3,))
def _clear_flat_cell(flat: jax.Array, slot, bucket,
                     num_buckets: int) -> jax.Array:
    start = (slot * num_buckets + bucket,) + (0,) * (flat.ndim - 1)
    return jax.lax.dynamic_update_slice(
        flat, jnp.zeros((1,) + flat.shape[1:], flat.dtype), start)


@partial(jax.jit, static_argnums=(1, 2, 3))
def _select_top_k(agg: jax.Array, num_buckets: int, k: int, kind: str):
    return stages.top_k_buckets(agg[:num_buckets], k, kind)


def _flat_carry(carry: jax.Array) -> tuple[jax.Array, tuple]:
    """View a (possibly vmap-batched) aggregate carry as its flattened
    (n_slots * num_buckets, channels) id space."""
    shape = carry.shape
    flat = carry.reshape((-1,) + shape[2:]) if carry.ndim == 3 else carry
    return flat, shape


def gather_window_slot(carry: jax.Array, slot: int,
                       num_buckets: int) -> np.ndarray:
    """Gather one finalized window's dense (num_buckets, channels) aggregate
    from the scattered carry.  Slices on device so only the window's rows —
    not the whole carry — cross to the host."""
    flat, _ = _flat_carry(carry)
    return np.asarray(_gather_flat_slot(flat, jnp.int32(slot), num_buckets))


def clear_window_slot_carry(carry: jax.Array, slot: int,
                            num_buckets: int) -> jax.Array:
    """Zero a finalized window's slice so its ring slot can be reused."""
    flat, shape = _flat_carry(carry)
    flat = _clear_flat_slot(flat, jnp.int32(slot), num_buckets)
    return flat.reshape(shape)


def read_window_cell(carry: jax.Array, slot: int, bucket: int,
                     num_buckets: int) -> np.ndarray:
    """Read one (slot, bucket) cell's (channels,) aggregate — a finalized
    session's entire state, since a session holds exactly one key."""
    flat, _ = _flat_carry(carry)
    return np.asarray(_gather_flat_cell(flat, jnp.int32(slot),
                                        jnp.int32(bucket), num_buckets))


def merge_window_cell_carry(carry: jax.Array, src_slot: int, dst_slot: int,
                            bucket: int, num_buckets: int) -> jax.Array:
    """Fold one cell's aggregate into another and zero the source — how a
    bridging event merges two open sessions of the same key without the
    carry ever leaving the device."""
    flat, shape = _flat_carry(carry)
    flat = _merge_flat_cell(flat, jnp.int32(src_slot), jnp.int32(dst_slot),
                            jnp.int32(bucket), num_buckets)
    return flat.reshape(shape)


def clear_window_cell_carry(carry: jax.Array, slot: int, bucket: int,
                            num_buckets: int) -> jax.Array:
    """Zero one (slot, bucket) cell so a finalized session's cell frees."""
    flat, shape = _flat_carry(carry)
    flat = _clear_flat_cell(flat, jnp.int32(slot), jnp.int32(bucket),
                            num_buckets)
    return flat.reshape(shape)


class CompiledStreamAggregate:
    """Streaming aggregate lowering: a scattered dense carry over the
    flattened (window_slot, bucket) id space, folded once per micro-batch
    by a single fused ``reduce_scatter``.

    ``step(rows, carry[, min_window]) -> (carry, stats)`` where stats is an
    int32 ``[late_pairs, folded_pairs, 0]`` vector (device-fan-out plans
    mask+count late (record, window) pairs on-chip).  Built once per stream
    so XLA compiles one program for every batch.

    ``backend="pallas"`` swaps the XLA body for the fused
    ``kernels/fused_fold`` kernel: hash, window fan-out, watermark masking
    and the scatter-accumulate all happen in one kernel over a single flat
    ``(n_slots * carry_buckets, channels)`` carry slab (the shard_map wire
    layout, so the coordinator and handoff edges need no new cases); the
    donated-carry step becomes a true in-place update via the kernel's
    ``input_output_aliases``.  Bit-parity with the XLA backends is
    test-enforced.  Interpret-vs-compile follows
    ``compile.default_pallas_interpret`` (interpret off-TPU).
    """

    def __init__(self, plan, map_fn, backend, mesh, jit):
        ws = plan.window
        carry_b = plan.carry_buckets
        if (ws.n_slots * carry_b) % plan.n_workers != 0:
            raise ValueError("n_slots * carry bucket width must divide by "
                             "n_workers")
        self.plan = plan
        self.backend = backend
        self._per_worker = (ws.n_slots * carry_b) // plan.n_workers
        axis = plan.axis_name
        if backend == "pallas":
            if not ws.fanout_on_device and map_fn is not None:
                raise ValueError("backend='pallas' decodes the standard "
                                 "host wire in-kernel; a custom map_fn "
                                 "does not apply")
            from ..kernels.fused_fold.ops import make_fold_step
            self._lower_step = partial(
                make_fold_step,
                fanout=ws.fanout if ws.fanout_on_device else 1,
                n_slots=ws.n_slots,
                num_buckets=plan.key_space.num_buckets,
                carry_buckets=carry_b,
                channel_base=plan.reduce.channel_base,
                hashed=plan.key_space.is_hashed,
                host_wire=not ws.fanout_on_device,
                interpret=default_pallas_interpret())
        else:
            if ws.fanout_on_device:
                body = partial(_stream_agg_device_body, plan=plan)
                in_specs = (P(axis), P(axis), P())
            else:
                body = partial(_stream_agg_host_body, plan=plan,
                               map_fn=map_fn or streaming_record_map)
                in_specs = (P(axis), P(axis))
            self._lower_step = partial(lower, body, axis_name=axis,
                                       in_specs=in_specs,
                                       out_specs=(P(axis), P()),
                                       backend=backend, mesh=mesh, jit=jit)
        self._step = self._lower_step()
        self._step_donating: Callable | None = None  # lowered on first use
        self._handoffs: dict[tuple, Callable] = {}  # (kind, rows) → handoff

    def init_carry(self, n_channels: int | None = None,
                   dtype=jnp.float32) -> jax.Array:
        """Zeroed carried window state in the scattered layout ``step``
        expects.  Defaults to the plan's channel width, so plans sharing a
        carry (joins) and single-plan streams use the same call."""
        plan = self.plan
        if n_channels is None:
            n_channels = plan.reduce.channels
        if self.backend == "vmap":
            return jnp.zeros((plan.n_workers, self._per_worker, n_channels),
                             dtype)
        return jnp.zeros(
            (plan.window.n_slots * plan.carry_buckets, n_channels), dtype)

    def step(self, rows, carry, min_window: int | None = None, *,
             donate: bool = False):
        """One micro-batch fold.  ``donate=True`` hands the carry buffer to
        XLA for in-place reuse (``donate_argnums``) — the caller must treat
        the passed carry as consumed and keep only the returned one, which
        every streaming drive loop already does (``stage.carry = step(...)``).
        """
        fn = self._donating_step() if donate else self._step
        if self.plan.window.fanout_on_device:
            return fn(rows, carry, jnp.int32(min_window))
        return fn(rows, carry)

    def _donating_step(self) -> Callable:
        """The same lowered step with the carry argument (index 1) donated,
        built lazily so non-streaming users never pay the second trace."""
        if self._step_donating is None:
            self._step_donating = self._lower_step(donate_argnums=(1,))
        return self._step_donating

    def read_slot(self, carry, slot: int) -> np.ndarray:
        return gather_window_slot(carry, slot, self.plan.carry_buckets)

    def clear_slot(self, carry, slot: int) -> jax.Array:
        return clear_window_slot_carry(carry, slot, self.plan.carry_buckets)

    # -- cell ops (session windows: one key per window) ----------------------
    def read_cell(self, carry, slot: int, bucket: int) -> np.ndarray:
        return read_window_cell(carry, slot, bucket,
                                self.plan.carry_buckets)

    def merge_cell(self, carry, src_slot: int, dst_slot: int,
                   bucket: int) -> jax.Array:
        return merge_window_cell_carry(carry, src_slot, dst_slot, bucket,
                                       self.plan.carry_buckets)

    def clear_cell(self, carry, slot: int, bucket: int) -> jax.Array:
        return clear_window_cell_carry(carry, slot, bucket,
                                       self.plan.carry_buckets)

    # -- fixed-capacity heavy hitters ----------------------------------------
    def top_k_slot(self, carry, slot: int, kind: str | None = None
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Select the plan's top-k buckets of one finalized window on
        device: gather the slot's dense aggregate, rank per ``kind``
        (default: the plan's ``reduce_fn`` kind), and keep the k largest.
        Returns ``(bucket_ids, values, valid)`` of length ``plan.reduce.k``.
        """
        rs = self.plan.reduce
        if rs.k < 1:
            raise ValueError("plan has no top-k capacity (reduce.k < 1)")
        if kind is None:
            kind = rs.reduce_fn if isinstance(rs.reduce_fn, str) else "sum"
        flat, _ = _flat_carry(carry)
        agg = _gather_flat_slot(flat, jnp.int32(slot),
                                self.plan.carry_buckets)
        ids, vals, valid = _select_top_k(agg, self.plan.key_space.num_buckets,
                                         rs.k, kind)
        return np.asarray(ids), np.asarray(vals), np.asarray(valid)

    # -- carry handoff (multi-stage chains and DAG fan-out edges) ------------
    def handoff_rows(self, carry, slot: int, relabel: jax.Array,
                     last_window: int, n_windows: int, kind: str,
                     dst_rows: int) -> jax.Array:
        """One finalized window's aggregates as a *successor* plan's wire
        rows — the reduce → map → window → reduce seam, entirely on
        device.  A teed stage calls this once per out-edge with that
        edge's own ``relabel`` table (and the destination's ``dst_rows``),
        so one finalized slot fans out to several downstream carries
        without ever visiting the host.

        Gathers the slot's dense aggregate, re-keys each occupied bucket
        through the ``relabel`` lookup (this plan's bucket id → the
        destination plan's key id, ``< 0`` = unassigned), stamps the
        re-windowed span ``[last_window, n_windows]`` (already rebased by
        the caller), and values each row with the finalized ``kind``
        aggregate.  Returns device-fan-out rows padded to ``dst_rows`` in
        the destination backend's wire layout: vmap gets the batched
        (workers, per, 5) shape, shard_map keeps the flat (rows, 5) global
        layout.  The (kind, dst_rows) jit cache keys one compiled handoff
        per distinct edge shape.
        """
        fn = self._handoffs.get((kind, dst_rows))
        if fn is None:
            fn = jax.jit(partial(self._handoff_impl, kind=kind,
                                 num_buckets=self.plan.carry_buckets,
                                 channel_base=self.plan.reduce.channel_base,
                                 dst_rows=dst_rows,
                                 n_workers=self.plan.n_workers
                                 if self.backend == "vmap" else 0))
            self._handoffs[(kind, dst_rows)] = fn
        return fn(carry, jnp.int32(slot), relabel,
                  jnp.float32(last_window), jnp.float32(n_windows))

    @staticmethod
    def _handoff_impl(carry, slot, relabel, last_window, n_windows, *,
                      kind, num_buckets, channel_base, dst_rows, n_workers):
        flat, _ = _flat_carry(carry)
        agg = _gather_flat_slot(flat, slot, num_buckets)
        rows = stages.carry_handoff_rows(agg, relabel, last_window,
                                         n_windows, kind, dst_rows,
                                         channel_base=channel_base)
        if n_workers:                   # vmap: batch the worker axis
            return rows.reshape(n_workers, dst_rows // n_workers, 5)
        return rows                     # shard_map: flat global wire


def _stream_group_body(rows, carry, min_window, *, plan: ExecutionPlan):
    """Windowed group-mode fold: fan out on-chip, exchange records to their
    (slot, bucket) owner over the flattened id space, append into the
    fixed-capacity per-slot buffers carried across batches."""
    ks, ws, rs = plan.key_space, plan.window, plan.reduce
    last, nw, keys, vals, valid = _decode_device_rows(rows)
    buckets = stages.bucketize(keys, ks.num_buckets, hashed=ks.is_hashed)
    slots, keys_f, vals_f, live, late, expanded = stages.window_fanout(
        last, nw, buckets, vals, valid, ws.fanout, ws.n_slots, min_window)
    flat = slots * ks.num_buckets + keys_f
    # per-destination capacity = all expanded records: the exchange cannot
    # drop; only the per-slot window buffers bound capacity
    sk, sv, sok, _ = stages.build_send_buffers(
        flat, vals_f, plan.n_workers, flat.shape[0], valid=live)
    rk, rv, rok = stages.exchange(sk, sv, sok, plan.axis_name)
    kb, vb, counts, dropped = stages.append_window_records(
        carry["keys"], carry["vals"], carry["counts"], rk.reshape(-1),
        jnp.where(rok.reshape(-1), rv.reshape(-1), 0.0), rok.reshape(-1),
        ws.n_slots, rs.capacity, ks.num_buckets)
    stats = jnp.stack([jax.lax.psum(late, plan.axis_name),
                       jax.lax.psum(expanded, plan.axis_name),
                       jax.lax.psum(dropped, plan.axis_name)])
    return {"keys": kb, "vals": vb, "counts": counts}, stats


def _stream_group_finalize_body(carry, slot, *, plan: ExecutionPlan):
    return stages.gather_window_group(carry["keys"], carry["vals"], slot,
                                      plan.axis_name, plan.reduce.reduce_fn)


class CompiledStreamGroup:
    """Streaming group-mode lowering: the carry is a fixed-capacity record
    buffer per (worker, window slot); arbitrary ``reduce_fn`` runs over each
    key's full value list at window finalization (``finalize_slot``), the
    same contract as batch group mode.
    """

    def __init__(self, plan, backend, mesh, jit):
        self.plan = plan
        self.backend = backend
        axis = plan.axis_name
        self._lower_step = partial(lower, partial(_stream_group_body,
                                                  plan=plan),
                                   axis_name=axis,
                                   in_specs=(P(axis), P(axis), P()),
                                   out_specs=(P(axis), P()), backend=backend,
                                   mesh=mesh, jit=jit)
        self._step = self._lower_step()
        self._step_donating = None
        self._finalize = lower(partial(_stream_group_finalize_body, plan=plan),
                               axis_name=axis, in_specs=(P(axis), P()),
                               out_specs=(P(), P(), P()), backend=backend,
                               mesh=mesh, jit=jit)
        self._clear = jax.jit(partial(self._clear_impl,
                                      n_slots=plan.window.n_slots))

    def init_carry(self, dtype=jnp.float32):
        """Zeroed per-(worker, window slot) record buffers.  Like the
        aggregate carry, the layout follows the backend: vmap batches the
        worker axis, shard_map shards the flattened (worker, slot) rows so
        each worker's slice matches what the stage body sees under vmap."""
        plan = self.plan
        n_slots, cap = plan.window.n_slots, plan.reduce.capacity
        if self.backend == "vmap":
            shape = (plan.n_workers, n_slots, cap)
        else:
            shape = (plan.n_workers * n_slots, cap)
        return {"keys": jnp.full(shape, stages.INVALID, jnp.int32),
                "vals": jnp.zeros(shape, dtype),
                "counts": jnp.zeros(shape[:-1], jnp.int32)}

    def step(self, rows, carry, min_window: int | None = None, *,
             donate: bool = False):
        """One micro-batch fold; ``donate=True`` donates the carry pytree's
        buffers for in-place reuse (see ``CompiledStreamAggregate.step``)."""
        if donate:
            if self._step_donating is None:
                self._step_donating = self._lower_step(donate_argnums=(1,))
            return self._step_donating(rows, carry, jnp.int32(min_window))
        return self._step(rows, carry, jnp.int32(min_window))

    def finalize_slot(self, carry, slot: int):
        """Gather + merge + reduce one window's buffered records across all
        workers.  Returns dense (group_keys, group_values, group_valid)."""
        gk, gv, gvalid = self._finalize(carry, jnp.int32(slot))
        return np.asarray(gk), np.asarray(gv), np.asarray(gvalid)

    @staticmethod
    def _clear_impl(carry, slot, *, n_slots):
        cap = carry["keys"].shape[-1]
        keys = carry["keys"].reshape(-1, n_slots, cap)
        vals = carry["vals"].reshape(-1, n_slots, cap)
        counts = carry["counts"].reshape(-1, n_slots)
        onehot = (jnp.arange(n_slots, dtype=jnp.int32) == slot)
        keys = jnp.where(onehot[None, :, None], stages.INVALID, keys)
        vals = jnp.where(onehot[None, :, None], 0.0, vals)
        counts = jnp.where(onehot[None, :], 0, counts)
        return {"keys": keys.reshape(carry["keys"].shape),
                "vals": vals.reshape(carry["vals"].shape),
                "counts": counts.reshape(carry["counts"].shape)}

    def clear_slot(self, carry, slot: int):
        return self._clear(carry, jnp.int32(slot))
