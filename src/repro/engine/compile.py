"""Backend lowering: one SPMD stage body, three execution substrates.

Every ``ExecutionPlan`` variant bottoms out here.  A *body* is a pure
function over per-worker arrays that may call collectives (``psum``,
``all_to_all``, ``psum_scatter``, ...) on ``axis_name``; ``lower`` turns it
into an executable either by vmapping the worker axis (simulating W workers
on one device — the CI path) or by shard_mapping it over a mesh axis (real
SPMD, the production path).  Placement is written once, as PartitionSpecs;
the vmap backend derives its in/out axes from them (``P(axis)`` → batched
at axis 0, ``P()`` → replicated), so both backends share one spec language
and the stage bodies in ``stages.py`` never mention a backend.

The third substrate, ``backend="pallas"``, does not lower a generic body
at all: the streaming aggregate fold dispatches to the fused Pallas
kernel (``kernels/fused_fold`` — hash → window fan-out →
scatter-accumulate in one kernel over the flat carry) inside
``plan.CompiledStreamAggregate``, with a single-slab carry in the
shard_map (flat) wire layout.  ``lower`` only knows enough about it to
say so in its error; ``default_pallas_interpret`` is the one switch every
pallas caller consults for compile-vs-interpret.

This module also owns the JAX version shim: jax >= 0.5 exposes
``jax.shard_map`` at top level with ``check_vma``; older releases (the
container ships 0.4.x) keep it in ``jax.experimental`` with ``check_rep``.
Callers (``core.mapreduce``, ``runtime.train_step``) must route through
``make_shard_map`` instead of touching ``jax.shard_map`` directly — drop
the shim here, and only here, when the toolchain moves to jax >= 0.5.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SM_CHECK_KW = "check_vma"
else:  # pragma: no cover - exercised on jax 0.4.x only
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_CHECK_KW = "check_rep"


#: backends ``ExecutionPlan.compile`` accepts; "pallas" is valid only for
#: plan shapes the fused fold covers (see plan.CompiledStreamAggregate)
BACKENDS = ("vmap", "shard_map", "pallas")


def default_pallas_interpret() -> bool:
    """Interpret Pallas kernel bodies unless a real TPU can compile them —
    the CI/container answer is always interpret (CPU executes the kernel
    body as jax ops, bit-identically), the production answer is Mosaic."""
    return jax.default_backend() != "tpu"


def make_shard_map(body: Callable, mesh: jax.sharding.Mesh, in_specs,
                   out_specs) -> Callable:
    """Version-portable ``shard_map`` with the replication checker off —
    finalized outputs are all_gather/psum results, replicated by
    construction, which the static checker can't always prove."""
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_SM_CHECK_KW: False})


def _vmap_axes(specs: Any, axis_name: str):
    """PartitionSpec (tree) → vmap axes: sharded on ``axis_name`` ↦ axis 0,
    replicated ↦ None.  Nested tuples mirror multi-output bodies; a single
    spec acts as a prefix over a pytree output (both backends broadcast)."""
    if isinstance(specs, jax.sharding.PartitionSpec):
        return 0 if axis_name in tuple(specs) else None
    if isinstance(specs, (tuple, list)):
        return tuple(_vmap_axes(s, axis_name) for s in specs)
    raise TypeError(f"expected PartitionSpec or tuple thereof, got {specs!r}")


def lower(body: Callable, *, axis_name: str, in_specs, out_specs,
          backend: str = "vmap", mesh: jax.sharding.Mesh | None = None,
          jit: bool = True,
          donate_argnums: tuple[int, ...] | None = None) -> Callable:
    """Lower an SPMD stage body to an executable for ``backend``.

    ``in_specs`` is a tuple with one PartitionSpec per body argument (a spec
    applies uniformly to a pytree argument); ``out_specs`` mirrors the body's
    output structure.  ``backend="vmap"`` needs no mesh; ``"shard_map"``
    shards/replicates per the same specs over ``mesh``.

    ``donate_argnums`` marks arguments whose buffers XLA may reuse for the
    outputs (``jax.jit`` donation) — streaming steps donate the carry so a
    long-lived fold updates one buffer in place instead of copying it every
    micro-batch.  The caller must not read a donated argument after the
    call; with ``jit=False`` donation is unavailable and silently skipped
    (an un-jitted body cannot alias buffers anyway).
    """
    if backend == "vmap":
        fn = jax.vmap(body, in_axes=_vmap_axes(tuple(in_specs), axis_name),
                      out_axes=_vmap_axes(out_specs, axis_name),
                      axis_name=axis_name)
    elif backend == "shard_map":
        if mesh is None:
            raise ValueError("shard_map backend needs a mesh")
        fn = make_shard_map(body, mesh, tuple(in_specs), out_specs)
    elif backend == "pallas":
        # the fused kernel replaces the body wholesale; only the streaming
        # aggregate plan knows how, so generic bodies cannot lower here
        raise ValueError(
            "backend='pallas' lowers the streaming aggregate fold only "
            "(the fused kernels/fused_fold kernel, dispatched inside "
            "CompiledStreamAggregate) — this plan shape has no pallas "
            "lowering; use 'vmap' or 'shard_map'")
    else:
        raise ValueError(f"unknown backend {backend!r} "
                         f"(expected one of {BACKENDS})")
    if not jit:
        return fn
    return jax.jit(fn, donate_argnums=donate_argnums or ())
