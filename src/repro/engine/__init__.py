"""Execution-plan layer — the one device engine behind batch and streaming.

``KeySpace`` × ``WindowSpec`` × ``ReduceSpec`` describe a job;
``ExecutionPlan.compile`` lowers it to a backend (``vmap`` simulated
workers or ``shard_map`` over a real mesh axis).  ``core.mapreduce`` and
``streaming.coordinator`` are thin façades over this package; new modes
(sessions, joins, top-k) should be new plan variants, not new engines.

Layout: ``plan`` (the declarative vocabulary + compiled plan objects),
``stages`` (pure SPMD stage bodies: shuffle, window fan-out, key hashing,
group buffers), ``compile`` (backend lowering + the jax version shim).
"""

from .compile import lower, make_shard_map
from .plan import (CompiledBatchPlan, CompiledStreamAggregate,
                   CompiledStreamGroup, ExecutionPlan, KeySpace, ReduceSpec,
                   WindowSpec, streaming_record_map)
from .stages import (ShuffleStats, device_hash, fold_key24, host_bucket,
                     segment_reduce, top_k_buckets)

__all__ = [
    "ExecutionPlan", "KeySpace", "ReduceSpec", "WindowSpec",
    "CompiledBatchPlan", "CompiledStreamAggregate", "CompiledStreamGroup",
    "streaming_record_map", "lower", "make_shard_map", "ShuffleStats",
    "device_hash", "fold_key24", "host_bucket", "segment_reduce",
    "top_k_buckets",
]
