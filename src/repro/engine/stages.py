"""Pure SPMD stage bodies — the device-side shuffle and window machinery.

Everything here is backend-agnostic: a stage is a pure function over one
worker's arrays that may call collectives on an axis name, equally valid
under ``jax.vmap`` (simulated workers) and ``shard_map`` (a real mesh axis).
``engine.compile.lower`` picks the substrate; ``engine.plan`` composes
stages into execution plans.

The shuffle stages re-express the paper's hash-partition + sorted-spill +
merge on a TPU mesh:

  * partition ``hash(key) % R``        →  the same hash, on int32 key ids
  * spill upload + reducer download    →  one ``jax.lax.all_to_all``
  * sorted spill runs + k-way merge    →  ``jax.lax.sort`` of the
                                          concatenated runs
  * combiner before spill              →  local bucket pre-reduction

Window stages keep streaming records on the fast path: a record crosses
host→device once and ``window_fanout`` replicates it into its
``ceil(size/slide)`` overlapping windows on-chip (broadcast + iota), so the
host never materializes the event × window expansion.  Key stages open the
key domain: ``bucketize`` hashes unbounded keys into a fixed bucket space
and ``distinct_keys_per_bucket`` does exact per-bucket collision accounting.

Keys are int32; values are float32/int32 arrays with leading axis = records.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

INVALID = jnp.int32(-1)
INT32_MAX = jnp.iinfo(jnp.int32).max


#: raw hashed-key ids must survive the float32 wire exactly
RAW_KEY_BITS = 24


def device_hash(keys: jax.Array) -> jax.Array:
    """murmur3 finalizer over int32 keys — stable, well-mixed, vectorized.

    The device analogue of the FNV-1a the host workers use on strings.
    """
    h = keys.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def fold_key24(key) -> int:
    """Stable host-side key → 24-bit raw id (FNV-1a 64, xor-folded).

    Small enough to ride the float32 wire exactly; the device hashes the
    raw id into buckets with ``device_hash``.  This is the single host
    entry point for open key domains — the streaming coordinator and any
    pipeline front end must fold keys here so labels and device buckets
    can never drift.
    """
    h = 0xCBF29CE484222325
    for b in str(key).encode():
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return (h ^ (h >> 24) ^ (h >> 48)) & ((1 << RAW_KEY_BITS) - 1)


def host_bucket(raw: int, num_buckets: int) -> int:
    """Host mirror of ``device_hash(raw) % num_buckets`` — bit-exact, so
    host-side bookkeeping (bucket labels, session cells) addresses the same
    bucket the device folds the record into."""
    h = raw & 0xFFFFFFFF
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & 0xFFFFFFFF
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & 0xFFFFFFFF
    h ^= h >> 16
    return h % num_buckets


def hash_partition(keys: jax.Array, n_partitions: int) -> jax.Array:
    """``hash(key) % R`` → destination partition (reducer) per record."""
    return (device_hash(keys) % jnp.uint32(n_partitions)).astype(jnp.int32)


def bucketize(keys: jax.Array, num_buckets: int, *,
              hashed: bool) -> jax.Array:
    """Raw int32 keys → bucket ids in ``[0, num_buckets)``.

    Dense key spaces pass through (the data layer already assigned dense
    ids); hashed key spaces fold an open domain into the bucket space with
    ``device_hash``, trading key identity for boundedness — collisions are
    accounted by ``distinct_keys_per_bucket``.
    """
    keys = keys.astype(jnp.int32)
    if hashed:
        return (device_hash(keys) % jnp.uint32(num_buckets)).astype(jnp.int32)
    return keys


# ---------------------------------------------------------------------------
# Local combine (the Mapper's sort+combiner, §III-A.3)
# ---------------------------------------------------------------------------

def local_combine_dense(keys: jax.Array, values: jax.Array, num_buckets: int,
                        valid: jax.Array | None = None) -> jax.Array:
    """Combine records into a dense per-bucket sum vector.

    TPU adaptation of the sorted spill + combiner: instead of comparison
    sorting, bucket-accumulate.  XLA lowers segment-sum as scatter-add; the
    Pallas ``hash_combine`` kernel does the same with one-hot MXU matmuls
    (see kernels/hash_combine).  Output is 'born sorted' by bucket id.
    """
    if valid is not None:
        vmask = valid.reshape((-1,) + (1,) * (values.ndim - 1))
        values = jnp.where(vmask, values, jnp.zeros_like(values))
        keys = jnp.where(valid, keys, 0)
    seg = jax.ops.segment_sum(values, keys.astype(jnp.int32),
                              num_segments=num_buckets)
    return seg


def sort_and_group(keys: jax.Array, values: jax.Array,
                   valid: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Key-sort records (invalid to the end) — the merged, grouped stream the
    Reducer consumes.  Returns (sorted_keys, sorted_values, group_starts) where
    ``group_starts[i]`` is 1 when a new key group begins at i."""
    if valid is None:
        valid = jnp.ones_like(keys, dtype=bool)
    sort_keys = jnp.where(valid, keys, INT32_MAX)
    order = jnp.argsort(sort_keys, stable=True)
    sk = sort_keys[order]
    sv = jnp.take(values, order, axis=0)
    starts = jnp.concatenate([
        jnp.ones((1,), dtype=jnp.int32),
        (sk[1:] != sk[:-1]).astype(jnp.int32),
    ])
    starts = jnp.where(sk == INT32_MAX, 0, starts)
    return sk, sv, starts


# ---------------------------------------------------------------------------
# Per-device accounting
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShuffleStats:
    """Per-device accounting, the analogue of the paper's bytes_in/bytes_out.

    ``bucket_collisions`` is present for hashed key spaces with collision
    tracking: per bucket, how many *extra* distinct raw keys share it
    (``distinct - 1``, clamped at 0) — exact, computed by a dedicated
    dedupe-and-count exchange (``distinct_keys_per_bucket``).
    """

    sent: jax.Array                      # records sent (valid, pre-exchange)
    dropped: jax.Array                   # records dropped by capacity overflow
    bucket_collisions: jax.Array | None = None

    @property
    def collisions(self):
        """Total colliding-key count over all buckets (0 when untracked)."""
        if self.bucket_collisions is None:
            return 0
        return jnp.sum(self.bucket_collisions)


jax.tree_util.register_pytree_node(
    ShuffleStats,
    lambda s: ((s.sent, s.dropped, s.bucket_collisions), None),
    lambda _, ch: ShuffleStats(*ch))


# ---------------------------------------------------------------------------
# The exchange (spill upload + download → all_to_all)
# ---------------------------------------------------------------------------

def build_send_buffers(keys: jax.Array, values: jax.Array, n_partitions: int,
                       capacity: int, valid: jax.Array | None = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array, ShuffleStats]:
    """Pack records into fixed (n_partitions, capacity) send buffers.

    The device analogue of writing one spill file per reducer: records are
    sorted by destination partition (so each partition's slice is contiguous
    — a 'file'), padded/truncated to ``capacity``.  Returns (send_keys,
    send_values, send_valid, stats).
    """
    n = keys.shape[0]
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    dest = jnp.where(valid, hash_partition(keys, n_partitions),
                     jnp.int32(n_partitions))  # invalid → virtual partition R
    order = jnp.argsort(dest, stable=True)
    d_sorted = dest[order]
    k_sorted = keys[order]
    v_sorted = jnp.take(values, order, axis=0)
    # position of each record within its destination group
    counts = jnp.bincount(d_sorted, length=n_partitions + 1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos_in_group = jnp.arange(n, dtype=jnp.int32) - offsets[d_sorted]
    in_cap = (pos_in_group < capacity) & (d_sorted < n_partitions)
    slot = jnp.where(in_cap, d_sorted * capacity + pos_in_group,
                     n_partitions * capacity)

    send_keys = jnp.full((n_partitions * capacity + 1,), INVALID,
                         dtype=keys.dtype)
    send_keys = send_keys.at[slot].set(jnp.where(in_cap, k_sorted, INVALID))
    val_shape = (n_partitions * capacity + 1,) + values.shape[1:]
    send_vals = jnp.zeros(val_shape, dtype=values.dtype)
    send_vals = send_vals.at[slot].set(
        jnp.where(in_cap.reshape((-1,) + (1,) * (values.ndim - 1)),
                  v_sorted, jnp.zeros_like(v_sorted)))
    send_valid = jnp.zeros((n_partitions * capacity + 1,), dtype=bool)
    send_valid = send_valid.at[slot].set(in_cap)

    sent = jnp.sum(counts[:n_partitions].astype(jnp.int32))
    kept = jnp.sum(send_valid[:-1].astype(jnp.int32))
    stats = ShuffleStats(sent=sent, dropped=sent - kept)
    return (send_keys[:-1].reshape(n_partitions, capacity),
            send_vals[:-1].reshape((n_partitions, capacity) + values.shape[1:]),
            send_valid[:-1].reshape(n_partitions, capacity),
            stats)


def exchange(send_keys: jax.Array, send_values: jax.Array,
             send_valid: jax.Array, axis_name: str
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The shuffle proper: one tiled all_to_all per tensor over the mesh axis.

    Row p of the send buffer goes to device p; row q of the result came from
    device q — i.e. every reducer receives one 'spill file' from every mapper,
    in a single ICI collective instead of 2·M·R object-store transfers.
    """
    a2a = partial(jax.lax.all_to_all, axis_name=axis_name,
                  split_axis=0, concat_axis=0, tiled=True)
    return a2a(send_keys), a2a(send_values), a2a(send_valid)


# ---------------------------------------------------------------------------
# Whole-shuffle compositions
# ---------------------------------------------------------------------------

def shuffle_group(keys: jax.Array, values: jax.Array, axis_name: str,
                  n_partitions: int, capacity: int,
                  valid: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array, jax.Array, ShuffleStats]:
    """Grouping shuffle: exchange + merge.  Per device returns the key-sorted,
    group-marked record stream for this device's partition."""
    sk, sv, svalid, stats = build_send_buffers(keys, values, n_partitions,
                                               capacity, valid)
    rk, rv, rvalid = exchange(sk, sv, svalid, axis_name)
    rk = rk.reshape(-1)
    rv = rv.reshape((-1,) + rv.shape[2:])
    rvalid = rvalid.reshape(-1)
    out_k, out_v, starts = sort_and_group(rk, rv, rvalid)
    return out_k, out_v, starts, stats


def resolve_combine_fn(combine_fn):
    """Resolve a combiner spec to a callable: ``None`` → the dense jnp
    combiner, the string ``"pallas"`` → the ``kernels/hash_combine`` one-hot
    MXU kernel (interpret mode off-TPU), a callable passes through — so
    pipeline configs can name the kernel without importing it."""
    if combine_fn == "pallas":
        from ..kernels.hash_combine.ops import make_combine_fn
        from .compile import default_pallas_interpret
        return make_combine_fn(use_pallas=True,
                               interpret=default_pallas_interpret())
    return combine_fn or local_combine_dense


def shuffle_aggregate(keys: jax.Array, values: jax.Array, axis_name: str,
                      num_buckets: int, valid: jax.Array | None = None,
                      combine_fn=None) -> jax.Array:
    """Aggregating shuffle: local combine (the combiner) + reduce_scatter.

    Each device returns its contiguous ``num_buckets / P`` slice of the fully
    reduced bucket vector — hash-partitioned ownership, exactly the paper's
    reducer assignment, fused into one collective.
    ``combine_fn(keys, values, num_buckets, valid)`` defaults to the dense jnp
    combiner; the Pallas ``hash_combine`` kernel slots in through this hook
    (pass its ``make_combine_fn(...)`` product, or just ``"pallas"``).
    """
    combine_fn = resolve_combine_fn(combine_fn)
    local = combine_fn(keys, values, num_buckets, valid)
    # reduce_scatter: sum over devices, scatter bucket ranges
    return jax.lax.psum_scatter(local, axis_name, scatter_dimension=0,
                                tiled=True)


def shuffle_aggregate_windowed(window_slots: jax.Array, keys: jax.Array,
                               values: jax.Array, axis_name: str,
                               n_slots: int, num_buckets: int,
                               valid: jax.Array | None = None,
                               combine_fn=None) -> jax.Array:
    """Windowed aggregating shuffle for the streaming engine.

    Records carry a *window slot* (a bounded ring index for an in-flight
    window) in addition to the bucket key.  The (slot, bucket) pair flattens
    into one dense id space of ``n_slots * num_buckets`` so the whole
    micro-batch still folds through a single fused ``reduce_scatter`` — the
    batch engine's combiner-in-the-collective, carried across batches.

    Each device returns its contiguous slice of the flattened
    ``(n_slots * num_buckets,) + values.shape[1:]`` update vector; the caller
    adds it to the carried window state (same layout).  Requires
    ``(n_slots * num_buckets) %`` axis size ``== 0``.
    """
    flat = window_slots.astype(jnp.int32) * num_buckets + keys.astype(jnp.int32)
    return shuffle_aggregate(flat, values, axis_name, n_slots * num_buckets,
                             valid=valid, combine_fn=combine_fn)


def bucket_owner(num_buckets: int, n_partitions: int) -> np.ndarray:
    """Host helper: which partition owns each bucket id under the aggregating
    shuffle's tiled scatter (contiguous ranges over the padded bucket
    space — see the aggregate padding in engine.plan)."""
    per = -(-num_buckets // n_partitions)
    return np.minimum(np.arange(num_buckets) // per, n_partitions - 1)


# ---------------------------------------------------------------------------
# Open key domains: exact collision accounting
# ---------------------------------------------------------------------------

def distinct_keys_per_bucket(raw_keys: jax.Array, valid: jax.Array | None,
                             axis_name: str, n_workers: int,
                             num_buckets: int) -> jax.Array:
    """Exact global per-bucket distinct-raw-key counts, as one fixed-shape
    SPMD stage.  ``bucket_collisions = max(counts - 1, 0)``.

    Three steps: (1) locally dedupe raw keys (sort + neighbor-compare);
    (2) route every locally-unique key to its owner worker
    (``hash(key) % W``) through the fixed-capacity exchange, so each distinct
    key is counted on exactly one worker; (3) dedupe again (the same key can
    arrive from several workers), bucket with the same hash the data path
    uses, scatter-add ones, and ``psum`` — ownership is disjoint, so the sum
    is exact.  ``INT32_MAX`` is reserved as the invalid sentinel.
    """
    n = raw_keys.shape[0]
    raw_keys = raw_keys.astype(jnp.int32)
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    sk = jnp.sort(jnp.where(valid, raw_keys, INT32_MAX))
    uniq = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    uniq = uniq & (sk != INT32_MAX)
    # capacity n: even if every locally-unique key hashes to one owner, the
    # per-destination buffer holds them all — the exchange cannot drop
    send_k, _, send_ok, _ = build_send_buffers(
        sk, jnp.zeros((n,), jnp.float32), n_workers, n, valid=uniq)
    a2a = partial(jax.lax.all_to_all, axis_name=axis_name,
                  split_axis=0, concat_axis=0, tiled=True)
    rk = a2a(send_k).reshape(-1)
    rok = a2a(send_ok).reshape(-1)
    rk = jnp.sort(jnp.where(rok, rk, INT32_MAX))
    owned = jnp.concatenate([jnp.ones((1,), bool), rk[1:] != rk[:-1]])
    owned = owned & (rk != INT32_MAX)
    buckets = bucketize(rk, num_buckets, hashed=True)
    buckets = jnp.where(owned, buckets, num_buckets)
    counts = jnp.zeros((num_buckets + 1,), jnp.int32).at[buckets].add(
        owned.astype(jnp.int32))[:num_buckets]
    return jax.lax.psum(counts, axis_name)


# ---------------------------------------------------------------------------
# Built-in segment reducers for grouping mode
# ---------------------------------------------------------------------------

#: built-in grouping reducer kinds — the single source of truth for
#: ``segment_reduce`` dispatch and config validation
SEGMENT_REDUCE_KINDS = ("sum", "max", "min", "count", "mean")


def segment_reduce(kind: str, keys: jax.Array, values: jax.Array,
                   starts: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Reduce a key-sorted, group-marked stream.

    Returns dense (group_keys, group_values, group_valid) of the same length
    as the input stream (padded with invalid groups) — static shapes, as TPU
    requires.  ``kind`` ∈ ``SEGMENT_REDUCE_KINDS``.
    """
    n = keys.shape[0]
    valid = keys != INT32_MAX
    seg = jnp.cumsum(starts) - 1
    seg = jnp.where(valid, seg, n)  # park invalid records on overflow row
    if kind in ("sum", "mean", "count"):
        sums = jax.ops.segment_sum(values, seg, num_segments=n + 1)
        counts = jax.ops.segment_sum(jnp.ones((n,), values.dtype), seg,
                                     num_segments=n + 1)
        if kind == "sum":
            out_v = sums
        elif kind == "count":
            out_v = counts.reshape((n + 1,) + (1,) * (values.ndim - 1)) \
                if values.ndim > 1 else counts
        else:
            out_v = sums / jnp.maximum(
                counts.reshape((-1,) + (1,) * (values.ndim - 1)), 1.0)
    elif kind == "max":
        out_v = jax.ops.segment_max(values, seg, num_segments=n + 1)
    elif kind == "min":
        out_v = jax.ops.segment_min(values, seg, num_segments=n + 1)
    else:
        raise ValueError(f"unknown segment reducer {kind!r}")

    group_keys = jnp.full((n + 1,), -1, dtype=jnp.int32).at[seg].max(
        jnp.where(valid, keys, -1))
    group_valid = group_keys[:n] >= 0
    out_v = out_v[:n]
    out_v = jnp.where(
        group_valid.reshape((-1,) + (1,) * (out_v.ndim - 1)),
        out_v, jnp.zeros_like(out_v))
    return group_keys[:n], out_v, group_valid


def apply_reduce_fn(reduce_fn, keys: jax.Array, values: jax.Array,
                    starts: jax.Array):
    """Dispatch a grouping reducer: built-in kind name or a user callable
    with the ``(keys, values, starts) -> (gk, gv, gvalid)`` contract."""
    if isinstance(reduce_fn, str):
        return segment_reduce(reduce_fn, keys, values, starts)
    return reduce_fn(keys, values, starts)


# ---------------------------------------------------------------------------
# Fixed-capacity top-k / heavy hitters over a dense aggregate
# ---------------------------------------------------------------------------

def bucket_rank_values(agg: jax.Array, kind: str) -> jax.Array:
    """Per-bucket ranking value from a ``(buckets, >=2)`` [sum, count]
    aggregate (or a 1-D sum vector): the quantity ``top_k_buckets`` orders
    by.  ``kind`` ∈ count | sum | mean (1-D input ranks by the vector)."""
    if agg.ndim == 1:
        return agg
    sums, counts = agg[..., 0], agg[..., 1]
    if kind == "count":
        return counts
    if kind == "sum":
        return sums
    if kind == "mean":
        return sums / jnp.maximum(counts, 1.0)
    raise ValueError(f"unknown top-k ranking kind {kind!r}")


def top_k_buckets(agg: jax.Array, k: int, kind: str = "sum"
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Exact top-k over a dense per-bucket aggregate — the heavy-hitters
    reduce as a fixed-capacity selection instead of a full sort + truncate.

    On closed (dense) key domains this is exact; on hashed domains it ranks
    buckets, i.e. heavy hitters up to collision merging.  Empty buckets
    (count 0, or value 0 for 1-D aggregates) never outrank occupied ones and
    come back invalid.  Ties break toward the lower bucket id
    (``jax.lax.top_k`` order), deterministically.

    Returns ``(bucket_ids, values, valid)`` of length ``k``.
    """
    values = bucket_rank_values(agg, kind)
    occupied = (agg[..., 1] > 0) if agg.ndim > 1 else (values != 0)
    masked = jnp.where(occupied, values, -jnp.inf)
    top_vals, top_ids = jax.lax.top_k(masked, k)
    valid = top_vals > -jnp.inf
    return (top_ids.astype(jnp.int32),
            jnp.where(valid, top_vals, 0.0), valid)


# ---------------------------------------------------------------------------
# Carry handoff (multi-stage chains + DAG fan-out: one plan's finalized
# windows feed one or more successor plans, one call per edge)
# ---------------------------------------------------------------------------

def carry_handoff_rows(agg: jax.Array, relabel: jax.Array,
                       last_window: jax.Array, n_windows: jax.Array,
                       kind: str, n_rows: int,
                       channel_base: int = 0) -> jax.Array:
    """One finalized window's dense aggregate → a successor plan's wire
    rows.  Pure per-edge function: a teed stage runs it once per out-edge
    with that edge's own ``relabel`` table, fanning the same slot into
    several downstream carries.

    ``agg`` is the (num_buckets, channels) slice of a finalized window;
    its ``[sum, count]`` pair lives at ``channel_base``.  Each occupied
    bucket becomes one device-fan-out wire row ``[last_window, n_windows,
    key, value, valid]`` for the *next* stage's plan: ``relabel`` maps
    this plan's bucket ids to the next key space (a dense id or a raw
    hashed-wire id; ``< 0`` marks unassigned buckets), ``last_window`` /
    ``n_windows`` are the re-windowed span of the finalized window's
    timestamp (scalars — every row of one handoff shares them), and the
    value is the finalized aggregate per ``kind`` (count | sum | mean).
    Output is padded to ``n_rows`` with invalid rows, so the next plan's
    step compiles once.  The emitted aggregates never visit the host —
    this is the reduce → map → window → reduce seam of a multi-stage
    chain.
    """
    sums = agg[:, channel_base]
    counts = agg[:, channel_base + 1]
    if kind == "count":
        value = counts
    elif kind == "sum":
        value = sums
    elif kind == "mean":
        value = sums / jnp.maximum(counts, 1.0)
    else:
        raise ValueError(f"unknown handoff aggregate kind {kind!r}")
    valid = (counts > 0) & (relabel >= 0)
    n = agg.shape[0]
    last = jnp.full((n,), last_window, jnp.float32)
    nw = jnp.full((n,), n_windows, jnp.float32)
    rows = jnp.stack([last, nw, relabel.astype(jnp.float32),
                      value.astype(jnp.float32),
                      valid.astype(jnp.float32)], axis=-1)
    return jnp.zeros((n_rows, 5), jnp.float32).at[:n].set(rows)


# ---------------------------------------------------------------------------
# On-device sliding-window fan-out (broadcast + iota)
# ---------------------------------------------------------------------------

def window_fanout(last_index: jax.Array, n_windows: jax.Array,
                  keys: jax.Array, values: jax.Array, valid: jax.Array,
                  fanout: int, n_slots: int, min_window: jax.Array):
    """Replicate each record into its overlapping windows on-chip.

    A record crosses host→device once, carrying only the index of the last
    (latest-starting) window containing it and how many consecutive windows
    do (1..fanout) — pure host float64 boundary math, no expansion.  The
    stage broadcasts every record ``fanout`` ways and masks with iota
    arithmetic: copy j covers window ``last_index - j`` and is live when
    ``j < n_windows``; windows below ``min_window`` already finalized, so
    those copies are masked late (and counted, for the watermark books).
    Ring slots are modular (``window % n_slots``) — the host tracker uses
    the same rule, so no slot table crosses the boundary.

    Returns flattened ``(n * fanout,)`` (slots, keys, values, live) plus
    scalar (late_pairs, admitted_pairs) counters.
    """
    n = last_index.shape[0]
    j = jax.lax.iota(jnp.int32, fanout)                       # (F,)
    widx = last_index.astype(jnp.int32)[:, None] - j[None, :]  # (n, F)
    covers = valid[:, None] & (j[None, :] < n_windows.astype(jnp.int32)[:, None])
    live = covers & (widx >= min_window)
    late = jnp.sum((covers & (widx < min_window)).astype(jnp.int32))
    slots = jnp.mod(widx, n_slots)
    keys_f = jnp.broadcast_to(keys.astype(jnp.int32)[:, None], (n, fanout))
    vshape = (n, fanout) + values.shape[1:]
    values_f = jnp.broadcast_to(values[:, None], vshape)
    return (slots.reshape(-1), keys_f.reshape(-1),
            values_f.reshape((n * fanout,) + values.shape[1:]),
            live.reshape(-1), late, jnp.sum(live.astype(jnp.int32)))


# ---------------------------------------------------------------------------
# Windowed group-mode record buffers (fixed-capacity, carried across batches)
# ---------------------------------------------------------------------------

def append_window_records(keys_buf: jax.Array, vals_buf: jax.Array,
                          counts: jax.Array, flat_keys: jax.Array,
                          values: jax.Array, valid: jax.Array,
                          n_slots: int, capacity: int, num_buckets: int):
    """Append exchanged (slot, bucket) records into per-slot ring buffers.

    ``keys_buf`` (n_slots, capacity) int32 (INVALID = empty), ``vals_buf``
    (n_slots, capacity), ``counts`` (n_slots,) — this worker's slice of the
    grouping carry.  Incoming records are slot-sorted so each record's write
    position is ``counts[slot] + rank_within_slot``; overflow beyond
    ``capacity`` is dropped and counted (the spill-file size bound).
    Returns (keys_buf, vals_buf, counts, dropped).
    """
    m = flat_keys.shape[0]
    slot = jnp.where(valid, flat_keys // num_buckets, jnp.int32(n_slots))
    key = jnp.mod(flat_keys, num_buckets)
    order = jnp.argsort(slot, stable=True)
    s = slot[order]
    k = key[order]
    v = jnp.take(values, order, axis=0)
    per_slot = jnp.bincount(s, length=n_slots + 1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(per_slot)[:-1].astype(jnp.int32)])
    base = jnp.concatenate([counts, jnp.zeros((1,), counts.dtype)])
    pos = base[s] + (jnp.arange(m, dtype=jnp.int32) - offsets[s])
    ok = (s < n_slots) & (pos < capacity)
    dst = jnp.where(ok, s * capacity + pos, n_slots * capacity)

    kb = jnp.concatenate([keys_buf.reshape(-1), jnp.full((1,), INVALID)])
    kb = kb.at[dst].set(jnp.where(ok, k, INVALID))
    vb = jnp.concatenate(
        [vals_buf.reshape((-1,) + vals_buf.shape[2:]),
         jnp.zeros((1,) + vals_buf.shape[2:], vals_buf.dtype)])
    vb = vb.at[dst].set(jnp.where(
        ok.reshape((-1,) + (1,) * (v.ndim - 1)), v, jnp.zeros_like(v)))
    new_counts = jnp.minimum(counts + per_slot[:n_slots].astype(counts.dtype),
                             capacity)
    dropped = jnp.sum(per_slot[:n_slots]).astype(jnp.int32) - \
        jnp.sum(ok.astype(jnp.int32))
    return (kb[:-1].reshape(n_slots, capacity),
            vb[:-1].reshape((n_slots, capacity) + vals_buf.shape[2:]),
            new_counts, dropped)


def gather_window_group(keys_buf: jax.Array, vals_buf: jax.Array,
                        slot, axis_name: str, reduce_fn):
    """Finalize one window of the grouping carry: gather the slot's buffered
    records from every worker (the Finalizer's stream-concat, as one
    ``all_gather``), merge-sort, and run the grouping reducer over each
    key's full value list.  Replicated output."""
    k = jax.lax.dynamic_slice_in_dim(keys_buf, slot, 1, axis=0)[0]
    v = jax.lax.dynamic_slice_in_dim(vals_buf, slot, 1, axis=0)[0]
    gk = jax.lax.all_gather(k, axis_name, tiled=True)
    gv = jax.lax.all_gather(v, axis_name, tiled=True)
    sk, sv, starts = sort_and_group(gk, gv, valid=gk >= 0)
    return apply_reduce_fn(reduce_fn, sk, sv, starts)


def clear_window_group(keys_buf: jax.Array, vals_buf: jax.Array,
                       counts: jax.Array, slot):
    """Reset one slot of the grouping carry so its ring slot can be reused."""
    keys_buf = jax.lax.dynamic_update_slice_in_dim(
        keys_buf, jnp.full((1,) + keys_buf.shape[1:], INVALID), slot, axis=0)
    vals_buf = jax.lax.dynamic_update_slice_in_dim(
        vals_buf, jnp.zeros((1,) + vals_buf.shape[1:], vals_buf.dtype),
        slot, axis=0)
    counts = jax.lax.dynamic_update_slice(
        counts, jnp.zeros((1,), counts.dtype), (slot,))
    return keys_buf, vals_buf, counts
