"""Tokenization for the data pipeline.

The paper's workload is word counting over preprocessed Wikipedia text
(§IV-B: lowercase, punctuation stripped, whitespace collapsed).  We keep the
same preprocessing, and two tokenizers:

  * ``HashTokenizer`` — stateless word→id via the same FNV-1a the shuffle
    uses; no vocabulary pass needed (ids are hash buckets).  This feeds the
    device word-count job and LM toy training.
  * ``build_vocab`` — an exact vocabulary built *by a MapReduce job* (word
    count → top-K), which is the paper's own pipeline eating its own output.
"""

from __future__ import annotations

import re
import string

_PUNCT = str.maketrans("", "", string.punctuation)
_WS = re.compile(r"\s+")


def preprocess(text: str) -> str:
    """The paper's locality preprocessing (§IV-B)."""
    return _WS.sub(" ", text.lower().translate(_PUNCT)).strip()


def fnv1a(word: str) -> int:
    h = 0xCBF29CE484222325
    for b in word.encode():
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


class HashTokenizer:
    """word → hash bucket in [0, vocab).  Deterministic, collision-accepting
    (documented: counts are per-bucket when collisions occur)."""

    def __init__(self, vocab: int) -> None:
        self.vocab = vocab

    def encode_words(self, words: list[str]) -> list[int]:
        return [fnv1a(w) % self.vocab for w in words]

    def encode(self, text: str) -> list[int]:
        return self.encode_words(preprocess(text).split())


def build_vocab(counts: dict[str, int], max_size: int) -> dict[str, int]:
    """Exact vocab from word counts (a MapReduce output): most frequent
    first, ties broken lexicographically; id 0 reserved for <unk>."""
    ordered = sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))
    vocab = {"<unk>": 0}
    for w, _ in ordered[: max_size - 1]:
        vocab[w] = len(vocab)
    return vocab
