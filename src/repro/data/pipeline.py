"""Training-data pipeline: byte-range sharded reading → tokens → packed
(B, S) batches, with host-side prefetch.

The reader consumes the *same* Splitter output as the MapReduce Mappers
(DESIGN.md §2): each data-parallel host owns a byte-range assignment fetched
by ranged GET, so adding hosts re-splits rather than re-copies.  Packing is
drop-remainder fixed-length next-token prediction.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

from ..core.splitter import ByteRange, split_prefix
from ..core.storage import MemoryStore, ObjectStore
from .tokenizer import HashTokenizer, preprocess


def synth_corpus(n_words: int, vocab_words: int = 1000, seed: int = 0,
                 zipf: float = 1.3) -> str:
    """Zipf-distributed synthetic corpus (stands in for the paper's
    preprocessed Wikipedia dump — same locality statistics shape)."""
    rng = np.random.default_rng(seed)
    ranks = rng.zipf(zipf, size=n_words)
    ranks = np.clip(ranks, 1, vocab_words)
    return " ".join(f"w{r}" for r in ranks)


class PackedLMDataset:
    """Iterates (inputs, labels) int32 (B, S) batches for one data-parallel
    host, reading its byte-range shard through the object store."""

    def __init__(self, store: ObjectStore, prefix: str, tokenizer: HashTokenizer,
                 batch: int, seq_len: int, host_id: int = 0, n_hosts: int = 1,
                 read_chunk: int = 1 << 20, seed: int = 0,
                 sep: bytes = b" ") -> None:
        self.store = store
        self.tokenizer = tokenizer
        self.batch = batch
        self.seq_len = seq_len
        # preprocessed corpora (§IV-B) are single space-separated streams, so
        # the record separator for boundary extension is the space
        assignments = split_prefix(store, prefix, n_hosts, sep=sep)
        self.ranges: list[ByteRange] = assignments[host_id]
        if not self.ranges:
            raise ValueError(
                f"host {host_id}/{n_hosts} received no byte ranges — input "
                f"under {prefix!r} is too small or not splittable")
        self.read_chunk = read_chunk
        self.rng = np.random.default_rng(seed + host_id)

    def _token_stream(self) -> Iterator[int]:
        while True:  # epoch loop
            for r in self.ranges:
                lo = r.lo
                carry = ""
                while lo < r.hi:
                    hi = min(lo + self.read_chunk, r.hi)
                    text = carry + self.store.get(r.key, (lo, hi)).decode(
                        "utf-8", "replace")
                    lo = hi
                    # keep the trailing partial word for the next chunk
                    if lo < r.hi and not text[-1].isspace():
                        text, _, carry = text.rpartition(" ")
                    else:
                        carry = ""
                    yield from self.tokenizer.encode(text)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        stream = self._token_stream()
        need = self.batch * (self.seq_len + 1)
        buf: list[int] = []
        while True:
            while len(buf) < need:
                buf.append(next(stream))
            block = np.asarray(buf[:need], dtype=np.int32).reshape(
                self.batch, self.seq_len + 1)
            buf = buf[need:]
            yield {"inputs": block[:, :-1], "labels": block[:, 1:]}


class Prefetcher:
    """Host-side prefetch: overlaps data preparation with the device step —
    the download/processing overlap the paper measures, applied to training."""

    def __init__(self, it: Iterator, depth: int = 2) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def make_store_with_corpus(n_words: int, key: str = "input/corpus.txt",
                           **kw) -> tuple[MemoryStore, str]:
    store = MemoryStore()
    store.put(key, preprocess(synth_corpus(n_words, **kw)).encode())
    return store, "input/"
