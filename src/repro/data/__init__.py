from .pipeline import PackedLMDataset, Prefetcher, synth_corpus
from .tokenizer import HashTokenizer, build_vocab

__all__ = ["PackedLMDataset", "Prefetcher", "synth_corpus", "HashTokenizer",
           "build_vocab"]
