"""Training launcher.

CPU-scale by default (reduced config, local mesh) so the example drivers run
in this container; the production path (full config, 16×16 or 2×16×16 mesh)
is exercised by the dry-run.  All the fault-tolerance machinery (async
checkpoints, restart, retries) is live in either mode.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --reduced \
      --steps 200 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import json

from repro import configs
from repro.core.metadata import MetadataStore
from repro.core.storage import FileStore, MemoryStore
from repro.data import HashTokenizer, PackedLMDataset, Prefetcher
from repro.data.pipeline import make_store_with_corpus
from repro.optim import AdamW
from repro.optim.schedule import cosine_schedule
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None,
                    help="filesystem checkpoint dir (default: in-memory)")
    ap.add_argument("--corpus-words", type=int, default=500_000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced \
        else configs.get(args.arch)
    if cfg.input_mode == "embeddings":
        raise SystemExit(f"{args.arch} trains on frontend embeddings; use "
                         "examples/train_lm.py for token-LM training")

    corpus_store, prefix = make_store_with_corpus(args.corpus_words)
    tok = HashTokenizer(cfg.vocab)
    ds = PackedLMDataset(corpus_store, prefix, tok, batch=args.batch,
                         seq_len=args.seq, seed=args.seed)
    batches = Prefetcher(iter(ds))

    opt = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps),
                weight_decay=0.1)
    ckpt_store = FileStore(args.ckpt_dir) if args.ckpt_dir else MemoryStore()
    trainer = Trainer(
        cfg, opt, ckpt_store, MetadataStore(),
        TrainerConfig(checkpoint_every=args.ckpt_every,
                      microbatches=args.microbatches),
        seed=args.seed)
    print(f"[train] {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"resuming from step {trainer.start_step}")
    trainer.run(batches, args.steps)
    for m in trainer.metrics_log:
        print(json.dumps(m))


if __name__ == "__main__":
    main()
