"""Production meshes (TPU v5e pods).

Functions, not module constants — importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before any jax initialization).

  single-pod : (data=16, model=16)            = 256 chips
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips

Axis roles: ``data`` carries batch DP + FSDP parameter sharding; ``model``
carries TP (hidden/heads/vocab) and sequence sharding for long KV caches;
``pod`` is pure DP across pods (DCN-ish boundary — gradient reduction only).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(n_devices: int | None = None,
                   axis_name: str = "data") -> jax.sharding.Mesh:
    """Small local mesh over whatever devices exist (tests/examples)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), (axis_name,),
                         axis_types=(jax.sharding.AxisType.Auto,))


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The batch axes of a mesh: ('pod', 'data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
