"""Serving launcher: batched decode with a request queue, plus the
job-service RPC front end.

CPU-scale driver (reduced configs) demonstrating the serving loop the
decode_32k / long_500k dry-run cells lower at production scale: prefill on
arrival, then batched one-token steps over the active set (continuous
batching-lite: finished sequences free their slot for queued requests).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --reduced \
      --requests 16 --max-new 32

:class:`JobRPC` is the same skeleton pointed at the multi-tenant job
server (``repro.service``): method-dispatch requests — the paper's
HTTP-trigger role — onto the control plane's submit/pause/resume/cancel/
status verbs, with programs referenced by registered name because a
compiled ``BuiltPipeline`` never crosses the wire (the paper ships a JSON
job config, not code).  :class:`JobSocketServer` puts that dispatch
behind a real TCP socket (length-prefixed JSON frames — see
``repro.core.rpc``) so a ``JobServiceClient(address=...)`` in another
process can drive the control plane.
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.rpc import FrameServer
from repro.models import decode_step, init_cache, init_params


@dataclass
class Request:
    id: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    tokens: list[int] = field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Fixed-slot batched decoder.  Each slot holds one active request;
    queue admission happens between steps (scale-from-zero per slot — an
    idle server holds no cache memory until requests arrive)."""

    def __init__(self, cfg, params, n_slots: int, max_len: int,
                 eos: int | None = None) -> None:
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos = eos
        self.cache = None             # allocated on first admission
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []
        self._decode = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                if self.cache is None:
                    self.cache = init_cache(self.cfg, self.n_slots,
                                            self.max_len)
                # per-slot prefill: run the prompt through decode steps
                for tok in req.prompt[:-1]:
                    t = jnp.full((self.n_slots, 1), int(tok), jnp.int32)
                    _, cache_new = self._decode(self.params, self.cache, t)
                    # only this slot's cache lanes advance
                    self.cache = jax.tree.map(
                        lambda new, old: _merge_slot(new, old, i),
                        cache_new, self.cache)
                req.tokens = [int(req.prompt[-1])]
                self.slots[i] = req

    def step(self) -> int:
        """One batched decode step over all active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        toks = np.zeros((self.n_slots, 1), np.int32)
        for i, r in enumerate(self.slots):
            if r is not None:
                toks[i, 0] = r.tokens[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            r = self.slots[i]
            r.tokens.append(int(nxt[i]))
            if len(r.tokens) - 1 >= r.max_new or (
                    self.eos is not None and int(nxt[i]) == self.eos):
                r.done = True
                self.slots[i] = None       # free the slot (scale down)
        return len(active)


class JobRPC:
    """Transport-less RPC dispatch onto the multi-tenant job server.

    One ``handle({"method": ..., ...params})`` call per request, answers
    ``{"ok": True, "result": ...}`` or ``{"ok": False, "error": ...}`` —
    the wire shape an HTTP trigger would carry, minus the socket.  A
    compiled ``BuiltPipeline`` never crosses this boundary: ``register``
    binds a program under a name server-side, and ``submit`` requests
    reference that name (the paper submits a JSON job config the same
    way).  Status polls answer purely from the metadata records, so a
    monitoring process needs no server handle at all.
    """

    METHODS = ("register", "submit", "pause", "resume", "cancel",
               "status", "jobs", "stats", "drain")

    def __init__(self, server) -> None:
        self.server = server
        self.programs: dict[str, object] = {}

    def register(self, name: str, program) -> None:
        """Server-side program registry: name → BuiltPipeline."""
        self.programs[name] = program

    def handle(self, request: dict) -> dict:
        method = request.get("method")
        params = {k: v for k, v in request.items() if k != "method"}
        if method not in self.METHODS:
            return {"ok": False,
                    "error": f"unknown method: {method!r}"}
        try:
            return {"ok": True, "result": getattr(self, f"_{method}")(
                **params)}
        except Exception as exc:                    # noqa: BLE001 — RPC edge
            return {"ok": False, "error": f"{type(exc).__name__}: {exc}"}

    # -- verbs ---------------------------------------------------------------
    def _register(self, name, program):
        self.register(name, program)
        return name

    def _submit(self, tenant, program, source_prefix, resume=False,
                partitions=None):
        if program not in self.programs:
            raise KeyError(f"no program registered as {program!r}")
        return self.server.submit(tenant, self.programs[program],
                                  source_prefix=source_prefix,
                                  resume=resume, partitions=partitions)

    def _pause(self, job_id):
        self.server.pause(job_id)
        return self.server.status(job_id)["state"]

    def _resume(self, job_id):
        self.server.resume(job_id)
        return self.server.status(job_id)["state"]

    def _cancel(self, job_id):
        self.server.cancel(job_id)
        return self.server.status(job_id)["state"]

    def _status(self, job_id):
        return self.server.status(job_id)

    def _jobs(self):
        return self.server.registry.jobs()

    def _stats(self):
        return self.server.stats()

    def _drain(self):
        return self.server.run_until_complete()


class JobSocketServer(FrameServer):
    """The job-service control plane behind a real TCP socket.

    Wraps a :class:`JobRPC` in a :class:`~repro.core.rpc.FrameServer`:
    each client connection exchanges length-prefixed JSON frames, every
    frame is one ``JobRPC.handle`` dispatch, and all dispatches are
    serialized under the transport's lock (the job server is
    single-threaded by design).  ``port=0`` binds an ephemeral port —
    read ``address`` back and hand it to ``JobServiceClient(address=...)``
    in another process.  Usable as a context manager::

        rpc = JobRPC(server)
        rpc.register("hourly-avg", program)
        with JobSocketServer(rpc) as srv:
            print("serving on", srv.address)
            ...
    """

    def __init__(self, rpc: JobRPC, host: str = "127.0.0.1",
                 port: int = 0) -> None:
        super().__init__(rpc.handle, host=host, port=port)
        self.rpc = rpc


def _merge_slot(new, old, slot: int):
    """Keep ``new``'s cache values only on the admitted slot's batch lane.

    Batch axis convention: lengths are (B,), layer-stacked caches are
    (L, B, ...) — axis 0 or 1 respectively.
    """
    ax = 0 if new.ndim == 1 else 1
    idx = tuple(slice(slot, slot + 1) if a == ax else slice(None)
                for a in range(new.ndim))
    return old.at[idx].set(new[idx])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch)
    if cfg.input_mode == "embeddings":
        raise SystemExit(f"{args.arch} serves embeddings; this driver is for "
                         "token LMs")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    server = BatchedServer(cfg, params, args.slots, args.max_len)

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        server.submit(Request(
            id=i, prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                      dtype=np.int32),
            max_new=args.max_new))

    t0 = time.perf_counter()
    steps = tokens = 0
    while any(server.slots) or server.queue:
        n = server.step()
        tokens += n
        steps += 1
        if steps > 10_000:
            raise RuntimeError("serving did not drain")
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: {args.requests} requests, {tokens} tokens in "
          f"{dt:.2f}s ({tokens/dt:.1f} tok/s, {steps} batched steps)")


if __name__ == "__main__":
    main()
