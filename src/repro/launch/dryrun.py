import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count at first init).  For each cell this driver:

  1. builds the production mesh (single-pod 16×16 or multi-pod 2×16×16),
  2. resolves the arch config and the step function the shape dictates
     (train_4k → train_step; prefill_32k → prefill_forward;
      decode_32k / long_500k → decode_step),
  3. constructs ShapeDtypeStruct stand-ins for every input (no allocation),
  4. jit-lowers with the Planner's in/out shardings and **compiles**,
  5. records memory_analysis / cost_analysis / per-collective bytes parsed
     from the optimized HLO → EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
  python -m repro.launch.dryrun --sweep --out results/dryrun     # all cells
"""

import argparse
import json
import re
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import (ModelConfig, SHAPES_BY_NAME, decode_step,
                          init_cache, init_params, prefill_forward,
                          shapes_for)
from repro.optim import AdamW
from repro.runtime.train_step import init_train_state, make_train_step
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import Planner

# microbatch counts for train_4k: global batch 256 → 8 microbatches of 32
TRAIN_MICROBATCHES = 8

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
                "s16": 2, "u16": 2}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum per-device result bytes of every collective op in optimized HLO.

    Lines look like:  %all-reduce.1 = f32[128,4096]{1,0} all-reduce(...)
    (tuple-shaped collectives contribute each tuple element).
    """
    out: dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    out["count"] = 0
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(\(?[^=]*?)\s*(all-gather|all-reduce|"
                      r"reduce-scatter|all-to-all|collective-permute)\(",
                      line)
        if not m:
            continue
        kind = m.group(2)
        shapes_part = line.split("=", 1)[1].split(kind + "(")[0]
        nbytes = 0
        for dt, dims in shape_re.findall(shapes_part):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
        out["count"] += 1
    out["total"] = sum(out[c] for c in COLLECTIVES)
    return out


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell —
    weak-type-correct, shardable, zero allocation."""
    cfg = configs.get(arch)
    shape = SHAPES_BY_NAME[shape_name]
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    emb = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    if shape.kind == "train":
        mb = TRAIN_MICROBATCHES
        bm = b // mb
        if cfg.input_mode == "embeddings":
            inputs = jax.ShapeDtypeStruct((mb, bm, s, cfg.d_model),
                                          jnp.bfloat16)
        else:
            inputs = jax.ShapeDtypeStruct((mb, bm, s), jnp.int32)
        return {"inputs": inputs,
                "labels": jax.ShapeDtypeStruct((mb, bm, s), jnp.int32)}
    if shape.kind == "prefill":
        return {"inputs": emb if cfg.input_mode == "embeddings" else tok}
    # decode: one new token against a cache of length seq_len
    if cfg.input_mode == "embeddings":
        return {"token": jax.ShapeDtypeStruct((b, 1, cfg.d_model),
                                              jnp.bfloat16)}
    return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def build_cell(arch: str, shape_name: str, mesh,
               cfg: ModelConfig | None = None, mode: str = "deploy",
               opts: dict | None = None):
    """→ (fn, example_args (ShapeDtypeStructs), in_shardings, out_shardings).

    mode="deploy": the production program (layer scan + remat + microbatch
    accumulation) — this is the compile proof and the memory_analysis source.
    mode="account": XLA's cost model counts while-loop bodies once, so the
    accounting variant unrolls the layer scan, widens attention chunks to the
    full sequence, and (for train) lowers ONE microbatch — run_cell scales
    its numbers back to a full step (×TRAIN_MICROBATCHES; exact, since
    microbatches are identical programs).
    """
    cfg = cfg or configs.get(arch)
    opts = opts or {}
    shape = SHAPES_BY_NAME[shape_name]
    planner = Planner(mesh, cfg, opts)
    b, s = shape.global_batch, shape.seq_len
    ins = input_specs(arch, shape_name)

    if shape.kind == "train":
        opt = AdamW(lr=1e-4)
        state_shape = jax.eval_shape(
            partial(init_train_state, cfg=cfg, opt=opt),
            jax.random.PRNGKey(0))
        state_specs = planner.state_specs(state_shape)
        grad_specs = planner.grad_specs(state_shape.params) \
            if opts.get("zero2") else None
        if mode == "account":
            # one microbatch, flat batch axis
            mb_b = b // TRAIN_MICROBATCHES
            if cfg.input_mode == "embeddings":
                ins = {"inputs": jax.ShapeDtypeStruct(
                    (mb_b, s, cfg.d_model), jnp.bfloat16),
                    "labels": jax.ShapeDtypeStruct((mb_b, s), jnp.int32)}
            else:
                ins = {"inputs": jax.ShapeDtypeStruct((mb_b, s), jnp.int32),
                       "labels": jax.ShapeDtypeStruct((mb_b, s), jnp.int32)}
            batch_specs = planner.batch_spec(microbatched=False)
            fn = make_train_step(cfg, opt, microbatches=1,
                                 grad_specs=grad_specs)
        else:
            batch_specs = planner.batch_spec(microbatched=True)
            fn = make_train_step(cfg, opt, microbatches=TRAIN_MICROBATCHES,
                                 grad_specs=grad_specs)
        args = (state_shape, ins)
        in_sh = (planner.to_shardings(state_specs),
                 planner.to_shardings(batch_specs))
        out_sh = (planner.to_shardings(state_specs), None)
        return fn, args, in_sh, out_sh

    params_shape = jax.eval_shape(partial(init_params, cfg=cfg),
                                  jax.random.PRNGKey(0))
    param_specs = planner.param_specs(params_shape)

    if shape.kind == "prefill":
        cache_shape = jax.eval_shape(partial(init_cache, cfg, b, s))
        cache_specs = planner.cache_specs(cache_shape, b)
        fn = partial(prefill_forward, cfg=cfg, max_len=s)
        args = (params_shape, ins["inputs"])
        in_sh = (planner.to_shardings(param_specs),
                 planner.shard(planner.token_spec(b)))
        out_sh = (None, planner.to_shardings(cache_specs))
        return fn, args, in_sh, out_sh

    # decode
    cache_shape = jax.eval_shape(partial(init_cache, cfg, b, s))
    cache_specs = planner.cache_specs(cache_shape, b)
    fn = partial(decode_step, cfg=cfg)
    args = (params_shape, cache_shape, ins["token"])
    in_sh = (planner.to_shardings(param_specs),
             planner.to_shardings(cache_specs),
             planner.shard(planner.token_spec(b)))
    out_sh = (None, planner.to_shardings(cache_specs))
    return fn, args, in_sh, out_sh


def _compile_variant(arch: str, shape_name: str, mesh, cfg, mode: str,
                     opts: dict | None = None):
    from repro.models.shardctx import activation_sharding
    from repro.launch.mesh import data_axes

    opts = opts or {}
    t0 = time.time()
    fn, args, in_sh, out_sh = build_cell(arch, shape_name, mesh, cfg, mode,
                                         opts)
    mcfg = cfg or configs.get(arch)
    shape = SHAPES_BY_NAME[shape_name]
    dp = data_axes(mesh)
    import numpy as np
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    # per-microbatch batch for train cells; full batch otherwise
    eff_batch = (shape.global_batch // TRAIN_MICROBATCHES
                 if shape.kind == "train" else shape.global_batch)
    batch_axes = dp if eff_batch % dp_size == 0 else (
        "data" if eff_batch % mesh.shape["data"] == 0 else None)
    donate = ()
    if shape.kind == "train":
        donate = (0,)            # state buffers reused across steps
    elif shape.kind == "decode":
        donate = (1,)            # cache updated in place
    seq_axis = "model" if opts.get("act") == "sp" else None
    tp_for_act = None if opts.get("act") in ("sp", "rep") else "model"
    from repro.models.layers import matmul_reduce_dtype
    import contextlib as _cl
    red = (matmul_reduce_dtype(jnp.bfloat16) if opts.get("bf16_reduce")
           else _cl.nullcontext())
    dp_for_moe = dp_size if (batch_axes is not None
                             and eff_batch % dp_size == 0) else 1
    with jax.set_mesh(mesh), red, activation_sharding(
            batch_axes, tp_for_act, mesh.shape["model"], eff_batch,
            mcfg.d_model, mcfg.vocab, seq_axis=seq_axis,
            dp_size=dp_for_moe):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return lowered, compiled, round(t_lower, 1), round(t_compile, 1)


def _layer_period(cfg: ModelConfig) -> int:
    """Smallest repeating structural unit of the layer stack."""
    if cfg.shared_attn_every > 0:
        return cfg.shared_attn_every        # zamba2: 6 mamba + 1 shared attn
    if cfg.window_pattern == "alternate":
        return 2                             # gemma2: local/global pair
    return 1


def account_costs(arch: str, shape_name: str, mesh,
                  cfg: ModelConfig | None = None,
                  opts: dict | None = None) -> dict:
    """Exact per-step FLOPs / HBM / collective bytes via two-point
    extrapolation.

    XLA's cost model counts while-loop bodies once, so the deploy program's
    numbers are useless.  Unrolling the full stack compiles in ~10 min/cell
    on this 1-core box; instead we exploit homogeneity: lower the *unrolled*
    stack at L = period and L = 2·period (seconds each).  Layers are
    structurally identical across periods, so

        cost(L) = fixed + (L / period) · per_period
        per_period = cost(2p) − cost(p);  fixed = cost(p) − per_period

    is exact for FLOPs/bytes up to XLA's local fusion decisions (validated:
    see EXPERIMENTS.md §Dry-run methodology).  Train cells lower one
    microbatch (flat batch) and scale ×TRAIN_MICROBATCHES — microbatches are
    identical programs.  Residual undercounts that live inside *data* loops
    (attention chunk scan, mamba time scan) are corrected analytically in
    benchmarks/roofline.py.
    """
    base = cfg or configs.get(arch)
    shape = SHAPES_BY_NAME[shape_name]
    p = _layer_period(base)
    t0 = time.time()

    def costs_at(n_layers: int):
        c = base.replace(n_layers=n_layers, unroll_layers=True)
        _, compiled, _, _ = _compile_variant(arch, shape_name, mesh, c,
                                             "account", opts)
        cost = compiled.cost_analysis()
        coll = parse_collective_bytes(compiled.as_text())
        return {"flops": cost.get("flops", 0.0),
                "hbm": cost.get("bytes accessed", 0.0),
                **{f"coll_{k}": v for k, v in coll.items()}}

    c1, c2 = costs_at(p), costs_at(2 * p)
    periods = base.n_layers / p
    scale = TRAIN_MICROBATCHES if shape.kind == "train" else 1

    def extrapolate(key):
        per = max(0.0, c2[key] - c1[key])   # clamp fusion-noise negatives
        fixed = max(0.0, c1[key] - per)
        return (fixed + periods * per) * scale

    coll_keys = [k for k in c1 if k.startswith("coll_")]
    return {
        "account_compile_s": round(time.time() - t0, 1),
        "account_period": p,
        "step_scale": scale,
        "flops_per_device": extrapolate("flops"),
        "hbm_bytes_per_device": extrapolate("hbm"),
        "collective_bytes_per_device": {
            k[5:]: extrapolate(k) for k in coll_keys},
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             cfg: ModelConfig | None = None, verbose: bool = True,
             account: bool = True, opts: dict | None = None) -> dict:
    """Compile the deploy variant (proof + memory) and, when ``account``,
    the unrolled accounting variant (exact FLOPs/collectives)."""
    mesh = make_production_mesh(multi_pod=multi_pod)

    _, compiled, t_lower, t_compile = _compile_variant(
        arch, shape_name, mesh, cfg, "deploy", opts)
    mem = compiled.memory_analysis()
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "devices": mesh.size,
        "lower_s": t_lower,
        "compile_s": t_compile,
        # memory_analysis is per-device on the SPMD module
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "outputs": mem.output_size_in_bytes,
            "temps": mem.temp_size_in_bytes,
            "aliased": mem.alias_size_in_bytes,
            "peak_estimate": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
    }

    if account:
        result.update(account_costs(arch, shape_name, mesh, cfg, opts))
    if verbose:
        gb = result["bytes_per_device"]["peak_estimate"] / 2**30
        extra = ""
        if account:
            extra = (f", {result['flops_per_device']/1e12:.2f} TFLOP/dev, "
                     f"coll {result['collective_bytes_per_device']['total']/2**30:.2f} GiB/dev")
        print(f"[dryrun] {arch} × {shape_name} × "
              f"{'multi' if multi_pod else 'single'}: compile OK "
              f"({t_compile}s, peak ≈ {gb:.2f} GiB/dev{extra})")
    return result


def sweep_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        for shape in shapes_for(cfg):
            cells.append((arch, shape.name))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = sweep_cells() if args.sweep else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[dryrun] {tag}: cached")
                continue
            try:
                # roofline accounting is single-pod only (§Roofline);
                # the multi-pod pass is the sharding/compile proof
                res = run_cell(arch, shape, multi, account=not multi)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
            except Exception as exc:
                failures.append((tag, str(exc)))
                print(f"[dryrun] {tag}: FAILED — {exc}")
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: "
                         + ", ".join(t for t, _ in failures))
    print("[dryrun] all cells compiled")


if __name__ == "__main__":
    main()
