"""Sharding planner: ModelConfig + mesh → PartitionSpec trees.

Strategy (DESIGN.md §6):
  * ``model`` axis — tensor parallelism: shard d_ff / fused-head / vocab
    columns, contract row-parallel back (plus sequence sharding for KV
    caches at serving time);
  * ``data`` axis — batch DP and FSDP: parameters store their *other* big
    dim sharded over ``data`` and are all-gathered at use (GSPMD inserts the
    gathers; gradients come back as reduce-scatter) — fully-sharded optimizer
    state falls out because moments mirror params;
  * ``pod`` axis — pure DP: params replicated across pods, batch split,
    gradient all-reduce crosses the pod boundary once per step.

Every rule checks divisibility and falls back to replication on that dim —
non-divisible cases (56 heads, 60 experts, odd vocab) compile correctly and
show up in the roofline as the padding/replication cost they are.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import ModelConfig
from .mesh import data_axes


def _axis_size(mesh: jax.sharding.Mesh, name: str | tuple) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


class Planner:
    """opts (hillclimb knobs, see EXPERIMENTS.md §Perf):
      zero2        — params replicated over data (one gather per step at the
                     update instead of per-microbatch); moments stay sharded
      cache_shard  — 'seq' (default) or 'headdim': which KV-cache dim rides
                     the model axis at decode time
    """

    def __init__(self, mesh: jax.sharding.Mesh, cfg: ModelConfig,
                 opts: dict | None = None) -> None:
        self.mesh = mesh
        self.cfg = cfg
        self.opts = opts or {}
        self.dp = data_axes(mesh)            # ('pod','data') or ('data',)
        self.fsdp = "data"                   # param sharding axis
        self.tp = "model"
        self._params_fsdp = not self.opts.get("zero2", False)

    # -- helpers ----------------------------------------------------------
    def _div(self, n: int, axis) -> Any:
        """axis if the dim divides the axis size, else None (replicate)."""
        if axis is None:
            return None
        return axis if n % _axis_size(self.mesh, axis) == 0 else None

    def shard(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # -- parameters -------------------------------------------------------
    def param_spec(self, path: tuple, leaf, fsdp_on: bool = True) -> P:
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        shape = leaf.shape
        fsdp = self.fsdp if (fsdp_on and self._params_fsdp_local) else None
        stacked = "layers" in keys            # leading L axis from the scan
        off = 1 if stacked else 0
        dims = shape[off:]
        lead = (None,) * off

        def spec(*axes):
            return P(*lead, *axes)

        if name in ("embed",):
            v, d = dims
            return spec(self._div(v, self.tp), self._div(d, fsdp))
        if name == "lm_head":
            d, v = dims
            return spec(self._div(d, fsdp), self._div(v, self.tp))
        if len(dims) <= 1:
            return spec(*(None,) * len(dims))  # norms/biases/scalars: replicate
        if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "x_proj",
                    "dt_proj"):
            if len(dims) == 3:                 # MoE stacked experts (E, d, f)
                e, d, f = dims
                # E (8/60) does not divide the 16-way model axis → experts
                # replicated, TP inside each expert (see EXPERIMENTS.md §Perf
                # for the EP hillclimb)
                return spec(None, self._div(d, fsdp),
                            self._div(f, self.tp))
            d_in, d_out = dims
            return spec(self._div(d_in, fsdp), self._div(d_out, self.tp))
        if name in ("wo", "w_down", "out_proj"):
            if len(dims) == 3:                 # (E, f, d)
                e, f, d = dims
                return spec(None, self._div(f, self.tp),
                            self._div(d, fsdp))
            d_in, d_out = dims
            return spec(self._div(d_in, self.tp), self._div(d_out, fsdp))
        if name == "router":
            d, e = dims
            return spec(self._div(d, fsdp), None)
        if name == "conv_w":
            k, ch = dims
            return spec(None, self._div(ch, self.tp))
        if name == "A_log" and len(dims) == 2:
            di, n = dims
            return spec(self._div(di, self.tp), None)
        # default 2D: FSDP on the larger dim
        if len(dims) == 2:
            a, b = dims
            if a >= b:
                return spec(self._div(a, fsdp), None)
            return spec(None, self._div(b, fsdp))
        return spec(*(None,) * len(dims))

    @property
    def _params_fsdp_local(self):
        return getattr(self, "_fsdp_override", self._params_fsdp)

    def param_specs(self, params_shape: Any) -> Any:
        return jax.tree_util.tree_map_with_path(self.param_spec, params_shape)

    def state_specs(self, state_shape: Any) -> Any:
        """TrainState: AdamW moments always FSDP-sharded; under zero2 the
        *parameters* are replicated over data (gathered once per step at the
        optimizer update) while moments/grad-accumulators stay sharded."""

        def spec(path, leaf):
            if leaf.ndim == 0:
                return P()
            keys = [str(getattr(k, "key", getattr(k, "name", k)))
                    for k in path]
            is_param = keys and keys[0] == "params"
            self._fsdp_override = self._params_fsdp or not is_param
            try:
                return self.param_spec(self._strip(path), leaf)
            finally:
                del self._fsdp_override

        return jax.tree_util.tree_map_with_path(spec, state_shape)

    def grad_specs(self, params_shape: Any) -> Any:
        """Gradient-accumulator specs: always FSDP over data (ZeRO-2's
        reduce-scattered gradients), regardless of the param layout."""
        self._fsdp_override = True
        try:
            return jax.tree_util.tree_map_with_path(self.param_spec,
                                                    params_shape)
        finally:
            del self._fsdp_override

    @staticmethod
    def _strip(path: tuple) -> tuple:
        """Drop the TrainState/OptState prefixes so moment leaves match the
        same rules as their parameters."""
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        drop = {"params", "opt_state", "m", "v", "0", "1", "2"}
        kept = [p for p, k in zip(path, keys) if str(k) not in drop]
        return tuple(kept) if kept else path

    # -- batches ------------------------------------------------------------
    def batch_spec(self, microbatched: bool) -> Any:
        lead = (None,) if microbatched else ()
        return {
            "inputs": P(*lead, self.dp, None) if self.cfg.input_mode != "embeddings"
            else P(*lead, self.dp, None, None),
            "labels": P(*lead, self.dp, None),
        }

    def token_spec(self, batch: int | None = None) -> P:
        ax = self.dp
        if batch is not None and batch % _axis_size(self.mesh, self.dp) != 0:
            ax = None            # long_500k: batch=1 stays replicated
        if self.cfg.input_mode == "embeddings":
            return P(ax, None, None)
        return P(ax, None)

    # -- serving cache ---------------------------------------------------------
    def cache_specs(self, cache_shape: Any, batch: int) -> Any:
        """KV caches: batch→data when divisible, sequence→model (and →data
        too when batch can't shard, e.g. long_500k's batch=1)."""
        bdiv = batch % _axis_size(self.mesh, self.dp) == 0
        batch_ax = self.dp if bdiv else None
        seq_ax = self.tp if bdiv else (self.dp + (self.tp,)
                                       if isinstance(self.dp, tuple)
                                       else ("data", "model"))
        mode = self.opts.get("cache_shard", "seq")

        def spec(path, leaf):
            keys = [getattr(k, "key", getattr(k, "name", str(k)))
                    for k in path]
            name = keys[-1] if keys else ""
            if name in ("k", "v", "sa_k", "sa_v"):
                L, b, h, s, hd = leaf.shape
                if mode == "headdim" and hd % _axis_size(self.mesh,
                                                         self.tp) == 0:
                    # head_dim over TP: cache writes stay local (no gather
                    # at the dynamic_update_slice), contractions psum small
                    s_ax = None if bdiv else self._div(s, "data")
                    return P(None, batch_ax, None, s_ax,
                             self._div(hd, self.tp))
                s_ok = s % _axis_size(self.mesh, seq_ax) == 0
                return P(None, batch_ax, None, seq_ax if s_ok else None, None)
            if name == "lengths":
                return P(None)
            if name == "conv":   # (L, B, K-1, ch)
                ch = leaf.shape[-1]
                return P(None, batch_ax, None, self._div(ch, self.tp))
            if name == "ssm":
                if leaf.ndim == 4:      # mamba1 (L, B, di, n)
                    return P(None, batch_ax, self._div(leaf.shape[2], self.tp),
                             None)
                return P(None, batch_ax,  # mamba2 (L, B, nh, n, hd)
                         self._div(leaf.shape[2], self.tp), None, None)
            return P(*(None,) * leaf.ndim)

        return jax.tree_util.tree_map_with_path(spec, cache_shape)

    # -- convenience: NamedSharding trees ------------------------------------
    def to_shardings(self, spec_tree: Any) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))
