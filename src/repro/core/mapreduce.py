"""Device-parallel MapReduce — the paper's pipeline on a TPU mesh.

``mapreduce()`` runs the full Coordinator workflow (split → map → combine →
shuffle → reduce → finalize) as one SPMD program.  Workers are mesh devices;
the Coordinator's synchronization is the collective schedule; spill traffic is
ICI.  The host-side engine (`core.workers`) and this one implement the same
semantics — ``tests/test_mapreduce.py`` holds them to the same answers.

Two backends run identical worker code:

  * ``backend="shard_map"`` — real SPMD over a mesh axis (production path,
    multi-pod dry-run).
  * ``backend="vmap"`` — the same collectives over a vmap axis, simulating W
    workers on one device (CI path; this container has a single CPU device).

Modes (see core.shuffle):

  * ``mode="aggregate"`` — commutative/associative reduce (sum family):
    local combine → ``reduce_scatter``.  The paper's combiner fused into the
    collective.
  * ``mode="group"`` — general reduce over each key's full value list:
    fixed-capacity ``all_to_all`` + sort + segment reduce.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from .shuffle import (ShuffleStats, shuffle_aggregate, shuffle_group,
                      sort_and_group)

INT32_MAX = jnp.iinfo(jnp.int32).max


@dataclass(frozen=True)
class DeviceJobConfig:
    """Device-engine analogue of the paper's JSON job config (§III-C).

    num_buckets    — key-id space size (aggregate mode's dense width)
    n_workers      — mesh-axis size: the paper's n_mappers == n_reducers here,
                     every device plays both roles (map, then own a partition)
    capacity       — per-partition record capacity for the grouping exchange
                     (the spill-file size bound)
    run_combiner   — pre-reduce locally before shuffling (paper default: on)
    """

    num_buckets: int
    n_workers: int
    capacity: int = 0
    axis_name: str = "workers"
    run_combiner: bool = True


# ---------------------------------------------------------------------------
# Built-in segment reducers for grouping mode
# ---------------------------------------------------------------------------

def segment_reduce(kind: str, keys: jax.Array, values: jax.Array,
                   starts: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Reduce a key-sorted, group-marked stream.

    Returns dense (group_keys, group_values, group_valid) of the same length
    as the input stream (padded with invalid groups) — static shapes, as TPU
    requires.  ``kind`` ∈ {sum, max, min, count, mean}.
    """
    n = keys.shape[0]
    valid = keys != INT32_MAX
    seg = jnp.cumsum(starts) - 1
    seg = jnp.where(valid, seg, n)  # park invalid records on overflow row
    vshape = (n + 1,) + values.shape[1:]

    if kind in ("sum", "mean", "count"):
        sums = jax.ops.segment_sum(values, seg, num_segments=n + 1)
        counts = jax.ops.segment_sum(jnp.ones((n,), values.dtype), seg,
                                     num_segments=n + 1)
        if kind == "sum":
            out_v = sums
        elif kind == "count":
            out_v = counts.reshape((n + 1,) + (1,) * (values.ndim - 1)) \
                if values.ndim > 1 else counts
        else:
            out_v = sums / jnp.maximum(
                counts.reshape((-1,) + (1,) * (values.ndim - 1)), 1.0)
    elif kind == "max":
        out_v = jax.ops.segment_max(values, seg, num_segments=n + 1)
    elif kind == "min":
        out_v = jax.ops.segment_min(values, seg, num_segments=n + 1)
    else:
        raise ValueError(f"unknown segment reducer {kind!r}")

    group_keys = jnp.full((n + 1,), -1, dtype=jnp.int32).at[seg].max(
        jnp.where(valid, keys, -1))
    group_valid = group_keys[:n] >= 0
    out_v = out_v[:n]
    out_v = jnp.where(
        group_valid.reshape((-1,) + (1,) * (out_v.ndim - 1)),
        out_v, jnp.zeros_like(out_v))
    return group_keys[:n], out_v, group_valid


# ---------------------------------------------------------------------------
# The SPMD worker body — identical under shard_map and vmap
# ---------------------------------------------------------------------------

def _worker_body(shard, *, cfg: DeviceJobConfig, map_fn: Callable,
                 mode: str, reduce_fn, combine_fn, finalize: bool):
    keys, values, valid = map_fn(shard)
    keys = keys.astype(jnp.int32)

    if mode == "aggregate":
        part = shuffle_aggregate(keys, values, cfg.axis_name, cfg.num_buckets,
                                 valid=valid, combine_fn=combine_fn)
        if finalize:
            # Finalizer: concatenate every reducer's slice into one object —
            # all_gather is the collective form of §III-A.5's stream-concat.
            return jax.lax.all_gather(part, cfg.axis_name, tiled=True)
        return part

    if mode == "group":
        if cfg.capacity <= 0:
            raise ValueError("grouping mode needs a positive capacity")
        out_k, out_v, starts, stats = shuffle_group(
            keys, values, cfg.axis_name, cfg.n_workers, cfg.capacity,
            valid=valid)
        if isinstance(reduce_fn, str):
            gk, gv, gvalid = segment_reduce(reduce_fn, out_k, out_v, starts)
        else:
            gk, gv, gvalid = reduce_fn(out_k, out_v, starts)
        dropped = jax.lax.psum(stats.dropped, cfg.axis_name)
        if finalize:
            gather = partial(jax.lax.all_gather, axis_name=cfg.axis_name,
                             tiled=True)
            return gather(gk), gather(gv), gather(gvalid), dropped
        return gk, gv, gvalid, dropped

    raise ValueError(f"unknown mode {mode!r}")


def mapreduce(map_fn: Callable, data, cfg: DeviceJobConfig, *,
              mode: str = "aggregate", reduce_fn: str | Callable = "sum",
              combine_fn: Callable | None = None, finalize: bool = True,
              backend: str = "vmap", mesh: jax.sharding.Mesh | None = None,
              data_spec=None, jit: bool = True):
    """Run a MapReduce job across ``cfg.n_workers`` SPMD workers.

    ``map_fn(shard) -> (keys, values, valid)`` is the user's map UDF over the
    worker's data shard (already split — the Splitter's output).  ``data`` has
    leading axis ``n_workers`` (vmap backend) or is a global array to be
    sharded over the mesh axis (shard_map backend).
    """
    if not cfg.run_combiner and mode == "aggregate":
        # without a combiner the aggregate path still works (segment-sum then
        # reduce-scatter); the flag matters for the grouping path's volume
        pass
    body = partial(_worker_body, cfg=cfg, map_fn=map_fn, mode=mode,
                   reduce_fn=reduce_fn, combine_fn=combine_fn,
                   finalize=finalize)

    if backend == "vmap":
        # finalized outputs are all_gather/psum results — unbatched over the
        # worker axis, so vmap returns a single copy (out_axes=None)
        fn = jax.vmap(body, in_axes=0, out_axes=None if finalize else 0,
                      axis_name=cfg.axis_name)
        fn = jax.jit(fn) if jit else fn
        return fn(data)

    if backend == "shard_map":
        if mesh is None:
            raise ValueError("shard_map backend needs a mesh")
        P = jax.sharding.PartitionSpec
        in_spec = data_spec if data_spec is not None else P(cfg.axis_name)
        if mode == "aggregate":
            out_spec = P() if finalize else P(cfg.axis_name)
        else:
            gspec = P() if finalize else P(cfg.axis_name)
            out_spec = (gspec, gspec, gspec, P())
        # finalized outputs are all_gather/psum results — replicated by
        # construction, which the static checker can't always prove
        sm = jax.shard_map(body, mesh=mesh, in_specs=(in_spec,),
                           out_specs=out_spec, check_vma=False)
        sm = jax.jit(sm) if jit else sm
        return sm(data)

    raise ValueError(f"unknown backend {backend!r}")


def wordcount_map_factory(num_buckets: int):
    """Device word count map UDF: shard is a (records, 2) int32 array of
    (token_id, 1) pairs with -1 padding — the data layer tokenizes text into
    ids.  Mirrors the paper's Fig. 5 mapper."""

    def map_fn(shard):
        keys = shard[:, 0]
        values = shard[:, 1].astype(jnp.float32)
        valid = keys >= 0
        keys = jnp.where(valid, keys, 0) % num_buckets
        return keys, values, valid

    return map_fn
