"""Device-parallel MapReduce helpers — a thin façade over ``repro.engine``.

Since the execution-plan refactor the engine proper lives in
``repro.engine``: batch one-shot, streaming incremental, aggregate, and
group modes are all lowerings of one ``ExecutionPlan.compile()``
(``KeySpace`` × ``WindowSpec`` × ``ReduceSpec`` → vmap/shard_map backend).
What remains here are the original device-engine call signatures the
streaming façade and the device tests still use — ``DeviceJobConfig``,
the incremental-step builders, and the window-slot carry helpers.  The
one-shot ``mapreduce()`` entry point was removed in PR 8, as its
deprecation message scheduled: author the job as
``repro.pipeline.Pipeline.from_source(shards=...).map(map_fn).reduce(...)``
and drive it with ``BuiltPipeline.run(data)``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from ..engine.plan import (ExecutionPlan, KeySpace, ReduceSpec, WindowSpec,
                           clear_window_slot_carry, gather_window_slot,
                           streaming_record_map)
from ..engine.stages import INT32_MAX, segment_reduce

__all__ = [
    "DeviceJobConfig", "segment_reduce", "streaming_record_map",
    "make_incremental_step", "init_window_carry", "read_window_slot",
    "clear_window_slot", "wordcount_map_factory", "INT32_MAX",
]


@dataclass(frozen=True)
class DeviceJobConfig:
    """Device-engine analogue of the paper's JSON job config (§III-C).

    num_buckets    — key-id space size (aggregate mode's dense width)
    n_workers      — mesh-axis size: the paper's n_mappers == n_reducers here,
                     every device plays both roles (map, then own a partition)
    capacity       — per-partition record capacity for the grouping exchange
                     (the spill-file size bound)
    run_combiner   — pre-reduce locally before shuffling (paper default: on)
    """

    num_buckets: int
    n_workers: int
    capacity: int = 0
    axis_name: str = "workers"
    run_combiner: bool = True


def _plan_from_config(cfg: DeviceJobConfig, mode: str, reduce_fn,
                      combine_fn, window: WindowSpec | None = None,
                      key_space: KeySpace | None = None) -> ExecutionPlan:
    return ExecutionPlan(
        key_space=key_space or KeySpace.dense(cfg.num_buckets),
        reduce=ReduceSpec(mode=mode, reduce_fn=reduce_fn,
                          combine_fn=combine_fn, capacity=cfg.capacity),
        n_workers=cfg.n_workers, window=window, axis_name=cfg.axis_name)


# ---------------------------------------------------------------------------
# Streaming: incremental windowed aggregation (one fused collective per batch)
# ---------------------------------------------------------------------------

def make_incremental_step(cfg: DeviceJobConfig, n_slots: int, *,
                          map_fn: Callable = streaming_record_map,
                          combine_fn: Callable | None = None,
                          backend: str = "vmap",
                          mesh: jax.sharding.Mesh | None = None,
                          jit: bool = True) -> Callable:
    """Build the streaming hot-path: ``step(batch, carry) -> carry``.

    ``carry`` is the in-flight window state in *scattered* layout — each of
    the ``cfg.n_workers`` devices owns a contiguous
    ``n_slots * num_buckets / n_workers`` slice of the flattened
    (window_slot, bucket) space, exactly the layout ``psum_scatter`` emits.
    One call folds one micro-batch into the carry with a single fused
    reduce_scatter; no gather happens until a window finalizes
    (``read_window_slot``).  Built once per stream so XLA compiles one
    program for every batch.

    This façade keeps the host-fan-out wire format (``map_fn`` decodes
    pre-expanded [slot, key, value, valid] rows).  The streaming
    coordinator now compiles its plan directly and defaults to on-device
    fan-out; use ``ExecutionPlan`` with ``WindowSpec(fanout_on_device=True)``
    for that path.
    """
    window = WindowSpec(size=0.0, n_slots=n_slots, fanout_on_device=False)
    plan = _plan_from_config(cfg, "aggregate", "sum", combine_fn,
                             window=window)
    compiled = plan.compile(map_fn, backend=backend, mesh=mesh, jit=jit)

    def step(batch, carry):
        new_carry, _stats = compiled.step(batch, carry)
        return new_carry

    return step


def init_window_carry(cfg: DeviceJobConfig, n_slots: int,
                      n_channels: int = 2, backend: str = "vmap",
                      dtype=jnp.float32) -> jax.Array:
    """Zeroed carried window state in the scattered layout ``step`` expects."""
    per_worker = (n_slots * cfg.num_buckets) // cfg.n_workers
    if backend == "vmap":
        return jnp.zeros((cfg.n_workers, per_worker, n_channels), dtype)
    return jnp.zeros((n_slots * cfg.num_buckets, n_channels), dtype)


def read_window_slot(carry: jax.Array, slot: int, num_buckets: int):
    """Gather one finalized window's dense (num_buckets, channels) aggregate
    from the scattered carry.  Slices on device so only the window's rows —
    not the whole carry — cross to the host."""
    return gather_window_slot(carry, slot, num_buckets)


def clear_window_slot(carry: jax.Array, slot: int,
                      num_buckets: int) -> jax.Array:
    """Zero a finalized window's slice so its ring slot can be reused."""
    return clear_window_slot_carry(carry, slot, num_buckets)


def wordcount_map_factory(num_buckets: int):
    """Device word count map UDF: shard is a (records, 2) int32 array of
    (token_id, 1) pairs with -1 padding — the data layer tokenizes text into
    ids.  Mirrors the paper's Fig. 5 mapper."""

    def map_fn(shard):
        keys = shard[:, 0]
        values = shard[:, 1].astype(jnp.float32)
        valid = keys >= 0
        keys = jnp.where(valid, keys, 0) % num_buckets
        return keys, values, valid

    return map_fn
