"""Device-parallel MapReduce — the paper's pipeline on a TPU mesh.

``mapreduce()`` runs the full Coordinator workflow (split → map → combine →
shuffle → reduce → finalize) as one SPMD program.  Workers are mesh devices;
the Coordinator's synchronization is the collective schedule; spill traffic is
ICI.  The host-side engine (`core.workers`) and this one implement the same
semantics — ``tests/test_mapreduce.py`` holds them to the same answers.

Two backends run identical worker code:

  * ``backend="shard_map"`` — real SPMD over a mesh axis (production path,
    multi-pod dry-run).
  * ``backend="vmap"`` — the same collectives over a vmap axis, simulating W
    workers on one device (CI path; this container has a single CPU device).

Modes (see core.shuffle):

  * ``mode="aggregate"`` — commutative/associative reduce (sum family):
    local combine → ``reduce_scatter``.  The paper's combiner fused into the
    collective.
  * ``mode="group"`` — general reduce over each key's full value list:
    fixed-capacity ``all_to_all`` + sort + segment reduce.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .shuffle import (shuffle_aggregate, shuffle_aggregate_windowed,
                      shuffle_group)

INT32_MAX = jnp.iinfo(jnp.int32).max

# jax >= 0.5 exposes shard_map at top level with check_vma; older releases
# (this container ships 0.4.x) keep it in experimental with check_rep
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SM_CHECK_KW = "check_vma"
else:  # pragma: no cover - exercised on jax 0.4.x only
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_CHECK_KW = "check_rep"


def _make_shard_map(body, mesh, in_specs, out_specs):
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_SM_CHECK_KW: False})


@dataclass(frozen=True)
class DeviceJobConfig:
    """Device-engine analogue of the paper's JSON job config (§III-C).

    num_buckets    — key-id space size (aggregate mode's dense width)
    n_workers      — mesh-axis size: the paper's n_mappers == n_reducers here,
                     every device plays both roles (map, then own a partition)
    capacity       — per-partition record capacity for the grouping exchange
                     (the spill-file size bound)
    run_combiner   — pre-reduce locally before shuffling (paper default: on)
    """

    num_buckets: int
    n_workers: int
    capacity: int = 0
    axis_name: str = "workers"
    run_combiner: bool = True


# ---------------------------------------------------------------------------
# Built-in segment reducers for grouping mode
# ---------------------------------------------------------------------------

def segment_reduce(kind: str, keys: jax.Array, values: jax.Array,
                   starts: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Reduce a key-sorted, group-marked stream.

    Returns dense (group_keys, group_values, group_valid) of the same length
    as the input stream (padded with invalid groups) — static shapes, as TPU
    requires.  ``kind`` ∈ {sum, max, min, count, mean}.
    """
    n = keys.shape[0]
    valid = keys != INT32_MAX
    seg = jnp.cumsum(starts) - 1
    seg = jnp.where(valid, seg, n)  # park invalid records on overflow row
    vshape = (n + 1,) + values.shape[1:]

    if kind in ("sum", "mean", "count"):
        sums = jax.ops.segment_sum(values, seg, num_segments=n + 1)
        counts = jax.ops.segment_sum(jnp.ones((n,), values.dtype), seg,
                                     num_segments=n + 1)
        if kind == "sum":
            out_v = sums
        elif kind == "count":
            out_v = counts.reshape((n + 1,) + (1,) * (values.ndim - 1)) \
                if values.ndim > 1 else counts
        else:
            out_v = sums / jnp.maximum(
                counts.reshape((-1,) + (1,) * (values.ndim - 1)), 1.0)
    elif kind == "max":
        out_v = jax.ops.segment_max(values, seg, num_segments=n + 1)
    elif kind == "min":
        out_v = jax.ops.segment_min(values, seg, num_segments=n + 1)
    else:
        raise ValueError(f"unknown segment reducer {kind!r}")

    group_keys = jnp.full((n + 1,), -1, dtype=jnp.int32).at[seg].max(
        jnp.where(valid, keys, -1))
    group_valid = group_keys[:n] >= 0
    out_v = out_v[:n]
    out_v = jnp.where(
        group_valid.reshape((-1,) + (1,) * (out_v.ndim - 1)),
        out_v, jnp.zeros_like(out_v))
    return group_keys[:n], out_v, group_valid


# ---------------------------------------------------------------------------
# The SPMD worker body — identical under shard_map and vmap
# ---------------------------------------------------------------------------

def _worker_body(shard, *, cfg: DeviceJobConfig, map_fn: Callable,
                 mode: str, reduce_fn, combine_fn, finalize: bool):
    keys, values, valid = map_fn(shard)
    keys = keys.astype(jnp.int32)

    if mode == "aggregate":
        # pad the bucket space to a multiple of the axis size so the tiled
        # reduce_scatter divides evenly; callers index ids < num_buckets and
        # the pad rows stay zero
        padded = -(-cfg.num_buckets // cfg.n_workers) * cfg.n_workers
        part = shuffle_aggregate(keys, values, cfg.axis_name, padded,
                                 valid=valid, combine_fn=combine_fn)
        if finalize:
            # Finalizer: concatenate every reducer's slice into one object —
            # all_gather is the collective form of §III-A.5's stream-concat.
            return jax.lax.all_gather(part, cfg.axis_name, tiled=True)
        return part

    if mode == "group":
        if cfg.capacity <= 0:
            raise ValueError("grouping mode needs a positive capacity")
        out_k, out_v, starts, stats = shuffle_group(
            keys, values, cfg.axis_name, cfg.n_workers, cfg.capacity,
            valid=valid)
        if isinstance(reduce_fn, str):
            gk, gv, gvalid = segment_reduce(reduce_fn, out_k, out_v, starts)
        else:
            gk, gv, gvalid = reduce_fn(out_k, out_v, starts)
        dropped = jax.lax.psum(stats.dropped, cfg.axis_name)
        if finalize:
            gather = partial(jax.lax.all_gather, axis_name=cfg.axis_name,
                             tiled=True)
            return gather(gk), gather(gv), gather(gvalid), dropped
        return gk, gv, gvalid, dropped

    raise ValueError(f"unknown mode {mode!r}")


def mapreduce(map_fn: Callable, data, cfg: DeviceJobConfig, *,
              mode: str = "aggregate", reduce_fn: str | Callable = "sum",
              combine_fn: Callable | None = None, finalize: bool = True,
              backend: str = "vmap", mesh: jax.sharding.Mesh | None = None,
              data_spec=None, jit: bool = True):
    """Run a MapReduce job across ``cfg.n_workers`` SPMD workers.

    ``map_fn(shard) -> (keys, values, valid)`` is the user's map UDF over the
    worker's data shard (already split — the Splitter's output).  ``data`` has
    leading axis ``n_workers`` (vmap backend) or is a global array to be
    sharded over the mesh axis (shard_map backend).
    """
    if not cfg.run_combiner and mode == "aggregate":
        # without a combiner the aggregate path still works (segment-sum then
        # reduce-scatter); the flag matters for the grouping path's volume
        pass
    body = partial(_worker_body, cfg=cfg, map_fn=map_fn, mode=mode,
                   reduce_fn=reduce_fn, combine_fn=combine_fn,
                   finalize=finalize)

    if backend == "vmap":
        # finalized outputs are all_gather/psum results — unbatched over the
        # worker axis, so vmap returns a single copy (out_axes=None)
        fn = jax.vmap(body, in_axes=0, out_axes=None if finalize else 0,
                      axis_name=cfg.axis_name)
        fn = jax.jit(fn) if jit else fn
        return fn(data)

    if backend == "shard_map":
        if mesh is None:
            raise ValueError("shard_map backend needs a mesh")
        P = jax.sharding.PartitionSpec
        in_spec = data_spec if data_spec is not None else P(cfg.axis_name)
        if mode == "aggregate":
            out_spec = P() if finalize else P(cfg.axis_name)
        else:
            gspec = P() if finalize else P(cfg.axis_name)
            out_spec = (gspec, gspec, gspec, P())
        # finalized outputs are all_gather/psum results — replicated by
        # construction, which the static checker can't always prove
        sm = _make_shard_map(body, mesh, (in_spec,), out_spec)
        sm = jax.jit(sm) if jit else sm
        return sm(data)

    raise ValueError(f"unknown backend {backend!r}")


# ---------------------------------------------------------------------------
# Streaming: incremental windowed aggregation (one fused collective per batch)
# ---------------------------------------------------------------------------

def streaming_record_map(shard):
    """Default map UDF for the streaming engine: shard is a (records, 4)
    float32 array of [window_slot, key_id, value, valid] rows (the
    StreamingCoordinator's wire format).  Emits (sum, count) value channels so
    count / sum / mean all come out of one carried state."""
    slots = shard[:, 0].astype(jnp.int32)
    keys = shard[:, 1].astype(jnp.int32)
    valid = shard[:, 3] > 0
    values = jnp.stack([shard[:, 2], jnp.ones_like(shard[:, 2])], axis=-1)
    return slots, keys, values, valid


def make_incremental_step(cfg: DeviceJobConfig, n_slots: int, *,
                          map_fn: Callable = streaming_record_map,
                          combine_fn: Callable | None = None,
                          backend: str = "vmap",
                          mesh: jax.sharding.Mesh | None = None,
                          jit: bool = True) -> Callable:
    """Build the streaming hot-path: ``step(batch, carry) -> carry``.

    ``carry`` is the in-flight window state in *scattered* layout — each of
    the ``cfg.n_workers`` devices owns a contiguous
    ``n_slots * num_buckets / n_workers`` slice of the flattened
    (window_slot, bucket) space, exactly the layout ``psum_scatter`` emits.
    One call folds one micro-batch into the carry with a single fused
    reduce_scatter; no gather happens until a window finalizes
    (``read_window_slot``).  Built once per stream so XLA compiles one program
    for every batch.
    """
    if (n_slots * cfg.num_buckets) % cfg.n_workers != 0:
        raise ValueError("n_slots * num_buckets must divide by n_workers")

    def body(shard, carry_slice):
        slots, keys, values, valid = map_fn(shard)
        part = shuffle_aggregate_windowed(
            slots, keys, values, cfg.axis_name, n_slots, cfg.num_buckets,
            valid=valid, combine_fn=combine_fn)
        return carry_slice + part

    if backend == "vmap":
        fn = jax.vmap(body, in_axes=(0, 0), out_axes=0,
                      axis_name=cfg.axis_name)
        return jax.jit(fn) if jit else fn
    if backend == "shard_map":
        if mesh is None:
            raise ValueError("shard_map backend needs a mesh")
        P = jax.sharding.PartitionSpec
        sm = _make_shard_map(body, mesh,
                             (P(cfg.axis_name), P(cfg.axis_name)),
                             P(cfg.axis_name))
        return jax.jit(sm) if jit else sm
    raise ValueError(f"unknown backend {backend!r}")


def init_window_carry(cfg: DeviceJobConfig, n_slots: int,
                      n_channels: int = 2, backend: str = "vmap",
                      dtype=jnp.float32) -> jax.Array:
    """Zeroed carried window state in the scattered layout ``step`` expects."""
    per_worker = (n_slots * cfg.num_buckets) // cfg.n_workers
    if backend == "vmap":
        return jnp.zeros((cfg.n_workers, per_worker, n_channels), dtype)
    return jnp.zeros((n_slots * cfg.num_buckets, n_channels), dtype)


@partial(jax.jit, static_argnums=(2,))
def _gather_flat_slot(flat: jax.Array, slot, num_buckets: int) -> jax.Array:
    start = (slot * num_buckets,) + (0,) * (flat.ndim - 1)
    return jax.lax.dynamic_slice(flat, start,
                                 (num_buckets,) + flat.shape[1:])


def read_window_slot(carry: jax.Array, slot: int, num_buckets: int):
    """Gather one finalized window's dense (num_buckets, channels) aggregate
    from the scattered carry.  Slices on device so only the window's rows —
    not the whole carry — cross to the host."""
    flat = carry.reshape((-1,) + carry.shape[2:]) if carry.ndim == 3 else carry
    return np.asarray(_gather_flat_slot(flat, jnp.int32(slot), num_buckets))


@partial(jax.jit, static_argnums=(2,))
def _clear_flat_slot(flat: jax.Array, slot, num_buckets: int) -> jax.Array:
    zeros = jnp.zeros((num_buckets,) + flat.shape[1:], flat.dtype)
    start = (slot * num_buckets,) + (0,) * (flat.ndim - 1)
    return jax.lax.dynamic_update_slice(flat, zeros, start)


def clear_window_slot(carry: jax.Array, slot: int,
                      num_buckets: int) -> jax.Array:
    """Zero a finalized window's slice so its ring slot can be reused."""
    shape = carry.shape
    flat = carry.reshape((-1,) + shape[2:]) if carry.ndim == 3 else carry
    flat = _clear_flat_slot(flat, jnp.int32(slot), num_buckets)
    return flat.reshape(shape)


def wordcount_map_factory(num_buckets: int):
    """Device word count map UDF: shard is a (records, 2) int32 array of
    (token_id, 1) pairs with -1 padding — the data layer tokenizes text into
    ids.  Mirrors the paper's Fig. 5 mapper."""

    def map_fn(shard):
        keys = shard[:, 0]
        values = shard[:, 1].astype(jnp.float32)
        valid = keys >= 0
        keys = jnp.where(valid, keys, 0) % num_buckets
        return keys, values, valid

    return map_fn
