"""The paper's worker components: Mapper, Reducer, Finalizer (§III-A.3–5).

These are the *host-side, paper-faithful* implementations — stateless
functions of (job config, metadata store, object store) that could each run in
a separate container, communicate only through storage/metadata, and report
back to the Coordinator over the status topic.  The device-parallel JAX engine
(`repro.core.mapreduce`) implements the same stages on a TPU mesh; tests check
the two agree.

Record wire format for intermediate data: one JSON array per line,
``[key, value]`` — text-sortable by serialized key, which is what makes the
Mapper's sorted spills merge-able with a plain k-way merge in the Reducer.

Every worker returns a ``PhaseTimes`` breakdown (downloading / processing /
uploading) — the quantities behind the paper's Fig. 8.
"""

from __future__ import annotations

import heapq
import io
import json
import time
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Iterator

from .job import JobConfig, load_udf
from .metadata import MetadataStore, stage_done_counter, task_status_key
from .splitter import fetch_split
from .storage import MultipartWriter, ObjectStore, parse_spill_key, spill_key


@dataclass
class PhaseTimes:
    downloading: float = 0.0
    processing: float = 0.0
    uploading: float = 0.0
    records_in: int = 0
    records_out: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    spills: int = 0

    @property
    def total(self) -> float:
        return self.downloading + self.processing + self.uploading

    def as_dict(self) -> dict[str, float]:
        return {
            "downloading": self.downloading, "processing": self.processing,
            "uploading": self.uploading, "total": self.total,
            "records_in": self.records_in, "records_out": self.records_out,
            "bytes_in": self.bytes_in, "bytes_out": self.bytes_out,
            "spills": self.spills,
        }


def _encode_records(records: list[tuple[str, Any]]) -> bytes:
    out = io.BytesIO()
    for k, v in records:
        out.write(json.dumps([k, v], separators=(",", ":")).encode())
        out.write(b"\n")
    return out.getvalue()


def _decode_records(blob: bytes) -> Iterator[tuple[str, Any]]:
    for line in blob.splitlines():
        if line:
            k, v = json.loads(line)
            yield k, v


def _hash_partition(key: str, n_reducers: int) -> int:
    """hash(key) % R — must be stable across processes (FNV-1a, not hash())."""
    h = 0xCBF29CE484222325
    for b in key.encode():
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h % n_reducers


def _combine(records: list[tuple[str, Any]],
             combiner: Callable | None) -> list[tuple[str, Any]]:
    """Sort by key, then locally reduce adjacent groups (the combiner)."""
    records.sort(key=lambda kv: kv[0])
    if combiner is None:
        return records
    out: list[tuple[str, Any]] = []
    i = 0
    while i < len(records):
        j = i
        key = records[i][0]
        while j < len(records) and records[j][0] == key:
            j += 1
        if j - i == 1:
            out.append(records[i])
        else:
            out.append(tuple(combiner(key, [v for _, v in records[i:j]])))
        i = j
    return out


# ---------------------------------------------------------------------------
# Mapper (§III-A.3)
# ---------------------------------------------------------------------------

def run_mapper(cfg: JobConfig, mapper_id: int, store: ObjectStore,
               meta: MetadataStore) -> PhaseTimes:
    """Fetch assigned chunks, run the map UDF, sort+combine buffered records,
    hash-partition and spill to storage.  Stateless: everything it needs is in
    metadata (byte ranges) and storage (input bytes)."""
    times = PhaseTimes()
    map_fn = load_udf(cfg.mapper_src)
    combine_fn = None
    if cfg.run_combiner:
        combine_fn = load_udf(cfg.combiner_src or cfg.reducer_src) \
            if (cfg.combiner_src or cfg.reducer_src) else None

    n_part = max(1, cfg.n_reducers)
    buffers: list[list[tuple[str, Any]]] = [[] for _ in range(n_part)]
    buffered_bytes = 0
    spill_counts = [0] * n_part
    spill_limit = cfg.output_buffer_bytes * cfg.spill_threshold

    def spill(partition: int) -> None:
        nonlocal buffered_bytes
        records = buffers[partition]
        if not records:
            return
        t0 = time.perf_counter()
        records = _combine(records, combine_fn)  # sorted (+ combined) spill
        times.processing += time.perf_counter() - t0
        blob = _encode_records(records)
        t0 = time.perf_counter()
        key = spill_key(cfg.job_id, partition, spill_counts[partition], mapper_id)
        if len(blob) > cfg.multipart_bytes:
            w = MultipartWriter(part_size=cfg.multipart_bytes)
            w.write(blob)
            store.multipart_upload(key, w.finish(), part_size=cfg.multipart_bytes)
        else:
            store.put(key, blob)
        times.uploading += time.perf_counter() - t0
        times.bytes_out += len(blob)
        times.records_out += len(records)
        times.spills += 1
        spill_counts[partition] += 1
        buffered_bytes -= sum(len(k) + 16 for k, _ in buffers[partition])
        buffers[partition] = []

    def spill_all() -> None:
        for p in range(n_part):
            spill(p)

    for r in fetch_split(meta, cfg.job_id, mapper_id):
        # download the assigned byte range in input-buffer-sized pieces
        lo = r.lo
        while lo < r.hi:
            hi = min(lo + cfg.input_buffer_bytes, r.hi)
            t0 = time.perf_counter()
            chunk = store.get(r.key, (lo, hi))
            times.downloading += time.perf_counter() - t0
            times.bytes_in += len(chunk)
            lo = hi
            t0 = time.perf_counter()
            payload = chunk if cfg.binary_input else chunk.decode("utf-8", "replace")
            for k, v in map_fn(r.key, payload):
                k = str(k)
                p = _hash_partition(k, n_part)
                buffers[p].append((k, v))
                buffered_bytes += len(k) + 16
                times.records_in += 1
            times.processing += time.perf_counter() - t0
            if buffered_bytes >= spill_limit:
                spill_all()
    spill_all()

    meta.set(task_status_key(cfg.job_id, "mapper", mapper_id),
             {"status": "done", **times.as_dict()})
    meta.incr(stage_done_counter(cfg.job_id, "mapper"))
    return times


# ---------------------------------------------------------------------------
# Reducer (§III-A.4)
# ---------------------------------------------------------------------------

def _merge_runs(runs: list[list[tuple[str, Any]]],
                fan_in: int) -> Iterator[tuple[str, Any]]:
    """k-way merge of sorted runs, multi-pass if runs exceed the fan-in."""
    while len(runs) > fan_in:
        merged = list(heapq.merge(*runs[:fan_in], key=lambda kv: kv[0]))
        runs = [merged] + runs[fan_in:]
    return heapq.merge(*runs, key=lambda kv: kv[0])


def _group_reduce(stream: Iterable[tuple[str, Any]],
                  reduce_fn: Callable) -> Iterator[tuple[str, Any]]:
    """Apply the reduce UDF per key group of a key-sorted stream — 'for each
    key, all values are processed before moving to the next' (§III-A.4)."""
    cur_key: str | None = None
    cur_vals: list[Any] = []
    for k, v in stream:
        if k != cur_key:
            if cur_key is not None:
                yield tuple(reduce_fn(cur_key, cur_vals))
            cur_key, cur_vals = k, [v]
        else:
            cur_vals.append(v)
    if cur_key is not None:
        yield tuple(reduce_fn(cur_key, cur_vals))


def reducer_output_key(cfg: JobConfig, reducer_id: int) -> str:
    return f"{cfg.output_prefix.rstrip('/')}/{cfg.job_id}/part-{reducer_id:05d}"


def run_reducer(cfg: JobConfig, reducer_id: int, store: ObjectStore,
                meta: MetadataStore) -> PhaseTimes:
    times = PhaseTimes()
    reduce_fn = load_udf(cfg.reducer_src)

    # find assigned spill files by name (format spill-reducer_id-idx-mapper_id)
    prefix = f"jobs/{cfg.job_id}/intermediate/spill-{reducer_id}-"
    spill_objs = [m for m in store.list_objects(prefix)
                  if parse_spill_key(m.key)[0] == reducer_id]

    runs: list[list[tuple[str, Any]]] = []
    for obj in spill_objs:
        t0 = time.perf_counter()
        blob = store.get(obj.key)
        times.downloading += time.perf_counter() - t0
        times.bytes_in += len(blob)
        run = list(_decode_records(blob))
        times.records_in += len(run)
        runs.append(run)

    t0 = time.perf_counter()
    merged = _merge_runs(runs, cfg.merge_fan_in)
    results = list(_group_reduce(merged, reduce_fn))
    times.processing += time.perf_counter() - t0
    times.records_out = len(results)

    blob = _encode_records(results)
    t0 = time.perf_counter()
    store.put(reducer_output_key(cfg, reducer_id), blob)
    times.uploading += time.perf_counter() - t0
    times.bytes_out += len(blob)

    meta.set(task_status_key(cfg.job_id, "reducer", reducer_id),
             {"status": "done", **times.as_dict()})
    meta.incr(stage_done_counter(cfg.job_id, "reducer"))
    return times


# ---------------------------------------------------------------------------
# Finalizer (§III-A.5)
# ---------------------------------------------------------------------------

def final_output_key(cfg: JobConfig) -> str:
    return f"{cfg.output_prefix.rstrip('/')}/{cfg.job_id}/final"


def run_finalizer(cfg: JobConfig, store: ObjectStore,
                  meta: MetadataStore) -> PhaseTimes:
    """Stream the Reducer outputs into a single object — S3 does not support
    updates, so the Finalizer reads each part and writes one combined file."""
    times = PhaseTimes()
    keys = [reducer_output_key(cfg, r) for r in range(cfg.n_reducers)]
    keys = [k for k in keys if store.exists(k)]
    t0 = time.perf_counter()
    n = store.stream_concat(final_output_key(cfg), keys)
    dt = time.perf_counter() - t0
    # stream_concat interleaves read/write; attribute half to each phase
    times.downloading += dt / 2
    times.uploading += dt / 2
    times.bytes_in += n
    times.bytes_out += n
    meta.set(task_status_key(cfg.job_id, "finalizer", 0),
             {"status": "done", **times.as_dict()})
    meta.incr(stage_done_counter(cfg.job_id, "finalizer"))
    return times


def read_final_output(cfg: JobConfig, store: ObjectStore) -> dict[str, Any]:
    """Convenience for tests: parse the final object back into a dict."""
    blob = store.get(final_output_key(cfg))
    return dict(_decode_records(blob))
