"""Client package — submitting and monitoring jobs (§III-D, Fig. 3/4).

The paper's users interact through a Python package that (1) extracts the
source of user-defined map/reduce functions and appends it to the JSON
payload, (2) submits each job to the Coordinator, (3) polls job progress from
the Redis metadata, and (4) runs multiple jobs asynchronously.  A job with
several map functions is executed as a *chain* of MapReduce jobs: each map
stage consumes the previous stage's intermediate output; only the last stage
runs the reducer — the client locates intermediate files between stages
(§III-D, the two-mapper example).

This module is that package against our in-process Coordinator.  ``Job`` and
``MapReduce`` mirror the names in the paper's Fig. 4.
"""

from __future__ import annotations

import asyncio
from dataclasses import dataclass, field
from typing import Any, Callable

from .coordinator import Coordinator, JobReport, JobState
from .job import JobConfig
from .metadata import job_state_key


@dataclass
class Job:
    """A user-facing job: one or more map functions and an optional reducer,
    exactly the Fig. 4 shape."""

    payload: dict[str, Any] | JobConfig
    mappers: list[Callable]
    reducer: Callable | None = None
    combiner: Callable | None = None
    reports: list[JobReport] = field(default_factory=list)

    def base_config(self) -> JobConfig:
        if isinstance(self.payload, JobConfig):
            return self.payload
        return JobConfig.from_json(dict(self.payload))

    def build_stages(self) -> list[JobConfig]:
        """Compile the multi-map job into chained JobConfigs.

        Stage i>0 reads stage i-1's output prefix; only the final stage gets
        the reducer + finalizer.  Identity-reduce intermediate stages are
        map-only workflows (the paper: 'the first executes the first map
        function only').
        """
        if not self.mappers:
            raise ValueError("need at least one mapper function")
        base = self.base_config()
        stages: list[JobConfig] = []
        prev_output: str | None = None
        n = len(self.mappers)
        for i, map_fn in enumerate(self.mappers):
            cfg = JobConfig.from_json(base.to_json())
            cfg.job_id = f"{base.job_id}-s{i}"
            if prev_output is not None:
                cfg.input_prefix = prev_output
            is_last = i == n - 1
            if is_last:
                cfg.with_functions(map_fn, self.reducer, self.combiner)
                cfg.run_finalizer = base.run_finalizer and self.reducer is not None
                if self.reducer is None:
                    cfg.n_reducers = 0
            else:
                # intermediate stage: map-only; pass records through unreduced
                cfg.with_functions(map_fn)
                cfg.n_reducers = 0
                cfg.run_finalizer = False
                cfg.run_combiner = False
            stages.append(cfg)
            prev_output = f"{cfg.output_prefix.rstrip('/')}/{cfg.job_id}/" \
                if is_last else f"jobs/{cfg.job_id}/intermediate/"
        return stages


class MapReduce:
    """Async multi-job runner (Fig. 4): each job is an asyncio task; the run
    returns the job IDs so users can locate results in storage."""

    def __init__(self, coordinator: Coordinator, jobs: list[Job],
                 logging: bool = False,
                 poll_interval: float = 0.02) -> None:
        self.coordinator = coordinator
        self.jobs = jobs
        self.logging = logging
        self.poll_interval = poll_interval

    # -- monitoring (Fig. 3: the package polls Redis metadata) ---------------
    def job_status(self, job_id: str) -> str:
        return self.coordinator.meta.get(job_state_key(job_id),
                                         JobState.PENDING.value)

    async def _run_job(self, job: Job) -> list[str]:
        loop = asyncio.get_running_loop()
        ids = []
        for cfg in job.build_stages():
            if self.logging:
                print(f"[client] submitting {cfg.job_id} "
                      f"({cfg.n_mappers} mappers / {cfg.n_reducers} reducers)")
            # submit to the coordinator off-thread; poll metadata meanwhile
            fut = loop.run_in_executor(None, self.coordinator.run_job, cfg)
            while not fut.done():
                await asyncio.sleep(self.poll_interval)
                if self.logging:
                    state = self.job_status(cfg.job_id)
                    m = self.coordinator.stage_progress(cfg.job_id, "mapper")
                    r = self.coordinator.stage_progress(cfg.job_id, "reducer")
                    print(f"[client] {cfg.job_id}: {state} "
                          f"(mappers done={m}, reducers done={r})")
            report: JobReport = fut.result()
            job.reports.append(report)
            if report.state != JobState.DONE:
                raise RuntimeError(
                    f"job {cfg.job_id} failed: {report.error}")
            ids.append(cfg.job_id)
        return ids

    async def run(self) -> list[list[str]]:
        """Run all jobs concurrently; returns per-job lists of stage job IDs."""
        return list(await asyncio.gather(
            *(self._run_job(j) for j in self.jobs)))

    def run_sync(self) -> list[list[str]]:
        return asyncio.run(self.run())


class JobServiceClient:
    """The job server's client package — the streaming twin of
    :class:`MapReduce`.

    Two transports, one surface.  *In-process* (``server=``): the
    lifecycle verbs delegate to the server's control plane directly, and
    monitoring reads only the metadata records (``job_record_key``),
    exactly as the paper's client polls Redis rather than the
    coordinator process — a dashboard holding just the MetadataStore
    sees the same state the server wrote.  *Remote* (``address=``): the
    same verbs travel as length-prefixed JSON frames to a
    ``launch.serve.JobSocketServer`` in another process, with
    ``timeout`` bounding every socket operation and ``retries`` bounding
    reconnect attempts; programs are referenced by their server-side
    registered name, since a compiled ``BuiltPipeline`` never crosses
    the wire.  Exactly one of ``server``/``address`` must be given.
    ``run()`` drives the server until every submitted job completes,
    awaiting asynchronously like Fig. 4's multi-job runner.
    """

    def __init__(self, server=None, *, address: tuple[str, int] | None = None,
                 timeout: float = 5.0, retries: int = 2,
                 poll_interval: float = 0.02) -> None:
        if (server is None) == (address is None):
            raise ValueError("pass exactly one of server= (in-process) or "
                             "address= (socket transport)")
        self.server = server
        if address is not None:
            from .rpc import FrameClient
            self._rpc = FrameClient(address, timeout=timeout, retries=retries)
        else:
            self._rpc = None
        self.poll_interval = poll_interval

    def _call(self, method: str, **params: Any) -> Any:
        from .rpc import RPCError
        response = self._rpc.call({"method": method, **params})
        if not response.get("ok"):
            raise RPCError(response.get("error", "rpc call failed"))
        return response.get("result")

    def close(self) -> None:
        """Drop the socket connection, if any.  Idempotent; the next
        remote call redials."""
        if self._rpc is not None:
            self._rpc.close()

    # -- submission / lifecycle verbs (RPC surface) --------------------------
    def submit(self, tenant: str, program, **kwargs) -> str:
        """Submit ``program`` for ``tenant``.  In-process, ``program`` is
        the ``BuiltPipeline`` itself; remote, it is the name the server's
        ``JobRPC.register`` bound."""
        if self.server is not None:
            return self.server.submit(tenant, program, **kwargs)
        return self._call("submit", tenant=tenant, program=program, **kwargs)

    def pause(self, job_id: str) -> None:
        """Park ``job_id`` until an explicit ``resume``."""
        if self.server is not None:
            self.server.pause(job_id)
        else:
            self._call("pause", job_id=job_id)

    def resume(self, job_id: str) -> None:
        """Wake a paused job (a cold restore if it had checkpointed)."""
        if self.server is not None:
            self.server.resume(job_id)
        else:
            self._call("resume", job_id=job_id)

    def cancel(self, job_id: str) -> None:
        """Stop a job for good; persisted windows stay."""
        if self.server is not None:
            self.server.cancel(job_id)
        else:
            self._call("cancel", job_id=job_id)

    def drain(self, timeout: float | None = None) -> dict[str, str]:
        """Drive the server until every job completes; returns {job_id:
        final state}.  Remote drains can far outlast a verb round-trip,
        so ``timeout`` (when given) temporarily widens the socket
        timeout for this one call."""
        if self.server is not None:
            return self.server.run_until_complete()
        if timeout is None:
            return self._call("drain")
        old = self._rpc.timeout
        self._rpc.timeout = timeout
        self._rpc.close()          # reconnect under the widened timeout
        try:
            return self._call("drain")
        finally:
            self._rpc.timeout = old
            self._rpc.close()

    # -- monitoring (metadata-only, like the paper's Redis polling) ----------
    def status(self, job_id: str) -> dict[str, Any]:
        """One job's record: lifecycle state, cursor/checkpointed offset,
        and its compute bill (``pool_seconds``/``fold_invocations``).
        In-process this reads the metadata records only; remote it asks
        the server's ``status`` verb (which reads the same records)."""
        if self.server is None:
            return self._call("status", job_id=job_id)
        from .metadata import job_record_key
        rec = self.server.meta.hgetall(job_record_key(job_id))
        if not rec:
            raise KeyError(f"unknown job: {job_id}")
        return rec

    def jobs(self) -> list[str]:
        """Every registered job id, from the metadata index."""
        if self.server is None:
            return list(self._call("jobs"))
        from .metadata import job_index_key
        return list(self.server.meta.get(job_index_key(), []))

    async def wait(self, job_id: str, states: tuple[str, ...] = ("DONE",
                   "CANCELLED", "FAILED")) -> str:
        """Poll until ``job_id`` reaches one of ``states``; returns it."""
        while True:
            state = self.status(job_id)["state"]
            if state in states:
                return state
            await asyncio.sleep(self.poll_interval)

    async def run(self) -> dict[str, str]:
        """Drive the server to completion; returns {job_id: final state}."""
        loop = asyncio.get_running_loop()
        fut = loop.run_in_executor(None, self.drain)
        while not fut.done():
            await asyncio.sleep(self.poll_interval)
        fut.result()
        return {jid: self.status(jid)["state"] for jid in self.jobs()}

    def run_sync(self) -> dict[str, str]:
        """Synchronous wrapper over :meth:`run`."""
        return asyncio.run(self.run())
