"""Scale-to-zero worker pool — the Knative stand-in.

The paper's workers are Knative Services/JobSinks: zero replicas until an event
arrives, then the autoscaler (KPA) brings instances up toward a concurrency
target, and back down to zero after an idle window.  Cold starts are the reason
the paper's Fig. 6 is flat at small inputs — activation latency dominates.

This module reproduces those dynamics so the benchmarks can show the same
curve: a ``ServerlessPool`` holds *deactivated* worker factories; incoming
events activate instances (paying a configurable ``cold_start`` delay once per
instance), a KPA-style loop sizes the pool as ``ceil(concurrency /
target_concurrency)`` bounded by ``max_scale``, and instances retire to zero
after ``scale_to_zero_grace`` of idleness.
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class AutoscalerConfig:
    target_concurrency: int = 1        # KPA default: 1 request per instance
    max_scale: int = 64
    min_scale: int = 0                 # scale-to-zero
    cold_start: float = 0.0            # seconds to activate an instance
    scale_to_zero_grace: float = 30.0  # idle seconds before retiring


@dataclass
class _Instance:
    id: int
    started: float
    busy: bool = False
    last_used: float = field(default_factory=time.time)


class ServerlessPool:
    """A pool of identical workers with scale-from-zero semantics.

    ``submit(task)`` behaves like an event hitting a Knative service: if a warm
    idle instance exists it runs immediately; otherwise a new instance is
    activated (cold start) provided we are under ``max_scale``; otherwise the
    task queues.  Execution is synchronous in the caller's thread (workers in
    this framework are deterministic stage functions); the pool tracks *which*
    instance ran it and the latency split (cold start vs execution) so
    benchmarks can report the paper's phase numbers.
    """

    def __init__(self, name: str, config: AutoscalerConfig | None = None) -> None:
        self.name = name
        self.config = config or AutoscalerConfig()
        self._instances: dict[int, _Instance] = {}
        self._next_id = 0
        self._lock = threading.Lock()
        # instrumentation
        self.cold_starts = 0
        self.total_invocations = 0
        self.cold_start_seconds = 0.0
        self.scale_downs = 0           # retire events (reap / scale-to-zero)
        self._last_active = time.time()

    # -- KPA / KEDA sizing ----------------------------------------------------
    def _clamped_scale(self, demand: int, per_replica: int) -> int:
        c = self.config
        want = math.ceil(demand / max(1, per_replica))
        return max(c.min_scale, min(c.max_scale, want))

    def desired_scale(self, concurrency: int) -> int:
        """KPA sizing: in-flight requests over the concurrency target."""
        return self._clamped_scale(concurrency, self.config.target_concurrency)

    def desired_scale_from_backlog(self, backlog: int,
                                   per_replica: int = 1) -> int:
        """KEDA-style sizing from queue depth: unconsumed events (consumer
        lag) divided by the per-replica drain rate.  The streaming
        coordinator feeds it ``bus.lag(...)`` so the pool tracks
        backpressure instead of a fixed split count."""
        return self._clamped_scale(backlog, per_replica)

    def ensure_scale(self, n: int) -> int:
        """Pre-activate instances up to ``n`` (paying cold starts now, not on
        the critical path of the next batch).  Returns replicas added."""
        n = min(n, self.config.max_scale)
        added = 0
        with self._lock:
            while len(self._instances) < n:
                inst = _Instance(id=self._next_id, started=time.time())
                self._next_id += 1
                self._instances[inst.id] = inst
                self.cold_starts += 1
                self.cold_start_seconds += self.config.cold_start
                added += 1
            if added:
                self._last_active = time.time()
        if added and self.config.cold_start > 0:
            # concurrent activations: one cold-start wait, not ``added``
            time.sleep(self.config.cold_start)
        return added

    def replicas(self) -> int:
        with self._lock:
            return len(self._instances)

    # -- instance lifecycle ----------------------------------------------------
    def _acquire(self) -> tuple[_Instance, bool]:
        """Returns (instance, was_cold)."""
        with self._lock:
            for inst in self._instances.values():
                if not inst.busy:
                    inst.busy = True
                    inst.last_used = time.time()
                    return inst, False
            if len(self._instances) < self.config.max_scale:
                inst = _Instance(id=self._next_id, started=time.time(), busy=True)
                self._next_id += 1
                self._instances[inst.id] = inst
                return inst, True
        # pool saturated: wait for an idle instance
        while True:
            time.sleep(0.001)
            with self._lock:
                for inst in self._instances.values():
                    if not inst.busy:
                        inst.busy = True
                        inst.last_used = time.time()
                        return inst, False

    def _release(self, inst: _Instance) -> None:
        with self._lock:
            inst.busy = False
            inst.last_used = time.time()

    def reap_idle(self) -> int:
        """Retire instances idle past the grace window (scale-to-zero),
        never shrinking below ``min_scale``."""
        now = time.time()
        with self._lock:
            idle = [i for i, inst in self._instances.items()
                    if not inst.busy
                    and now - inst.last_used > self.config.scale_to_zero_grace]
            allowed = max(0, len(self._instances) - self.config.min_scale)
            dead = idle[:allowed]
            for i in dead:
                del self._instances[i]
            self.scale_downs += len(dead)
        return len(dead)

    def scale_to_zero(self) -> int:
        """Retire every idle instance immediately — the job server's park
        path, which need not wait out the grace window because the barrier
        checkpoint already made the workers' state recoverable.  Returns
        instances retired."""
        with self._lock:
            keep = {i: inst for i, inst in self._instances.items()
                    if inst.busy}
            retired = len(self._instances) - len(keep)
            self._instances = keep
            self.scale_downs += retired
        return retired

    def idle_for(self) -> float:
        """Seconds since the pool last ran (or pre-activated) anything —
        the lifecycle controller's park signal."""
        with self._lock:
            return time.time() - self._last_active

    # -- invocation -------------------------------------------------------------
    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> Any:
        inst, cold = self._acquire()
        self.total_invocations += 1
        self._last_active = time.time()
        if cold:
            self.cold_starts += 1
            if self.config.cold_start > 0:
                time.sleep(self.config.cold_start)
            self.cold_start_seconds += self.config.cold_start
        try:
            return fn(*args, **kwargs)
        finally:
            self._release(inst)

    def stats(self) -> dict[str, Any]:
        return {
            "pool": self.name,
            "replicas": self.replicas(),
            "cold_starts": self.cold_starts,
            "invocations": self.total_invocations,
            "cold_start_seconds": round(self.cold_start_seconds, 6),
            "scale_downs": self.scale_downs,
        }


@dataclass
class ComputeMeter:
    """One job's compute account: wall-clock seconds spent inside pool
    invocations plus the invocation count — the two quantities serverless
    platforms actually bill (GB-seconds and requests).  The job server
    attaches one meter per job via :class:`MeteredPool` and enforces
    per-tenant ``quota_pool_seconds`` against the summed accounts, the
    compute-side twin of the storage byte quota."""

    pool_seconds: float = 0.0
    invocations: int = 0

    def as_dict(self) -> dict[str, Any]:
        """Metering fields in the shape ``JobServer.status()`` reports."""
        return {"pool_seconds": round(self.pool_seconds, 6),
                "fold_invocations": self.invocations}


class MeteredPool:
    """A per-job accounting view of a shared :class:`ServerlessPool`.

    ``submit`` delegates to the shared pool while charging the elapsed
    wall time and one invocation to this view's :class:`ComputeMeter`;
    every other attribute proxies straight through, so a coordinator
    holding a ``MeteredPool`` sees the real pool's scaling, replica, and
    instrumentation surface unchanged.  This is how N tenants fold on
    ONE physical pool yet each receives its own bill.
    """

    def __init__(self, inner: ServerlessPool,
                 meter: ComputeMeter | None = None) -> None:
        self._inner = inner
        self.meter = meter if meter is not None else ComputeMeter()

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> Any:
        start = time.perf_counter()
        try:
            return self._inner.submit(fn, *args, **kwargs)
        finally:
            self.meter.pool_seconds += time.perf_counter() - start
            self.meter.invocations += 1

    def __getattr__(self, name: str) -> Any:
        return getattr(self._inner, name)
