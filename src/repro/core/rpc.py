"""Length-prefixed JSON-frame RPC over TCP — the control plane's wire.

The job service's verbs (submit/pause/resume/cancel/status/...) are
plain JSON dicts; this module moves them across a process boundary with
the smallest honest transport: each message is one *frame* — a 4-byte
big-endian unsigned length followed by that many bytes of UTF-8 JSON.
Stdlib only (``socketserver`` + ``struct`` + ``json``), no HTTP stack,
because the paper's control plane is request/response over a broker and
the interesting properties live above the wire: the server serializes
every dispatch under one lock (the job server's verbs are not
internally thread-safe), and the client owns timeouts and bounded
reconnect-retries — delivery is therefore at-least-once, which the
verbs tolerate (submit of a live job errors loudly; pause/resume/
cancel/status are idempotent).
"""

from __future__ import annotations

import json
import socket
import socketserver
import struct
import threading
import time
from typing import Any, Callable

__all__ = [
    "MAX_FRAME_BYTES",
    "FrameClient",
    "FrameServer",
    "RPCError",
    "recv_frame",
    "send_frame",
]

# One control-plane message should be small (verbs + status dicts); the
# cap exists so a corrupt length header can't allocate gigabytes.
MAX_FRAME_BYTES = 16 * 1024 * 1024

_HEADER = struct.Struct(">I")


class RPCError(RuntimeError):
    """A control-plane call failed — transport exhausted its retries, a
    frame was malformed/oversized, or the server answered ``ok: False``
    (in which case the message carries the server-side exception text)."""


def send_frame(sock: socket.socket, obj: Any) -> None:
    """Serialize ``obj`` as one length-prefixed JSON frame onto ``sock``.
    Raises ``ValueError`` if the payload exceeds ``MAX_FRAME_BYTES`` and
    ``TypeError`` if ``obj`` is not JSON-serializable — both before any
    bytes hit the wire, so a failed send never corrupts the stream."""
    payload = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    if len(payload) > MAX_FRAME_BYTES:
        raise ValueError(f"frame of {len(payload)} bytes exceeds "
                         f"MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
    sock.sendall(_HEADER.pack(len(payload)) + payload)


def recv_frame(sock: socket.socket) -> Any:
    """Read one frame from ``sock`` and decode its JSON body.  Returns
    ``None`` on an orderly EOF *between* frames (peer hung up cleanly);
    raises ``ConnectionError`` on EOF mid-frame and ``RPCError`` on an
    oversized length header."""
    header = _recv_exact(sock, _HEADER.size, eof_ok=True)
    if header is None:
        return None
    (length,) = _HEADER.unpack(header)
    if length > MAX_FRAME_BYTES:
        raise RPCError(f"incoming frame claims {length} bytes "
                       f"(> MAX_FRAME_BYTES={MAX_FRAME_BYTES})")
    return json.loads(_recv_exact(sock, length).decode("utf-8"))


def _recv_exact(sock: socket.socket, n: int,
                eof_ok: bool = False) -> bytes | None:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if eof_ok and not buf:
                return None
            raise ConnectionError(
                f"connection closed mid-frame ({len(buf)}/{n} bytes)")
        buf += chunk
    return bytes(buf)


class FrameServer:
    """Serve a ``handle(dict) -> dict`` callable over frame RPC.

    A ``ThreadingTCPServer`` accepts any number of concurrent clients
    (daemon threads, one frame loop per connection), but every dispatch
    into ``handle`` runs under ONE lock — clients get concurrency on the
    wire, the handler gets the single-threaded world it was written for.
    ``port=0`` binds an ephemeral port; read it back from ``address``.
    """

    def __init__(self, handle: Callable[[dict], dict],
                 host: str = "127.0.0.1", port: int = 0) -> None:
        self._handle = handle
        self._lock = threading.RLock()
        self._thread: threading.Thread | None = None
        outer = self

        class _Handler(socketserver.BaseRequestHandler):
            def handle(self) -> None:
                while True:
                    try:
                        request = recv_frame(self.request)
                    except (ConnectionError, OSError, RPCError,
                            json.JSONDecodeError):
                        return
                    if request is None:
                        return
                    with outer._lock:
                        response = outer._dispatch(request)
                    try:
                        send_frame(self.request, response)
                    except OSError:
                        return

        class _Server(socketserver.ThreadingTCPServer):
            daemon_threads = True
            allow_reuse_address = True

        self._server = _Server((host, port), _Handler)

    def _dispatch(self, request: dict) -> dict:
        try:
            response = self._handle(request)
            # force serializability server-side so the error surfaces in
            # the reply instead of tearing down the connection
            json.dumps(response)
            return response
        except Exception as exc:  # noqa: BLE001 — the wire reports, not raises
            return {"ok": False, "error": f"{type(exc).__name__}: {exc}"}

    @property
    def address(self) -> tuple[str, int]:
        """The bound ``(host, port)`` — what a ``FrameClient`` dials."""
        host, port = self._server.server_address[:2]
        return (host, port)

    def start(self) -> "FrameServer":
        """Begin serving on a daemon thread; returns ``self`` so
        ``server = FrameServer(h).start()`` reads naturally."""
        self._thread = threading.Thread(
            target=self._server.serve_forever,
            kwargs={"poll_interval": 0.05},
            name=f"frame-server:{self.address[1]}", daemon=True)
        self._thread.start()
        return self

    def close(self) -> None:
        """Stop accepting, close the listening socket, join the serve
        thread.  Idempotent."""
        self._server.shutdown()
        self._server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "FrameServer":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.close()


class FrameClient:
    """Dial a :class:`FrameServer` and exchange one frame per call.

    The connection is lazy and persistent; ``timeout`` bounds every
    socket operation and ``retries`` bounds reconnect-and-resend
    attempts on transport failure (connection refused, timeout, peer
    reset), with linear backoff between attempts.  Resending after a
    sent-but-unanswered request makes delivery at-least-once — fine for
    this control plane, whose verbs are idempotent or loudly duplicate-
    rejecting.  When every attempt fails, raises :class:`RPCError`
    carrying the last transport error.
    """

    def __init__(self, address: tuple[str, int], *, timeout: float = 5.0,
                 retries: int = 2, retry_delay: float = 0.05) -> None:
        self.address = (address[0], int(address[1]))
        self.timeout = timeout
        self.retries = max(0, int(retries))
        self.retry_delay = retry_delay
        self._sock: socket.socket | None = None

    def _connect(self) -> socket.socket:
        if self._sock is None:
            self._sock = socket.create_connection(self.address,
                                                  timeout=self.timeout)
            self._sock.settimeout(self.timeout)
        return self._sock

    def call(self, request: dict) -> dict:
        """One request frame out, one response frame back."""
        last: Exception | None = None
        for attempt in range(self.retries + 1):
            try:
                sock = self._connect()
                send_frame(sock, request)
                response = recv_frame(sock)
                if response is None:
                    raise ConnectionError("server closed the connection")
                return response
            except (OSError, ConnectionError) as exc:
                last = exc
                self.close()
                if attempt < self.retries:
                    time.sleep(self.retry_delay * (attempt + 1))
        raise RPCError(f"rpc to {self.address[0]}:{self.address[1]} failed "
                       f"after {self.retries + 1} attempt(s): {last}")

    def close(self) -> None:
        """Drop the persistent connection (the next ``call`` redials).
        Idempotent."""
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def __enter__(self) -> "FrameClient":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
