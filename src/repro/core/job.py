"""MapReduce job configuration — the paper's JSON input (§III-C).

The paper's client sends the Coordinator a JSON document with: input/output S3
locations, the number of Mapper and Reducer components, whether a Finalizer
runs, text/binary split mode, buffer sizes, the spill threshold as a percent,
the reducer merge fan-in (k of the k-way merge), the multipart size, and the
user-defined map/reduce function *source code* (the client package extracts it
with ``inspect.getsource`` and appends it to the payload — Fig. 4/5).

``JobConfig`` is that document, with validation and (de)serialization.  UDFs
travel as source strings and are re-materialized in the worker with ``exec`` —
the same mechanism the paper uses to ship Python functions into containers.
"""

from __future__ import annotations

import inspect
import json
import textwrap
import uuid
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Iterable, Iterator

MB = 1024 * 1024


@dataclass
class JobConfig:
    # locations
    input_prefix: str = "input/"
    output_prefix: str = "output/"
    # component counts — the paper's evaluation uses 4 mappers / 2 reducers
    n_mappers: int = 4
    n_reducers: int = 2
    run_finalizer: bool = True
    # split mode: text extends chunk boundaries to record separators (§III-A.2)
    binary_input: bool = False
    record_separator: bytes = b"\n"
    # buffers — paper defaults: 50 MB in/out buffers, 5 MB multipart,
    # 75% spill threshold, merge fan-in 100
    input_buffer_bytes: int = 50 * MB
    output_buffer_bytes: int = 50 * MB
    multipart_bytes: int = 5 * MB
    spill_threshold: float = 0.75
    merge_fan_in: int = 100
    # combiner (local reduce before spill — §II-A.1)
    run_combiner: bool = True
    # UDF source code (shipped as strings, per the paper's client package)
    mapper_src: str = ""
    reducer_src: str = ""
    combiner_src: str = ""          # defaults to reducer when combiner enabled
    # identity
    job_id: str = field(default_factory=lambda: uuid.uuid4().hex[:12])

    # -- validation ---------------------------------------------------------
    def validate(self) -> None:
        if self.n_mappers < 1:
            raise ValueError("need at least one mapper")
        if self.n_reducers < 0:
            raise ValueError("n_reducers must be >= 0 (0 = map-only workflow)")
        if not (0.0 < self.spill_threshold <= 1.0):
            raise ValueError("spill_threshold is a fraction in (0, 1]")
        if self.merge_fan_in < 2:
            raise ValueError("merge fan-in must be >= 2")
        if not self.mapper_src:
            raise ValueError("mapper source is required")
        if self.n_reducers > 0 and not self.reducer_src:
            raise ValueError("reducer source required when reducers requested")

    # -- JSON wire format ------------------------------------------------------
    def to_json(self) -> str:
        d = asdict(self)
        d["record_separator"] = self.record_separator.decode("latin-1")
        return json.dumps(d)

    @classmethod
    def from_json(cls, blob: str | dict[str, Any]) -> "JobConfig":
        d = dict(json.loads(blob)) if isinstance(blob, str) else dict(blob)
        if isinstance(d.get("record_separator"), str):
            d["record_separator"] = d["record_separator"].encode("latin-1")
        return cls(**d)

    # -- UDF handling ------------------------------------------------------------
    @staticmethod
    def extract_source(fn: Callable) -> str:
        """What the client package does to user functions (Fig. 4)."""
        return textwrap.dedent(inspect.getsource(fn))

    def with_functions(self, mapper: Callable, reducer: Callable | None = None,
                       combiner: Callable | None = None) -> "JobConfig":
        self.mapper_src = self.extract_source(mapper)
        if reducer is not None:
            self.reducer_src = self.extract_source(reducer)
        if combiner is not None:
            self.combiner_src = self.extract_source(combiner)
        return self


def load_udf(src: str) -> Callable:
    """Materialize a shipped UDF in a worker.

    The namespace is restricted to builtins — UDFs in this framework are pure
    record transforms, as in the paper's word-count example (Fig. 5).
    """
    ns: dict[str, Any] = {}
    exec(src, ns)  # noqa: S102 - the paper ships user code the same way
    fns = [v for k, v in ns.items()
           if callable(v) and not k.startswith("__")]
    if not fns:
        raise ValueError("UDF source defines no function")
    return fns[0]


# -- the paper's Fig. 5 word-count UDFs, used across tests/benchmarks --------

def wordcount_mapper(key: Any, chunk: str) -> Iterator[tuple[str, int]]:
    for word in chunk.split():
        yield word, 1


def wordcount_reducer(key: str, values: Iterable[int]) -> tuple[str, int]:
    total = sum(values)
    return key, total


def make_wordcount_job(**overrides: Any) -> JobConfig:
    cfg = JobConfig(**overrides)
    return cfg.with_functions(wordcount_mapper, wordcount_reducer)
