"""Workflow metadata store — the Redis stand-in.

The paper keeps all workflow state in Redis: split byte-range metadata from the
Splitter, per-task progress updates from Mappers/Reducers, and overall job state
that the Python client polls (§III-D).  Workers are stateless precisely because
this store is not.

API kept deliberately Redis-shaped (GET/SET/HSET/HGETALL/INCR/expiry/watch) so
the coordinator and client code reads like the system in the paper.  A JSON
snapshot/restore path makes coordinator restart (fault tolerance) testable.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable


class MetadataStore:
    """In-memory, thread-safe, Redis-like KV store with hashes and counters."""

    def __init__(self, persist_path: str | None = None) -> None:
        self._kv: dict[str, Any] = {}
        self._hashes: dict[str, dict[str, Any]] = {}
        self._expiry: dict[str, float] = {}
        self._lock = threading.RLock()
        self._watchers: list[Callable[[str, Any], None]] = []
        self.persist_path = persist_path
        if persist_path and os.path.isfile(persist_path):
            self.restore(persist_path)

    # -- plain KV ----------------------------------------------------------
    def set(self, key: str, value: Any, ttl: float | None = None) -> None:
        with self._lock:
            self._kv[key] = value
            if ttl is not None:
                self._expiry[key] = time.time() + ttl
            else:
                self._expiry.pop(key, None)
        for w in list(self._watchers):
            w(key, value)

    def get(self, key: str, default: Any = None) -> Any:
        with self._lock:
            if key in self._expiry and time.time() > self._expiry[key]:
                self._kv.pop(key, None)
                self._expiry.pop(key, None)
            return self._kv.get(key, default)

    def delete(self, key: str) -> None:
        with self._lock:
            self._kv.pop(key, None)
            self._hashes.pop(key, None)
            self._expiry.pop(key, None)

    def keys(self, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(k for k in self._kv if k.startswith(prefix))

    def incr(self, key: str, amount: int = 1) -> int:
        """Atomic counter — used for completed-task counts the Coordinator
        checks to decide a stage is done."""
        with self._lock:
            val = int(self._kv.get(key, 0)) + amount
            self._kv[key] = val
            return val

    # -- hashes (Redis HSET/HGETALL) ----------------------------------------
    def hset(self, key: str, field: str, value: Any) -> None:
        with self._lock:
            self._hashes.setdefault(key, {})[field] = value

    def hget(self, key: str, field: str, default: Any = None) -> Any:
        with self._lock:
            return self._hashes.get(key, {}).get(field, default)

    def hgetall(self, key: str) -> dict[str, Any]:
        with self._lock:
            return dict(self._hashes.get(key, {}))

    def hdel(self, key: str, field: str) -> None:
        with self._lock:
            self._hashes.get(key, {}).pop(field, None)

    # -- pub-sub-ish watch ---------------------------------------------------
    def watch(self, fn: Callable[[str, Any], None]) -> None:
        self._watchers.append(fn)

    # -- persistence (coordinator restart) -----------------------------------
    def snapshot(self, path: str | None = None) -> None:
        path = path or self.persist_path
        if path is None:
            raise ValueError("no persist path configured")
        with self._lock:
            blob = json.dumps({"kv": self._kv, "hashes": self._hashes},
                              default=str)
        tmp = path + ".tmp"
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(tmp, "w") as f:
            f.write(blob)
        os.replace(tmp, path)

    def restore(self, path: str | None = None) -> None:
        path = path or self.persist_path
        if path is None or not os.path.isfile(path):
            return
        with open(path) as f:
            blob = json.load(f)
        with self._lock:
            self._kv = blob.get("kv", {})
            self._hashes = blob.get("hashes", {})


# -- key helpers: the schema the paper's components share --------------------

def job_state_key(job_id: str) -> str:
    return f"job:{job_id}:state"


def job_config_key(job_id: str) -> str:
    return f"job:{job_id}:config"


def split_key(job_id: str, mapper_id: int) -> str:
    """Byte-range metadata the Splitter writes for each Mapper (§III-A.2)."""
    return f"job:{job_id}:split:{mapper_id}"


def task_status_key(job_id: str, role: str, worker_id: int) -> str:
    return f"job:{job_id}:{role}:{worker_id}:status"


def stage_done_counter(job_id: str, role: str) -> str:
    return f"job:{job_id}:{role}:done"


# -- job-service schema: the control plane's per-job records -----------------

def job_record_key(job_id: str) -> str:
    """Hash holding one submitted job's control-plane record (tenant,
    state, sink prefixes, cursor, park/restore counters)."""
    return f"jobsvc:job:{job_id}"


def job_index_key() -> str:
    """KV key whose value is the sorted list of all submitted job ids —
    what ``status()`` and the registry's collision scan iterate."""
    return "jobsvc:jobs"
