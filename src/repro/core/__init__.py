"""Core — the paper's serverless MapReduce system.

Host plane (paper-faithful components):
  storage (S3), metadata (Redis), events (Kafka/CloudEvents),
  autoscaler (Knative KPA), splitter, workers (Mapper/Reducer/Finalizer),
  coordinator (job state machine), job (JSON config), client (Fig. 4 package).

Device plane (the TPU-native realization):
  shuffle (hash-partition all_to_all / reduce_scatter),
  mapreduce (device-engine helpers; one-shot jobs are authored as
  ``repro.pipeline`` programs since the PR 8 shim removal).
"""

from .autoscaler import (AutoscalerConfig, ComputeMeter, MeteredPool,
                         ServerlessPool)
from .client import Job, JobServiceClient, MapReduce
from .coordinator import Coordinator, JobReport, JobState
from .events import CloudEvent, EventBus
from .job import JobConfig, make_wordcount_job
from .mapreduce import (DeviceJobConfig, clear_window_slot, init_window_carry,
                        make_incremental_step, read_window_slot,
                        segment_reduce)
from .metadata import MetadataStore
from .rpc import FrameClient, FrameServer, RPCError
from .splitter import ByteRange, split_object, split_prefix
from .storage import (FileStore, MemoryStore, NamespacedStore, ObjectStore,
                      QuotaExceeded)
from .workers import read_final_output, run_mapper, run_reducer

__all__ = [
    "AutoscalerConfig", "ComputeMeter", "MeteredPool", "ServerlessPool",
    "Job", "MapReduce", "Coordinator",
    "JobReport", "JobState", "CloudEvent", "EventBus", "JobConfig",
    "make_wordcount_job", "DeviceJobConfig", "segment_reduce",
    "make_incremental_step", "init_window_carry", "read_window_slot",
    "clear_window_slot", "FrameClient", "FrameServer", "RPCError",
    "MetadataStore", "ByteRange", "split_object", "split_prefix", "FileStore",
    "MemoryStore", "NamespacedStore", "ObjectStore", "QuotaExceeded",
    "JobServiceClient", "read_final_output", "run_mapper", "run_reducer",
]
