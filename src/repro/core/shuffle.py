"""Device-side shuffle — compatibility façade over ``repro.engine.stages``.

The paper's hash-partition + sorted-spill + merge, re-expressed on a TPU
mesh, lives in the execution-plan layer now (``engine/stages.py``); this
module keeps the original import surface so the host engine, kernels, and
tests are untouched.  See ``engine.stages`` for the stage bodies and
``engine.plan`` for how they compose into execution plans.
"""

from ..engine.stages import (INVALID, ShuffleStats, bucket_owner,
                             build_send_buffers, device_hash, exchange,
                             hash_partition, local_combine_dense,
                             resolve_combine_fn, shuffle_aggregate,
                             shuffle_aggregate_windowed, shuffle_group,
                             sort_and_group)

__all__ = [
    "INVALID", "ShuffleStats", "bucket_owner", "build_send_buffers",
    "device_hash", "exchange", "hash_partition", "local_combine_dense",
    "resolve_combine_fn", "shuffle_aggregate", "shuffle_aggregate_windowed",
    "shuffle_group", "sort_and_group",
]
