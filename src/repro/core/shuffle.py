"""Device-side shuffle — the paper's hash-partition + sorted-spill + merge,
re-expressed on a TPU mesh (DESIGN.md §2/§4).

The paper's shuffle writes hash-partitioned, key-sorted spill files through S3
because FaaS workers share no fabric.  A pod shares ICI, so:

  * partition ``hash(key) % R``        →  the same hash, on int32 key ids
  * spill upload + reducer download    →  one ``jax.lax.all_to_all``
  * sorted spill runs + k-way merge    →  ``jax.lax.sort`` of the concatenated
                                          runs (XLA's sort is a bitonic
                                          network — the TPU-shaped merge)
  * combiner before spill              →  local bucket pre-reduction
                                          (``kernels/hash_combine`` on MXU)

Two execution modes, chosen by the reduce function's algebra:

  * **aggregating** (commutative+associative reduce, e.g. wordcount):
    records combine into a dense per-bucket vector locally, then a single
    ``reduce_scatter`` both shuffles *and* reduces — the combiner fused into
    the collective.  This is the fast path and the paper's combiner insight
    taken to its limit.
  * **grouping** (general reduce over the full value list): records are
    exchanged with ``all_to_all`` into fixed-capacity per-partition buffers,
    then key-sorted and segment-grouped.

All functions are pure and usable inside ``jax.shard_map`` or single-device.
Keys are int32 ids in ``[0, num_buckets)`` (the data layer maps raw keys to
ids); values are float32/int32 arrays with leading axis = records.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

INVALID = jnp.int32(-1)


def device_hash(keys: jax.Array) -> jax.Array:
    """murmur3 finalizer over int32 keys — stable, well-mixed, vectorized.

    The device analogue of the FNV-1a the host workers use on strings.
    """
    h = keys.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def hash_partition(keys: jax.Array, n_partitions: int) -> jax.Array:
    """``hash(key) % R`` → destination partition (reducer) per record."""
    return (device_hash(keys) % jnp.uint32(n_partitions)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Local combine (the Mapper's sort+combiner, §III-A.3)
# ---------------------------------------------------------------------------

def local_combine_dense(keys: jax.Array, values: jax.Array, num_buckets: int,
                        valid: jax.Array | None = None) -> jax.Array:
    """Combine records into a dense per-bucket sum vector.

    TPU adaptation of the sorted spill + combiner: instead of comparison
    sorting, bucket-accumulate.  XLA lowers segment-sum as scatter-add; the
    Pallas ``hash_combine`` kernel does the same with one-hot MXU matmuls
    (see kernels/hash_combine).  Output is 'born sorted' by bucket id.
    """
    if valid is not None:
        vmask = valid.reshape((-1,) + (1,) * (values.ndim - 1))
        values = jnp.where(vmask, values, jnp.zeros_like(values))
        keys = jnp.where(valid, keys, 0)
    seg = jax.ops.segment_sum(values, keys.astype(jnp.int32),
                              num_segments=num_buckets)
    return seg


def sort_and_group(keys: jax.Array, values: jax.Array,
                   valid: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Key-sort records (invalid to the end) — the merged, grouped stream the
    Reducer consumes.  Returns (sorted_keys, sorted_values, group_starts) where
    ``group_starts[i]`` is 1 when a new key group begins at i."""
    if valid is None:
        valid = jnp.ones_like(keys, dtype=bool)
    sort_keys = jnp.where(valid, keys, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(sort_keys, stable=True)
    sk = sort_keys[order]
    sv = jnp.take(values, order, axis=0)
    starts = jnp.concatenate([
        jnp.ones((1,), dtype=jnp.int32),
        (sk[1:] != sk[:-1]).astype(jnp.int32),
    ])
    starts = jnp.where(sk == jnp.iinfo(jnp.int32).max, 0, starts)
    return sk, sv, starts


# ---------------------------------------------------------------------------
# The exchange (spill upload + download → all_to_all)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShuffleStats:
    """Per-device accounting, the analogue of the paper's bytes_in/bytes_out."""

    sent: jax.Array       # records sent (valid, pre-exchange)
    dropped: jax.Array    # records dropped by capacity overflow


def build_send_buffers(keys: jax.Array, values: jax.Array, n_partitions: int,
                       capacity: int, valid: jax.Array | None = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array, ShuffleStats]:
    """Pack records into fixed (n_partitions, capacity) send buffers.

    The device analogue of writing one spill file per reducer: records are
    sorted by destination partition (so each partition's slice is contiguous
    — a 'file'), padded/truncated to ``capacity``.  Returns (send_keys,
    send_values, send_valid, stats).
    """
    n = keys.shape[0]
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    dest = jnp.where(valid, hash_partition(keys, n_partitions),
                     jnp.int32(n_partitions))  # invalid → virtual partition R
    order = jnp.argsort(dest, stable=True)
    d_sorted = dest[order]
    k_sorted = keys[order]
    v_sorted = jnp.take(values, order, axis=0)
    # position of each record within its destination group
    counts = jnp.bincount(d_sorted, length=n_partitions + 1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos_in_group = jnp.arange(n, dtype=jnp.int32) - offsets[d_sorted]
    in_cap = (pos_in_group < capacity) & (d_sorted < n_partitions)
    slot = jnp.where(in_cap, d_sorted * capacity + pos_in_group, n_partitions * capacity)

    send_keys = jnp.full((n_partitions * capacity + 1,), INVALID, dtype=keys.dtype)
    send_keys = send_keys.at[slot].set(jnp.where(in_cap, k_sorted, INVALID))
    val_shape = (n_partitions * capacity + 1,) + values.shape[1:]
    send_vals = jnp.zeros(val_shape, dtype=values.dtype)
    send_vals = send_vals.at[slot].set(
        jnp.where(in_cap.reshape((-1,) + (1,) * (values.ndim - 1)),
                  v_sorted, jnp.zeros_like(v_sorted)))
    send_valid = jnp.zeros((n_partitions * capacity + 1,), dtype=bool)
    send_valid = send_valid.at[slot].set(in_cap)

    sent = jnp.sum(counts[:n_partitions].astype(jnp.int32))
    kept = jnp.sum(send_valid[:-1].astype(jnp.int32))
    stats = ShuffleStats(sent=sent, dropped=sent - kept)
    return (send_keys[:-1].reshape(n_partitions, capacity),
            send_vals[:-1].reshape((n_partitions, capacity) + values.shape[1:]),
            send_valid[:-1].reshape(n_partitions, capacity),
            stats)


def exchange(send_keys: jax.Array, send_values: jax.Array,
             send_valid: jax.Array, axis_name: str
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The shuffle proper: one tiled all_to_all per tensor over the mesh axis.

    Row p of the send buffer goes to device p; row q of the result came from
    device q — i.e. every reducer receives one 'spill file' from every mapper,
    in a single ICI collective instead of 2·M·R object-store transfers.
    """
    a2a = partial(jax.lax.all_to_all, axis_name=axis_name,
                  split_axis=0, concat_axis=0, tiled=True)
    return a2a(send_keys), a2a(send_values), a2a(send_valid)


# ---------------------------------------------------------------------------
# Whole-shuffle compositions (used by core.mapreduce inside shard_map)
# ---------------------------------------------------------------------------

def shuffle_group(keys: jax.Array, values: jax.Array, axis_name: str,
                  n_partitions: int, capacity: int,
                  valid: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array, jax.Array, ShuffleStats]:
    """Grouping shuffle: exchange + merge.  Per device returns the key-sorted,
    group-marked record stream for this device's partition."""
    sk, sv, svalid, stats = build_send_buffers(keys, values, n_partitions,
                                               capacity, valid)
    rk, rv, rvalid = exchange(sk, sv, svalid, axis_name)
    rk = rk.reshape(-1)
    rv = rv.reshape((-1,) + rv.shape[2:])
    rvalid = rvalid.reshape(-1)
    out_k, out_v, starts = sort_and_group(rk, rv, rvalid)
    return out_k, out_v, starts, stats


def shuffle_aggregate(keys: jax.Array, values: jax.Array, axis_name: str,
                      num_buckets: int, valid: jax.Array | None = None,
                      combine_fn=None) -> jax.Array:
    """Aggregating shuffle: local combine (the combiner) + reduce_scatter.

    Each device returns its contiguous ``num_buckets / P`` slice of the fully
    reduced bucket vector — hash-partitioned ownership, exactly the paper's
    reducer assignment, fused into one collective.
    ``combine_fn(keys, values, num_buckets, valid)`` defaults to the dense jnp
    combiner; the Pallas kernel slots in through this hook.
    """
    combine_fn = combine_fn or local_combine_dense
    local = combine_fn(keys, values, num_buckets, valid)
    # reduce_scatter: sum over devices, scatter bucket ranges
    return jax.lax.psum_scatter(local, axis_name, scatter_dimension=0,
                                tiled=True)


def shuffle_aggregate_windowed(window_slots: jax.Array, keys: jax.Array,
                               values: jax.Array, axis_name: str,
                               n_slots: int, num_buckets: int,
                               valid: jax.Array | None = None,
                               combine_fn=None) -> jax.Array:
    """Windowed aggregating shuffle for the streaming engine.

    Records carry a *window slot* (a bounded ring index for an in-flight
    window) in addition to the bucket key.  The (slot, bucket) pair flattens
    into one dense id space of ``n_slots * num_buckets`` so the whole
    micro-batch still folds through a single fused ``reduce_scatter`` — the
    batch engine's combiner-in-the-collective, carried across batches.

    Each device returns its contiguous slice of the flattened
    ``(n_slots * num_buckets,) + values.shape[1:]`` update vector; the caller
    adds it to the carried window state (same layout).  Requires
    ``(n_slots * num_buckets) %`` axis size ``== 0``.
    """
    flat = window_slots.astype(jnp.int32) * num_buckets + keys.astype(jnp.int32)
    return shuffle_aggregate(flat, values, axis_name, n_slots * num_buckets,
                             valid=valid, combine_fn=combine_fn)


def bucket_owner(num_buckets: int, n_partitions: int) -> np.ndarray:
    """Host helper: which partition owns each bucket id under the aggregating
    shuffle's tiled scatter (contiguous ranges over the padded bucket
    space — see core.mapreduce's aggregate padding)."""
    per = -(-num_buckets // n_partitions)
    return np.minimum(np.arange(num_buckets) // per, n_partitions - 1)
