"""Splitter — byte-range division of the input (§III-A.2).

Given S3 path prefixes the Splitter measures total input size, divides it into
``n_mappers`` equal byte ranges, and — for text input — extends each boundary
forward to the next record separator so no record is cut in half.  Binary
input splits purely on byte offsets.  The resulting ranges are written to the
metadata store so stateless Mappers can ranged-GET their chunk.

The same algorithm shards the training corpus across data-parallel hosts in
``repro.data`` — one subsystem, two consumers, as DESIGN.md §2 lays out.
"""

from __future__ import annotations

from dataclasses import dataclass

from .metadata import MetadataStore, split_key
from .storage import ObjectStore


@dataclass(frozen=True)
class ByteRange:
    """A half-open byte range [lo, hi) within one object."""

    key: str
    lo: int
    hi: int

    @property
    def size(self) -> int:
        return self.hi - self.lo

    def to_meta(self) -> dict:
        return {"key": self.key, "lo": self.lo, "hi": self.hi}

    @classmethod
    def from_meta(cls, d: dict) -> "ByteRange":
        return cls(d["key"], int(d["lo"]), int(d["hi"]))


def _extend_to_separator(store: ObjectStore, key: str, pos: int, size: int,
                         sep: bytes, probe: int = 64 * 1024) -> int:
    """Move ``pos`` forward to just past the next separator (or EOF).

    Mirrors the paper: 'In case of the input being text-based, the splitter
    extends the boundaries it will split, in order to not cut any record in
    half.'  Probes in bounded ranged-GETs to avoid reading whole objects.
    """
    if pos <= 0 or pos >= size:
        return max(0, min(pos, size))
    while pos < size:
        chunk = store.get(key, (pos, min(pos + probe, size)))
        idx = chunk.find(sep)
        if idx >= 0:
            return pos + idx + len(sep)
        pos += len(chunk)
    return size


def split_object(store: ObjectStore, key: str, n_splits: int,
                 binary: bool = False, sep: bytes = b"\n") -> list[ByteRange]:
    """Split one object into ``n_splits`` contiguous byte ranges."""
    size = store.head(key).size
    if size == 0 or n_splits < 1:
        return []
    n_splits = min(n_splits, size)  # never hand out empty ranges
    raw = [round(i * size / n_splits) for i in range(n_splits + 1)]
    if binary:
        bounds = raw
    else:
        bounds = [0]
        for b in raw[1:-1]:
            adj = _extend_to_separator(store, key, b, size, sep)
            # keep bounds monotone — a long record can swallow a split
            bounds.append(max(adj, bounds[-1]))
        bounds.append(size)
    out = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi > lo:
            out.append(ByteRange(key, lo, hi))
    return out


def split_prefix(store: ObjectStore, prefix: str, n_mappers: int,
                 binary: bool = False, sep: bytes = b"\n") -> list[list[ByteRange]]:
    """Split everything under an S3 prefix into ``n_mappers`` assignments.

    Sizes the per-object split counts proportionally to object size so the
    payload is 'equally distributed' across Mappers (§III-A.2), then
    round-robins ranges into per-mapper lists balanced by bytes.
    """
    objs = store.list_objects(prefix)
    total = sum(m.size for m in objs)
    if total == 0:
        return [[] for _ in range(n_mappers)]
    ranges: list[ByteRange] = []
    for m in objs:
        if m.size == 0:
            continue
        # at least 1 split per object; proportional share of the mapper count
        n = max(1, round(n_mappers * m.size / total))
        ranges.extend(split_object(store, m.key, n, binary, sep))
    # greedy balance: biggest range to the lightest mapper
    assignments: list[list[ByteRange]] = [[] for _ in range(n_mappers)]
    loads = [0] * n_mappers
    for r in sorted(ranges, key=lambda r: -r.size):
        i = loads.index(min(loads))
        assignments[i].append(r)
        loads[i] += r.size
    return assignments


def publish_splits(meta: MetadataStore, job_id: str,
                   assignments: list[list[ByteRange]]) -> None:
    """Write chunk metadata to the store for Mappers to fetch (§III-A.2)."""
    for mapper_id, ranges in enumerate(assignments):
        meta.set(split_key(job_id, mapper_id),
                 [r.to_meta() for r in ranges])


def fetch_split(meta: MetadataStore, job_id: str, mapper_id: int) -> list[ByteRange]:
    raw = meta.get(split_key(job_id, mapper_id), [])
    return [ByteRange.from_meta(d) for d in raw]
