"""Coordinator — the job state machine (§III-A.1).

The Coordinator is the entry point: it receives the JSON job config, assigns
work to the Splitter, creates and synchronizes Mapper/Reducer/Finalizer
workers by producing CloudEvents, tracks progress through status events and
the metadata store, and updates job state on any failure.  It is stateless —
all durable state lives in the metadata store, so a restarted Coordinator can
resume a job from the recorded stage (tested in tests/test_fault_tolerance.py).

Beyond the paper (which inherits these from Knative/Kubernetes restarts), the
coordinator implements the two classic MapReduce reliability mechanisms that
thousand-node deployments need, both enabled by stateless workers +
deterministic spill naming:

  * **retries** — a failed task is re-produced up to ``max_task_retries``;
    re-execution overwrites the same spill keys with identical bytes, so
    retries are idempotent;
  * **speculative execution** — when a running task exceeds
    ``straggler_factor ×`` the median completed-task duration, a duplicate is
    launched; first completion wins (per-task done flags in metadata).
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable

from .autoscaler import AutoscalerConfig, ServerlessPool
from .events import (EventBus, TOPIC_STATUS, status_event, trigger_event)
from .job import JobConfig
from .metadata import (MetadataStore, job_config_key, job_state_key,
                       stage_done_counter, task_status_key)
from .splitter import publish_splits, split_prefix
from .storage import ObjectStore
from .workers import PhaseTimes, run_finalizer, run_mapper, run_reducer


class JobState(str, Enum):
    PENDING = "PENDING"
    SPLITTING = "SPLITTING"
    MAPPING = "MAPPING"
    REDUCING = "REDUCING"
    FINALIZING = "FINALIZING"
    DONE = "DONE"
    FAILED = "FAILED"


@dataclass
class TaskResult:
    role: str
    worker_id: int
    attempt: int
    times: PhaseTimes
    speculative: bool = False


@dataclass
class JobReport:
    job_id: str
    state: JobState
    task_results: list[TaskResult] = field(default_factory=list)
    wall_time: float = 0.0
    retries: int = 0
    speculative_launches: int = 0
    error: str | None = None

    def component_times(self) -> dict[str, float]:
        """Average total seconds per component — the paper's Fig. 7 quantity."""
        by_role: dict[str, list[float]] = {}
        for t in self.task_results:
            by_role.setdefault(t.role, []).append(t.times.total)
        return {r: sum(v) / len(v) for r, v in by_role.items()}

    def phase_times(self) -> dict[str, dict[str, float]]:
        """Per-component per-phase averages — the paper's Fig. 8 quantity."""
        by_role: dict[str, list[PhaseTimes]] = {}
        for t in self.task_results:
            by_role.setdefault(t.role, []).append(t.times)
        out = {}
        for r, ts in by_role.items():
            n = len(ts)
            out[r] = {
                "processing": sum(t.processing for t in ts) / n,
                "uploading": sum(t.uploading for t in ts) / n,
                "downloading": sum(t.downloading for t in ts) / n,
            }
        return out


class Coordinator:
    """Drives MapReduce jobs to completion over the event bus + worker pools."""

    def __init__(self, store: ObjectStore, meta: MetadataStore,
                 bus: EventBus | None = None,
                 autoscaler: AutoscalerConfig | None = None,
                 max_task_retries: int = 2,
                 straggler_factor: float = 3.0,
                 straggler_min_seconds: float = 0.5,
                 speculative_execution: bool = True,
                 fault_injector: Callable[[str, int, int], None] | None = None,
                 max_workers: int = 16) -> None:
        self.store = store
        self.meta = meta
        self.bus = bus or EventBus()
        self.max_task_retries = max_task_retries
        self.straggler_factor = straggler_factor
        self.straggler_min_seconds = straggler_min_seconds
        self.speculative_execution = speculative_execution
        self.fault_injector = fault_injector
        ac = autoscaler or AutoscalerConfig(max_scale=max_workers)
        # one scale-to-zero pool per component role, like one Knative
        # Service/JobSink per component in the paper
        self.pools = {role: ServerlessPool(role, ac)
                      for role in ("splitter", "mapper", "reducer", "finalizer")}
        self._executor = ThreadPoolExecutor(max_workers=max_workers * 2)
        self._lock = threading.Lock()

    # -- state handling -------------------------------------------------------
    def _set_state(self, job_id: str, state: JobState) -> None:
        self.meta.set(job_state_key(job_id), state.value)
        if self.meta.persist_path:
            self.meta.snapshot()

    def job_state(self, job_id: str) -> JobState:
        raw = self.meta.get(job_state_key(job_id), JobState.PENDING.value)
        return JobState(raw)

    # -- task execution ----------------------------------------------------------
    def _run_task(self, cfg: JobConfig, role: str, worker_id: int,
                  attempt: int) -> PhaseTimes:
        """Execute one worker inside its scale-to-zero pool.  The event-bus
        round trip (trigger out, status back) happens even though execution is
        in-process, so event accounting matches the paper's architecture."""
        self.bus.produce(f"repro.{role}",
                         trigger_event(role, cfg.job_id, worker_id,
                                       {"attempt": attempt}),
                         key=f"{cfg.job_id}/{worker_id}")
        if self.fault_injector is not None:
            self.fault_injector(role, worker_id, attempt)
        if role == "mapper":
            times = self.pools[role].submit(run_mapper, cfg, worker_id,
                                            self.store, self.meta)
        elif role == "reducer":
            times = self.pools[role].submit(run_reducer, cfg, worker_id,
                                            self.store, self.meta)
        elif role == "finalizer":
            times = self.pools[role].submit(run_finalizer, cfg, self.store,
                                            self.meta)
        else:
            raise ValueError(role)
        self.bus.produce(TOPIC_STATUS,
                         status_event(role, cfg.job_id, worker_id, "done",
                                      times.as_dict()),
                         key=f"{cfg.job_id}/{worker_id}")
        return times

    def _run_stage(self, cfg: JobConfig, role: str, n_workers: int,
                   report: JobReport) -> None:
        """Run one stage's tasks in parallel with retries + speculation."""
        done_flags: dict[int, bool] = {}
        durations: list[float] = []
        inflight: dict[Future, tuple[int, int, float, bool]] = {}

        def launch(worker_id: int, attempt: int, speculative: bool) -> None:
            fut = self._executor.submit(self._run_task, cfg, role, worker_id,
                                        attempt)
            inflight[fut] = (worker_id, attempt, time.perf_counter(), speculative)

        for w in range(n_workers):
            done_flags[w] = False
            launch(w, 0, False)

        while inflight:
            done, _pending = wait(list(inflight), timeout=0.05,
                                  return_when=FIRST_COMPLETED)
            for fut in done:
                worker_id, attempt, t0, speculative = inflight.pop(fut)
                try:
                    times = fut.result()
                except Exception as exc:  # task failed → retry
                    if done_flags[worker_id]:
                        continue  # a twin already finished; ignore
                    if attempt >= self.max_task_retries:
                        for f in inflight:
                            f.cancel()
                        raise RuntimeError(
                            f"{role}-{worker_id} failed after "
                            f"{attempt + 1} attempts: {exc}") from exc
                    report.retries += 1
                    launch(worker_id, attempt + 1, False)
                    continue
                if done_flags[worker_id]:
                    continue  # speculative twin lost the race
                done_flags[worker_id] = True
                durations.append(time.perf_counter() - t0)
                self.meta.set(task_status_key(cfg.job_id, role, worker_id),
                              {"status": "done", **times.as_dict()})
                report.task_results.append(
                    TaskResult(role, worker_id, attempt, times, speculative))
            # straggler check: anything running far beyond the median?
            if self.speculative_execution and durations:
                durations.sort()
                median = durations[len(durations) // 2]
                threshold = max(self.straggler_min_seconds,
                                self.straggler_factor * median)
                now = time.perf_counter()
                running = {wid for (wid, _a, _t, _s) in inflight.values()}
                spec_counts = sum(1 for (_w, _a, _t, s) in inflight.values() if s)
                for fut, (wid, attempt, t0, spec) in list(inflight.items()):
                    if (not spec and not done_flags[wid]
                            and now - t0 > threshold
                            and list(running).count(wid) < 2
                            and spec_counts < n_workers):
                        report.speculative_launches += 1
                        launch(wid, attempt, True)
                        running.add(wid)
                        spec_counts += 1

    # -- the workflow (Fig. 2) -----------------------------------------------------
    def run_job(self, cfg: JobConfig) -> JobReport:
        cfg.validate()
        report = JobReport(cfg.job_id, JobState.PENDING)
        t_start = time.perf_counter()
        self.meta.set(job_config_key(cfg.job_id), cfg.to_json())
        try:
            resume_from = self.job_state(cfg.job_id)

            # -- SPLITTING ----------------------------------------------------
            if resume_from in (JobState.PENDING, JobState.SPLITTING):
                self._set_state(cfg.job_id, JobState.SPLITTING)
                t0 = time.perf_counter()
                assignments = self.pools["splitter"].submit(
                    split_prefix, self.store, cfg.input_prefix, cfg.n_mappers,
                    cfg.binary_input, cfg.record_separator)
                publish_splits(self.meta, cfg.job_id, assignments)
                pt = PhaseTimes(processing=time.perf_counter() - t0)
                report.task_results.append(TaskResult("splitter", 0, 0, pt))

            # -- MAPPING -------------------------------------------------------
            if self.job_state(cfg.job_id) in (JobState.SPLITTING, JobState.MAPPING):
                self._set_state(cfg.job_id, JobState.MAPPING)
                self._run_stage(cfg, "mapper", cfg.n_mappers, report)

            # -- REDUCING ------------------------------------------------------
            if cfg.n_reducers > 0 and self.job_state(cfg.job_id) in (
                    JobState.MAPPING, JobState.REDUCING):
                self._set_state(cfg.job_id, JobState.REDUCING)
                self._run_stage(cfg, "reducer", cfg.n_reducers, report)

            # -- FINALIZING -----------------------------------------------------
            if cfg.run_finalizer and cfg.n_reducers > 0 and self.job_state(
                    cfg.job_id) in (JobState.REDUCING, JobState.FINALIZING):
                self._set_state(cfg.job_id, JobState.FINALIZING)
                self._run_stage(cfg, "finalizer", 1, report)

            self._set_state(cfg.job_id, JobState.DONE)
            report.state = JobState.DONE
        except Exception as exc:
            self._set_state(cfg.job_id, JobState.FAILED)
            report.state = JobState.FAILED
            report.error = str(exc)
        report.wall_time = time.perf_counter() - t_start
        return report

    def resume_job(self, job_id: str) -> JobReport:
        """Coordinator restart: rebuild the config from metadata and continue
        from the recorded stage — possible because workers are stateless and
        all progress lives in the metadata store."""
        raw = self.meta.get(job_config_key(job_id))
        if raw is None:
            raise KeyError(f"unknown job {job_id}")
        cfg = JobConfig.from_json(raw)
        state = self.job_state(job_id)
        if state == JobState.DONE:
            return JobReport(job_id, JobState.DONE)
        if state in (JobState.FAILED, JobState.MAPPING, JobState.SPLITTING,
                     JobState.PENDING):
            # restart the interrupted stage from the top (idempotent tasks)
            self._set_state(job_id, JobState.SPLITTING)
        return self.run_job(cfg)

    def stage_progress(self, job_id: str, role: str) -> int:
        return int(self.meta.get(stage_done_counter(job_id, role), 0))
