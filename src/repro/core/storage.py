"""Object storage layer — the S3 stand-in.

The paper stores input files, Mapper spill files, Reducer outputs and the final
object in an S3 bucket.  This module keeps the S3 *semantics* that shaped the
paper's design so the rest of the framework is written against a realistic API:

  * flat key space with prefix listing (``list_objects(prefix=...)``),
  * whole-object GET plus **ranged GET** (the Splitter hands Mappers byte
    ranges; Mappers fetch ``bytes=lo-hi``),
  * **multipart upload** with a configurable part size (the paper sets 5 MB),
  * **no append / no in-place update** — the Finalizer must stream-concatenate
    reducer outputs into a new object, exactly as §III-A.5 notes.

Two backends: a process-local in-memory store (tests, benchmarks) and a
filesystem-backed store (persistence across coordinator restarts — what S3
gives the paper's stateless workers).
"""

from __future__ import annotations

import io
import os
import threading
import time
from dataclasses import dataclass, field


class StorageError(Exception):
    pass


class NoSuchKey(StorageError):
    pass


class QuotaExceeded(StorageError):
    """A PUT would push a tenant's namespace past its byte quota."""


@dataclass
class ObjectMeta:
    key: str
    size: int
    created: float


class ObjectStore:
    """Abstract S3-like object store."""

    #: default multipart part size — the paper's experiments use 5 MB
    DEFAULT_PART_SIZE = 5 * 1024 * 1024

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str, byte_range: tuple[int, int] | None = None) -> bytes:
        """GET an object; ``byte_range=(lo, hi)`` is inclusive-exclusive."""
        raise NotImplementedError

    def head(self, key: str) -> ObjectMeta:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def list_objects(self, prefix: str = "") -> list[ObjectMeta]:
        raise NotImplementedError

    # -- conveniences shared by both backends ------------------------------
    def put_many(self, items: "list[tuple[str, bytes]]") -> None:
        """PUT several objects in one store round trip.

        The streaming coordinator stages every window emitted during one
        finalization sweep and writes the whole sweep through a single
        ``put_many`` call instead of one PUT per window — against a real
        object store that is one batched request (and one set of
        request-level latencies) per sweep.  The default implementation
        loops ``self.put`` so every backend — and every instrumented
        subclass that hooks ``put`` — observes the same per-object writes.
        """
        for key, data in items:
            self.put(key, data)

    def exists(self, key: str) -> bool:
        try:
            self.head(key)
            return True
        except NoSuchKey:
            return False

    def total_size(self, prefix: str = "") -> int:
        """Total bytes under a prefix — the Splitter's first step (§III-A.2)."""
        return sum(m.size for m in self.list_objects(prefix))

    def multipart_upload(self, key: str, parts: "list[bytes] | MultipartWriter",
                         part_size: int | None = None) -> None:
        """Assemble a multipart upload.  Parts except the last must be
        >= part_size (S3 enforces a 5 MB minimum)."""
        if isinstance(parts, MultipartWriter):
            parts = parts.parts
        part_size = part_size or self.DEFAULT_PART_SIZE
        for p in parts[:-1]:
            if len(p) < min(part_size, 5 * 1024 * 1024):
                raise StorageError(
                    f"multipart part smaller than part size ({len(p)} < {part_size})")
        self.put(key, b"".join(parts))

    def stream_concat(self, out_key: str, in_keys: list[str],
                      chunk_size: int = 8 * 1024 * 1024) -> int:
        """Finalizer primitive: stream several objects into one new object.

        S3 cannot append to an existing object, so the Finalizer reads each
        reducer output in chunks and writes a single combined object (§III-A.5).
        Returns total bytes written.
        """
        buf = io.BytesIO()
        for k in in_keys:
            size = self.head(k).size
            lo = 0
            while lo < size:
                hi = min(lo + chunk_size, size)
                buf.write(self.get(k, (lo, hi)))
                lo = hi
        data = buf.getvalue()
        self.put(out_key, data)
        return len(data)


class MemoryStore(ObjectStore):
    """In-memory object store (thread-safe) — unit tests and benchmarks."""

    def __init__(self) -> None:
        self._objects: dict[str, bytes] = {}
        self._meta: dict[str, ObjectMeta] = {}
        self._lock = threading.Lock()
        # instrumentation for the paper's phase breakdown (Fig. 8)
        self.bytes_uploaded = 0
        self.bytes_downloaded = 0

    def put(self, key: str, data: bytes) -> None:
        if not isinstance(data, (bytes, bytearray)):
            raise TypeError(f"object body must be bytes, got {type(data)}")
        with self._lock:
            self._objects[key] = bytes(data)
            self._meta[key] = ObjectMeta(key, len(data), time.time())
            self.bytes_uploaded += len(data)

    def get(self, key: str, byte_range: tuple[int, int] | None = None) -> bytes:
        with self._lock:
            if key not in self._objects:
                raise NoSuchKey(key)
            data = self._objects[key]
            if byte_range is not None:
                lo, hi = byte_range
                data = data[lo:hi]
            self.bytes_downloaded += len(data)
            return data

    def head(self, key: str) -> ObjectMeta:
        with self._lock:
            if key not in self._meta:
                raise NoSuchKey(key)
            return self._meta[key]

    def delete(self, key: str) -> None:
        with self._lock:
            self._objects.pop(key, None)
            self._meta.pop(key, None)

    def list_objects(self, prefix: str = "") -> list[ObjectMeta]:
        with self._lock:
            return sorted((m for k, m in self._meta.items() if k.startswith(prefix)),
                          key=lambda m: m.key)


class FileStore(ObjectStore):
    """Filesystem-backed object store — survives process restarts, used for
    checkpoints and coordinator-restart tests.  Keys map to files under a root
    directory ('bucket'); '/' in keys becomes directory structure."""

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        self.bytes_uploaded = 0
        self.bytes_downloaded = 0

    def _path(self, key: str) -> str:
        path = os.path.normpath(os.path.join(self.root, key))
        if not path.startswith(os.path.abspath(self.root) + os.sep) and \
           path != os.path.abspath(self.root):
            path = os.path.join(self.root, key.replace("/", "_"))
        return path

    def put(self, key: str, data: bytes) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)  # atomic publish, like S3's all-or-nothing PUT
        with self._lock:
            self.bytes_uploaded += len(data)

    def get(self, key: str, byte_range: tuple[int, int] | None = None) -> bytes:
        path = self._path(key)
        if not os.path.isfile(path):
            raise NoSuchKey(key)
        with open(path, "rb") as f:
            if byte_range is not None:
                lo, hi = byte_range
                f.seek(lo)
                data = f.read(hi - lo)
            else:
                data = f.read()
        with self._lock:
            self.bytes_downloaded += len(data)
        return data

    def head(self, key: str) -> ObjectMeta:
        path = self._path(key)
        if not os.path.isfile(path):
            raise NoSuchKey(key)
        st = os.stat(path)
        return ObjectMeta(key, st.st_size, st.st_mtime)

    def delete(self, key: str) -> None:
        path = self._path(key)
        if os.path.isfile(path):
            os.remove(path)

    def list_objects(self, prefix: str = "") -> list[ObjectMeta]:
        out = []
        for dirpath, _dirs, files in os.walk(self.root):
            for fn in files:
                if fn.endswith(".tmp"):
                    continue
                full = os.path.join(dirpath, fn)
                key = os.path.relpath(full, self.root).replace(os.sep, "/")
                if key.startswith(prefix):
                    st = os.stat(full)
                    out.append(ObjectMeta(key, st.st_size, st.st_mtime))
        return sorted(out, key=lambda m: m.key)


class NamespacedStore(ObjectStore):
    """A tenant's view of a shared bucket: one prefix, one byte quota.

    Every key a job writes or reads through this view is transparently
    prefixed with the tenant namespace, so two tenants running the *same*
    program (same job id, same sink) on one physical store never touch
    each other's objects — the multi-tenant isolation the paper gets from
    per-team S3 prefixes and IAM policy.  ``quota_bytes`` bounds the
    namespace's footprint: a PUT that would push the total past the quota
    raises :class:`QuotaExceeded` *before* writing (replacing an object
    frees its old bytes first, as S3 versioned-overwrite accounting does).

    Listings come back namespace-relative, so callers — the coordinator's
    resume scan, ``collect_outputs`` — see exactly the key space they
    wrote.
    """

    def __init__(self, inner: ObjectStore, namespace: str,
                 quota_bytes: int | None = None) -> None:
        if not namespace.strip("/"):
            raise StorageError("namespace must be non-empty")
        self.inner = inner
        self.namespace = namespace.strip("/") + "/"
        self.quota_bytes = quota_bytes

    def _k(self, key: str) -> str:
        return self.namespace + key.lstrip("/")

    def used_bytes(self) -> int:
        return self.inner.total_size(self.namespace)

    def put(self, key: str, data: bytes) -> None:
        if self.quota_bytes is not None:
            used = self.used_bytes()
            try:
                used -= self.inner.head(self._k(key)).size
            except NoSuchKey:
                pass
            if used + len(data) > self.quota_bytes:
                raise QuotaExceeded(
                    f"namespace {self.namespace!r}: PUT of {len(data)} B "
                    f"over {used} B used exceeds quota {self.quota_bytes} B")
        self.inner.put(self._k(key), data)

    def get(self, key: str, byte_range: tuple[int, int] | None = None) -> bytes:
        return self.inner.get(self._k(key), byte_range)

    def head(self, key: str) -> ObjectMeta:
        m = self.inner.head(self._k(key))
        return ObjectMeta(key, m.size, m.created)

    def delete(self, key: str) -> None:
        self.inner.delete(self._k(key))

    def list_objects(self, prefix: str = "") -> list[ObjectMeta]:
        ns = len(self.namespace)
        return [ObjectMeta(m.key[ns:], m.size, m.created)
                for m in self.inner.list_objects(self._k(prefix))]


@dataclass
class MultipartWriter:
    """Buffers writes and cuts multipart parts at ``part_size`` boundaries —
    how the Mapper streams spill files out without holding them whole."""

    part_size: int = ObjectStore.DEFAULT_PART_SIZE
    parts: list[bytes] = field(default_factory=list)
    _buf: bytearray = field(default_factory=bytearray)

    def write(self, data: bytes) -> None:
        self._buf.extend(data)
        while len(self._buf) >= self.part_size:
            self.parts.append(bytes(self._buf[: self.part_size]))
            del self._buf[: self.part_size]

    def finish(self) -> list[bytes]:
        if self._buf:
            self.parts.append(bytes(self._buf))
            self._buf = bytearray()
        return self.parts


def spill_key(job_id: str, reducer_id: int, file_index: int, mapper_id: int) -> str:
    """Spill-file naming from §III-A.4: ``spill-reducer_id-file_index-mapper_id``.
    Reducers list by prefix ``spill-{their id}-`` to find their inputs."""
    return f"jobs/{job_id}/intermediate/spill-{reducer_id}-{file_index}-{mapper_id}"


def parse_spill_key(key: str) -> tuple[int, int, int]:
    """Inverse of :func:`spill_key` → (reducer_id, file_index, mapper_id)."""
    name = key.rsplit("/", 1)[-1]
    if not name.startswith("spill-"):
        raise ValueError(f"not a spill key: {key}")
    r, f, m = name[len("spill-"):].split("-")
    return int(r), int(f), int(m)
