"""Event transport — the Kafka / CloudEvents stand-in.

In the paper the Coordinator spawns workers by *producing CloudEvents to Kafka
topics*; Knative JobSinks consume them and materialize containers.  This module
keeps the same shape in-process:

  * topics with a fixed partition count; events carry key/value/timestamp/headers,
  * producers append; partition chosen by ``hash(key) % n_partitions``
    (exactly the record→partition rule Kafka uses and the paper relies on),
  * consumer groups with offset tracking — each partition is owned by at most
    one consumer of a group, replays are possible from a saved offset (this is
    what makes worker restarts exactly-once-ish in the paper's design),
  * a blocking ``poll`` so worker loops look like real consumers.

CloudEvent envelope fields follow the CloudEvents 1.0 spec attributes the
paper's Knative JobSinks consume (id, source, type, subject, data).
"""

from __future__ import annotations

import itertools
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any


@dataclass
class CloudEvent:
    """CloudEvents-1.0-shaped envelope."""

    type: str                      # e.g. "repro.mapper.trigger"
    source: str                    # e.g. "coordinator"
    data: dict[str, Any]
    subject: str | None = None     # e.g. "job-42/mapper-3"
    id: str = field(default_factory=lambda: uuid.uuid4().hex)
    time: float = field(default_factory=time.time)


@dataclass
class Record:
    key: str | None
    value: CloudEvent
    timestamp: float
    offset: int
    partition: int
    headers: dict[str, str] = field(default_factory=dict)


class _Partition:
    def __init__(self) -> None:
        self.log: list[Record] = []
        self.cond = threading.Condition()

    def append(self, rec: Record) -> None:
        with self.cond:
            self.log.append(rec)
            self.cond.notify_all()


class Topic:
    def __init__(self, name: str, n_partitions: int = 4) -> None:
        self.name = name
        self.partitions = [_Partition() for _ in range(n_partitions)]

    def partition_for(self, key: str | None) -> int:
        if key is None:
            return 0
        # FNV-1a over the key bytes — stable across processes (unlike hash())
        h = 0xCBF29CE484222325
        for b in key.encode():
            h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
        return h % len(self.partitions)


class EventBus:
    """Broker: topics + consumer groups with offsets."""

    def __init__(self) -> None:
        self._topics: dict[str, Topic] = {}
        self._offsets: dict[tuple[str, str, int], int] = {}  # (group, topic, part)
        self._lock = threading.Lock()
        self.produced = 0  # instrumentation

    def create_topic(self, name: str, n_partitions: int = 4) -> Topic:
        with self._lock:
            if name not in self._topics:
                self._topics[name] = Topic(name, n_partitions)
            return self._topics[name]

    def topic(self, name: str) -> Topic:
        with self._lock:
            if name not in self._topics:
                self._topics[name] = Topic(name)
            return self._topics[name]

    # -- producer ------------------------------------------------------------
    def produce(self, topic: str, event: CloudEvent, key: str | None = None,
                headers: dict[str, str] | None = None) -> Record:
        t = self.topic(topic)
        p = t.partition_for(key)
        part = t.partitions[p]
        with part.cond:
            rec = Record(key=key, value=event, timestamp=time.time(),
                         offset=len(part.log), partition=p,
                         headers=headers or {})
            part.log.append(rec)
            part.cond.notify_all()
        self.produced += 1
        return rec

    # -- consumer ------------------------------------------------------------
    def poll(self, group: str, topic: str, timeout: float = 1.0,
             max_records: int = 64) -> list[Record]:
        """Fetch new records for a consumer group across all partitions."""
        t = self.topic(topic)
        deadline = time.time() + timeout
        out: list[Record] = []
        while not out and time.time() < deadline:
            for p_idx, part in enumerate(t.partitions):
                okey = (group, topic, p_idx)
                with self._lock:
                    off = self._offsets.get(okey, 0)
                with part.cond:
                    new = part.log[off: off + max_records]
                if new:
                    out.extend(new)
                    with self._lock:
                        self._offsets[okey] = off + len(new)
                if len(out) >= max_records:
                    break
            if not out:
                time.sleep(0.001)
        return out

    def seek(self, group: str, topic: str, partition: int, offset: int) -> None:
        """Rewind a consumer group — replay after a worker failure."""
        with self._lock:
            self._offsets[(group, topic, partition)] = offset

    def fetch(self, topic: str, partition: int = 0, offset: int = 0,
              max_records: int | None = None) -> list[Record]:
        """Group-less record-addressed read: the log from ``offset`` on.

        Consumer groups share one cursor per partition; a *subscriber*
        keeps its own.  The job server's shared-ingest fan-out reads the
        materialized stream this way — every subscriber replays from its
        private record cursor (a late registrant starts at 0 and catches
        up) without advancing anyone else's position.
        """
        t = self.topic(topic)
        part = t.partitions[partition]
        with part.cond:
            if max_records is None:
                return part.log[offset:]
            return part.log[offset: offset + max_records]

    def end_offset(self, topic: str, partition: int = 0) -> int:
        """Next offset to be written — a subscriber's lag is
        ``end_offset - cursor``."""
        t = self.topic(topic)
        part = t.partitions[partition]
        with part.cond:
            return len(part.log)

    def lag(self, group: str, topic: str) -> int:
        """Unconsumed records — the autoscaler's scaling signal (KPA uses
        concurrency; Kafka-based KEDA-style scaling uses consumer lag)."""
        t = self.topic(topic)
        total = 0
        for p_idx, part in enumerate(t.partitions):
            with self._lock:
                off = self._offsets.get((group, topic, p_idx), 0)
            total += max(0, len(part.log) - off)
        return total


# Topic names used by the framework — one per worker role, as the paper's
# Coordinator produces distinct CloudEvent types per component.
TOPIC_SPLITTER = "repro.splitter"
TOPIC_MAPPER = "repro.mapper"
TOPIC_REDUCER = "repro.reducer"
TOPIC_FINALIZER = "repro.finalizer"
TOPIC_STATUS = "repro.status"      # worker → coordinator completion callbacks

# Streaming topics: the source announces each micro-batch on STREAM_BATCH
# (the trigger the streaming loop consumes; its consumer lag is the
# backpressure/scaling signal), and the coordinator publishes every finalized
# window on STREAM_WINDOW for downstream consumers.
TOPIC_STREAM_BATCH = "repro.stream.batch"
TOPIC_STREAM_WINDOW = "repro.stream.window"

# Job-service topics: the control plane announces every lifecycle
# transition (submitted/running/parked/…) on JOB_LIFECYCLE, and each
# shared source materializes its one physical log read onto a private
# ``repro.ingest.<source>`` topic that all subscribing jobs replay from
# their own record cursors.
TOPIC_JOB_LIFECYCLE = "repro.job.lifecycle"
TOPIC_INGEST_PREFIX = "repro.ingest."


def ingest_topic(source_id: str) -> str:
    """Topic name for one shared source's materialized record stream.

    The topic may carry one partition (the default — offset equals
    record index) or N partitions keyed by record key.  Either way every
    record carries its global materialization sequence number (``seq``),
    so any subset of partitions merges back into one deterministic total
    order and replay stays exactly-once per partition — see
    ``repro.service.ingest_share``."""
    return TOPIC_INGEST_PREFIX + source_id.strip("/").replace("/", ".")

_event_counter = itertools.count()


def trigger_event(role: str, job_id: str, worker_id: int,
                  payload: dict[str, Any]) -> CloudEvent:
    return CloudEvent(
        type=f"repro.{role}.trigger",
        source="coordinator",
        subject=f"{job_id}/{role}-{worker_id}",
        data={"job_id": job_id, "worker_id": worker_id, **payload},
    )


def batch_event(job_id: str, batch_index: int, n_records: int,
                max_event_time: float | None = None) -> CloudEvent:
    """Micro-batch announcement — one per batch on TOPIC_STREAM_BATCH.
    ``max_event_time`` is None when the producer announced from record
    counts without parsing payloads."""
    return CloudEvent(
        type="repro.stream.batch.available",
        source="stream-source",
        subject=f"{job_id}/batch-{batch_index}",
        data={"job_id": job_id, "batch_index": batch_index,
              "n_records": n_records, "max_event_time": max_event_time},
    )


def window_event(job_id: str, window_start: float, window_end: float,
                 n_keys: int, output_key: str) -> CloudEvent:
    """Finalized-window emission notice on TOPIC_STREAM_WINDOW."""
    return CloudEvent(
        type="repro.stream.window.finalized",
        source="streaming-coordinator",
        subject=f"{job_id}/window-{window_start}",
        data={"job_id": job_id, "window_start": window_start,
              "window_end": window_end, "n_keys": n_keys,
              "output_key": output_key},
    )


def job_lifecycle_event(job_id: str, tenant: str, state: str,
                        info: dict[str, Any] | None = None) -> CloudEvent:
    """Control-plane transition notice on TOPIC_JOB_LIFECYCLE — the job
    server's submit/pause/park/restore audit stream."""
    return CloudEvent(
        type=f"repro.job.{state}",
        source="job-server",
        subject=f"{tenant}/{job_id}",
        data={"job_id": job_id, "tenant": tenant, "state": state,
              **(info or {})},
    )


def status_event(role: str, job_id: str, worker_id: int, status: str,
                 info: dict[str, Any] | None = None) -> CloudEvent:
    return CloudEvent(
        type=f"repro.{role}.{status}",
        source=f"{role}-{worker_id}",
        subject=f"{job_id}/{role}-{worker_id}",
        data={"job_id": job_id, "worker_id": worker_id, "status": status,
              **(info or {})},
    )
