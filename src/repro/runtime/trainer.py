"""Trainer — the fault-tolerant training driver.

The Coordinator pattern (paper §III-A.1) applied to training: all durable
state (model/optimizer checkpoint, step counter, data cursor) lives in the
storage + metadata layers; the Trainer process itself is stateless and
restartable.  Mechanisms:

  * **checkpoint/restart** — async sharded checkpoints every
    ``checkpoint_every``; on construction the Trainer resumes from the
    newest manifest (commit-point semantics, see checkpoint.py);
  * **preemption simulation** — ``run(..., preempt_at=k)`` raises after k
    steps; tests restart a fresh Trainer and verify bit-identical
    continuation;
  * **fault injection** — a hook called every step can raise transient
    worker errors; the step is retried (idempotent: the step function is
    pure and the batch is re-used), mirroring the Coordinator's task retry;
  * **straggler mitigation** — at the MapReduce layer (speculative twins,
    coordinator.py); within a jit step XLA is bulk-synchronous, so the
    trainer-level lever is the *elastic re-mesh*: restore onto fewer/more
    hosts (tests/test_fault_tolerance.py::test_elastic_remesh).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from ..core.metadata import MetadataStore
from ..core.storage import ObjectStore
from ..models import ModelConfig
from ..optim import AdamW, TrainState
from .train_step import init_train_state, make_train_step


@dataclass
class TrainerConfig:
    checkpoint_every: int = 50
    checkpoint_prefix: str = "ckpt"
    n_ckpt_shards: int = 4
    max_step_retries: int = 2
    microbatches: int = 1
    log_every: int = 10


class PreemptionError(RuntimeError):
    pass


class Trainer:
    def __init__(self, cfg: ModelConfig, opt: AdamW, store: ObjectStore,
                 meta: MetadataStore | None = None,
                 tcfg: TrainerConfig | None = None, seed: int = 0,
                 fault_hook: Callable[[int], None] | None = None) -> None:
        self.cfg = cfg
        self.opt = opt
        self.store = store
        self.meta = meta or MetadataStore()
        self.tcfg = tcfg or TrainerConfig()
        self.fault_hook = fault_hook
        self._step_fn = jax.jit(
            make_train_step(cfg, opt, self.tcfg.microbatches))
        self.ckpt = AsyncCheckpointer(store, self.tcfg.checkpoint_prefix,
                                      self.tcfg.n_ckpt_shards)
        # restore-or-init (the restart path)
        key = jax.random.PRNGKey(seed)
        self.state = init_train_state(key, cfg, opt)
        self.start_step = 0
        last = latest_step(store, self.tcfg.checkpoint_prefix)
        if last is not None:
            self.state, _ = restore_checkpoint(
                store, self.tcfg.checkpoint_prefix, self.state, last)
            self.state = jax.tree.map(jnp.asarray, self.state)
            self.start_step = int(self.state.step)
        self.metrics_log: list[dict[str, float]] = []

    # -- the loop -------------------------------------------------------------
    def run(self, batches: Iterator[dict[str, np.ndarray]], num_steps: int,
            preempt_at: int | None = None) -> TrainState:
        it = iter(batches)
        step = self.start_step
        t0 = time.perf_counter()
        while step < num_steps:
            batch = next(it)
            if preempt_at is not None and step >= preempt_at:
                self.ckpt.save(step, self.state)
                self.ckpt.wait()
                raise PreemptionError(f"preempted at step {step}")
            # task retry loop (transient worker failure → re-run, idempotent)
            attempt = 0
            while True:
                try:
                    if self.fault_hook is not None:
                        self.fault_hook(step)
                    new_state, metrics = self._step_fn(self.state, batch)
                    break
                except PreemptionError:
                    raise
                except Exception:
                    attempt += 1
                    if attempt > self.tcfg.max_step_retries:
                        raise
            self.state = new_state
            step += 1
            if step % self.tcfg.log_every == 0 or step == num_steps:
                m = {k: float(v) for k, v in jax.device_get(metrics).items()}
                m["step"] = step
                m["steps_per_s"] = (step - self.start_step) / max(
                    1e-9, time.perf_counter() - t0)
                self.metrics_log.append(m)
                self.meta.set(f"train:step", step)
                self.meta.set(f"train:loss", m.get("loss"))
            if step % self.tcfg.checkpoint_every == 0:
                self.ckpt.save(step, self.state)
        self.ckpt.save(step, self.state)
        self.ckpt.wait()
        return self.state
