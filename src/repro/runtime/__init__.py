from .train_step import init_train_state, make_eval_step, make_train_step
from .trainer import Trainer, TrainerConfig

__all__ = ["init_train_state", "make_eval_step", "make_train_step", "Trainer",
           "TrainerConfig"]
