"""The training step as a MapReduce round (DESIGN.md §2).

  map      — per-device forward/backward on its batch shard
  combine  — local microbatch gradient accumulation (``lax.scan``), the
             paper's combiner: pre-reduce before any communication
  shuffle+reduce — the gradient all-reduce.  Under ``jax.jit`` + sharded
             batch this is implicit (XLA inserts reduce-scatter/all-reduce);
             under ``shard_map`` it is explicit ``psum`` — optionally the
             int8 ``compressed_psum`` (smaller spill files)
  finalize — optimizer update (+ async checkpoint, in the Trainer)

Both distribution styles are provided:
  * ``make_train_step`` — jit/GSPMD path (what the multi-pod dry-run lowers);
  * ``make_shardmap_train_step`` — explicit-collective path used to
    demonstrate gradient compression on the wire.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..engine.compile import make_shard_map
from ..models import ModelConfig, init_params, loss_fn
from ..optim import AdamW, TrainState, apply_updates
from ..optim.compression import compressed_psum


def init_train_state(key: jax.Array, cfg: ModelConfig,
                     opt: AdamW) -> TrainState:
    params = init_params(key, cfg)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, opt: AdamW, microbatches: int = 1,
                    loss: Callable | None = None, donate: bool = True,
                    grad_specs=None):
    """jit-able ``train_step(state, batch) -> (state, metrics)``.

    ``microbatches > 1``: batch leading axis is (microbatches, B/mb, S) and
    gradients accumulate locally before the (implicit) reduction — the
    combiner.  Shardings are attached by the caller (launch/dryrun or
    launch/train) via in_shardings/out_shardings at jit time.

    ``grad_specs`` (optional PartitionSpec tree): sharding constraint for the
    fp32 gradient accumulator — under the ZeRO-2 layout the accumulator is
    FSDP-sharded even though parameters are replicated over data, so each
    microbatch's gradients arrive as a reduce-scatter instead of an
    all-reduce (EXPERIMENTS.md §Perf).
    """
    loss = loss or loss_fn
    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def _constrain(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_specs)

    def train_step(state: TrainState, batch: dict[str, jax.Array]):
        if microbatches == 1:
            (_loss, metrics), grads = grad_fn(state.params, batch, cfg)
            grads = _constrain(grads)
        else:
            def body(carry, mb):
                acc = carry
                (_loss, metrics), g = grad_fn(state.params, mb, cfg)
                acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                   acc, g)
                return _constrain(acc), metrics

            zeros = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            grads, ms = jax.lax.scan(body, zeros, batch)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], ms)
        updates, opt_state, stats = opt.update(grads, state.opt_state,
                                               state.params)
        params = apply_updates(state.params, updates)
        new_state = TrainState(params, opt_state, state.step + 1)
        return new_state, {**metrics, **stats}

    return train_step


def make_shardmap_train_step(cfg: ModelConfig, opt: AdamW,
                             mesh: jax.sharding.Mesh, axis_name: str = "data",
                             compress_grads: bool = False,
                             loss: Callable | None = None):
    """Explicit-collective train step: per-device grads + psum (optionally
    int8-compressed) over ``axis_name``.  Params/opt-state replicated over
    the axis; batch sharded on it."""
    loss = loss or loss_fn
    grad_fn = jax.value_and_grad(loss, has_aux=True)
    P = jax.sharding.PartitionSpec

    def worker(state: TrainState, batch):
        (_loss, metrics), grads = grad_fn(state.params, batch, cfg)
        if compress_grads:
            grads = compressed_psum(grads, axis_name)   # int8 on the wire
        else:
            grads = jax.lax.pmean(grads, axis_name)
        metrics = jax.lax.pmean(metrics, axis_name)
        updates, opt_state, stats = opt.update(grads, state.opt_state,
                                               state.params)
        params = apply_updates(state.params, updates)
        return TrainState(params, opt_state, state.step + 1), \
            {**metrics, **stats}

    def train_step(state, batch):
        # routed through the engine's version shim: jax 0.4.x has no
        # top-level jax.shard_map (see engine/compile.py)
        fn = make_shard_map(
            worker, mesh,
            (jax.tree.map(lambda _: P(), state),
             jax.tree.map(lambda _: P(axis_name), batch)),
            (jax.tree.map(lambda _: P(), state), P()))
        return fn(state, batch)

    return train_step


def make_eval_step(cfg: ModelConfig, loss: Callable | None = None):
    loss = loss or loss_fn

    def eval_step(params, batch):
        _, metrics = loss(params, batch, cfg)
        return metrics

    return eval_step
