"""Sharded, async checkpoints through the object-storage layer.

Checkpoints reuse the paper's spill-file discipline: each saver shard writes
one immutable object named ``ckpt/step-S/shard-i-of-N`` (plus a JSON manifest
with the tree structure, shapes, dtypes and per-shard CRCs), so

  * any worker can be re-run idempotently (same bytes, same key),
  * restore is *elastic*: the manifest, not the shard count, defines the
    logical arrays — a checkpoint written by N workers restores onto any
    M-device mesh (leaves are reassembled, then resharded by the caller's
    shardings), which is the re-mesh path the runtime uses after losing nodes,
  * the final manifest PUT is the commit point (S3-style atomic publish);
    a crash mid-save leaves no visible checkpoint.

``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
writes through a background thread — training never blocks on storage, the
paper's upload-phase overlap applied to the training loop.
"""

from __future__ import annotations

import io
import json
import queue
import threading
import zlib
from typing import Any

import jax
import numpy as np

from ..core.storage import NoSuchKey, ObjectStore


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _manifest_key(prefix: str, step: int) -> str:
    return f"{prefix.rstrip('/')}/step-{step:08d}/MANIFEST.json"


def _shard_key(prefix: str, step: int, i: int, n: int) -> str:
    return f"{prefix.rstrip('/')}/step-{step:08d}/shard-{i}-of-{n}"


def save_checkpoint(store: ObjectStore, prefix: str, step: int, tree: Any,
                    n_shards: int = 4) -> dict:
    """Write ``tree`` as ``n_shards`` objects + manifest.  Leaves are split on
    their first axis (padded shards at the tail); scalars go to shard 0."""
    leaves, treedef = _flatten(tree)
    arrs = [np.asarray(jax.device_get(x)) for x in leaves]
    meta = []
    shard_bufs: list[dict[str, np.ndarray]] = [dict() for _ in range(n_shards)]
    for li, a in enumerate(arrs):
        if a.ndim == 0 or a.shape[0] < n_shards:
            shard_bufs[0][f"leaf{li}"] = a
            meta.append({"shape": list(a.shape), "dtype": str(a.dtype),
                         "split": False})
        else:
            bounds = np.linspace(0, a.shape[0], n_shards + 1).astype(int)
            for si in range(n_shards):
                shard_bufs[si][f"leaf{li}"] = a[bounds[si]:bounds[si + 1]]
            meta.append({"shape": list(a.shape), "dtype": str(a.dtype),
                         "split": True,
                         "bounds": [int(b) for b in bounds]})
    crcs = []
    for si, buf in enumerate(shard_bufs):
        bio = io.BytesIO()
        np.savez(bio, **buf)
        blob = bio.getvalue()
        crcs.append(zlib.crc32(blob))
        store.put(_shard_key(prefix, step, si, n_shards), blob)
    manifest = {
        "step": step,
        "n_shards": n_shards,
        "leaves": meta,
        "crc32": crcs,
        "treedef_repr": str(treedef),   # structure check is by repr + leaf count
    }
    # the manifest PUT commits the checkpoint
    store.put(_manifest_key(prefix, step),
              json.dumps(manifest).encode())
    return manifest


def latest_step(store: ObjectStore, prefix: str) -> int | None:
    steps = []
    for m in store.list_objects(prefix.rstrip("/") + "/"):
        if m.key.endswith("MANIFEST.json"):
            part = m.key.rsplit("/", 2)[-2]          # step-XXXXXXXX
            steps.append(int(part.split("-")[1]))
    return max(steps) if steps else None


def restore_checkpoint(store: ObjectStore, prefix: str, target: Any,
                       step: int | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``target`` (its treedef defines the
    layout; shapes/dtypes validated against the manifest).  Returns
    (tree, step).  Elastic: works regardless of current worker count."""
    if step is None:
        step = latest_step(store, prefix)
        if step is None:
            raise NoSuchKey(f"no checkpoint under {prefix}")
    manifest = json.loads(store.get(_manifest_key(prefix, step)))
    n = manifest["n_shards"]
    bufs = []
    for si in range(n):
        blob = store.get(_shard_key(prefix, step, si, n))
        if zlib.crc32(blob) != manifest["crc32"][si]:
            raise IOError(f"checkpoint shard {si} failed CRC validation")
        bufs.append(np.load(io.BytesIO(blob)))
    leaves_meta = manifest["leaves"]
    flat_target, treedef = jax.tree.flatten(target)
    if len(flat_target) != len(leaves_meta):
        raise ValueError(
            f"checkpoint has {len(leaves_meta)} leaves, target expects "
            f"{len(flat_target)}")
    out = []
    for li, meta in enumerate(leaves_meta):
        key = f"leaf{li}"
        if meta["split"]:
            a = np.concatenate([bufs[si][key] for si in range(n)], axis=0)
        else:
            a = bufs[0][key]
        want = flat_target[li]
        if hasattr(want, "shape") and tuple(want.shape) != tuple(a.shape):
            raise ValueError(
                f"leaf {li}: checkpoint shape {a.shape} != target "
                f"{tuple(want.shape)}")
        out.append(a)
    return jax.tree.unflatten(treedef, out), step


class AsyncCheckpointer:
    """Background writer: ``save()`` snapshots to host and returns; a worker
    thread performs the object-store writes.  ``wait()`` drains the queue."""

    def __init__(self, store: ObjectStore, prefix: str, n_shards: int = 4,
                 keep: int = 3) -> None:
        self.store = store
        self.prefix = prefix
        self.n_shards = n_shards
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._errors: list[Exception] = []
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, tree = item
            try:
                save_checkpoint(self.store, self.prefix, step, tree,
                                self.n_shards)
                self._gc()
            except Exception as exc:  # surfaced on wait()
                self._errors.append(exc)
            finally:
                self._q.task_done()

    def _gc(self) -> None:
        steps = sorted({int(m.key.rsplit("/", 2)[-2].split("-")[1])
                        for m in self.store.list_objects(
                            self.prefix.rstrip("/") + "/")
                        if "step-" in m.key})
        for s in steps[:-self.keep] if len(steps) > self.keep else []:
            for m in self.store.list_objects(
                    f"{self.prefix.rstrip('/')}/step-{s:08d}/"):
                self.store.delete(m.key)

    def save(self, step: int, tree: Any) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host_tree))

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        self._q.put(None)
        self._q.join()
