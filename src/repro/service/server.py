"""The JobServer — many tenants, one engine pool, a parked-job lifecycle.

One server instance holds the four shared substrates — ObjectStore,
MetadataStore, EventBus, ServerlessPool — and multiplexes any number of
``BuiltPipeline`` programs over them:

* **submit/pause/resume/cancel/status** are the control-plane verbs the
  paper's client exercises over HTTP against the Coordinator; here they
  drive metadata-backed :class:`~repro.service.registry.JobRegistry`
  records, so any process holding the MetadataStore observes the same
  lifecycle.
* **Ingest is physical-once**: every source prefix gets one
  :class:`~repro.service.ingest_share.SharedIngest`; jobs subscribe with
  private cursors and ``step()`` pumps each ingest exactly once per
  round regardless of subscriber count.
* **Scale-to-zero lifecycle**: a job with no new records for
  ``park_after_idle`` rounds is *parked* — its lanes drain at the
  micro-batch barrier (they always do), its one-pytree carry state is
  checkpointed, its coordinator is dropped, and when no job remains
  running the pool retires every instance.  The next matching event
  *unparks* it: a fresh coordinator cold-restores the checkpoint
  (measured — this is the cold start the paper's Fig. 6 charges) and
  resumes from the checkpointed record offset.  Emission idempotence
  makes the round trip exactly-once: re-finalized windows re-write the
  same bytes, already-persisted ones are skipped.

The drive loop is cooperative and synchronous (``step()`` /
``run_until_complete()``): determinism is what lets the tests assert
byte-identical sinks against standalone runs.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

from ..analysis.diagnostics import PlanRejected, errors
from ..core.autoscaler import AutoscalerConfig, ServerlessPool
from ..core.events import (TOPIC_JOB_LIFECYCLE, EventBus,
                           job_lifecycle_event)
from ..core.metadata import MetadataStore
from ..core.storage import ObjectStore, StorageError
from ..streaming.coordinator import (RunOptions, StreamingCoordinator,
                                     StreamReport)
from .ingest_share import SharedIngest, SubscriberSource
from .registry import JobRegistry
from .tenancy import Tenant

__all__ = ["JobServer", "JobStatus"]


class JobStatus:
    """Lifecycle states — string constants, mirrored into the metadata
    records so clients need no enum import to poll them."""

    PENDING = "PENDING"      # submitted, coordinator not yet built
    RUNNING = "RUNNING"      # live coordinator, folding batches
    PAUSED = "PAUSED"        # parked by explicit request; only resume() wakes
    PARKED = "PARKED"        # scaled to zero; next matching event wakes
    DONE = "DONE"
    CANCELLED = "CANCELLED"
    FAILED = "FAILED"

    TERMINAL = (DONE, CANCELLED, FAILED)


@dataclass
class _Job:
    """Server-side live state for one submitted job.  Everything durable
    lives in the registry records; this holds only what a crash may lose
    (and restore rebuilds): the coordinator and its drive bookkeeping."""

    job_id: str
    tenant: Tenant
    program: Any
    options: RunOptions
    store: ObjectStore                  # the tenant's namespaced view
    ingest: SharedIngest
    sub: SubscriberSource
    state: str = JobStatus.PENDING
    coord: StreamingCoordinator | None = None
    report: StreamReport = None
    cursor: int = 0                     # records consumed (live offset)
    idle_rounds: int = 0
    error: str | None = None
    cold_start_latencies: list = field(default_factory=list)

    def __post_init__(self) -> None:
        if self.report is None:
            self.report = StreamReport(self.job_id)


class JobServer:
    """Control plane + drive loop over the shared substrates."""

    def __init__(self, store: ObjectStore, meta: MetadataStore | None = None,
                 bus: EventBus | None = None, *,
                 autoscaler: AutoscalerConfig | None = None,
                 park_after_idle: int = 2) -> None:
        self.store = store
        self.meta = meta if meta is not None else MetadataStore()
        self.bus = bus if bus is not None else EventBus()
        self.pool = ServerlessPool("job-server",
                                   autoscaler or AutoscalerConfig())
        self.registry = JobRegistry(self.meta)
        self.park_after_idle = park_after_idle
        self.tenants: dict[str, Tenant] = {}
        self.ingests: dict[str, SharedIngest] = {}
        self.jobs: dict[str, _Job] = {}

    # -- tenancy / ingest setup ---------------------------------------------
    def add_tenant(self, name: str,
                   quota_bytes: int | None = None) -> Tenant:
        if name in self.tenants:
            return self.tenants[name]
        t = Tenant(name, quota_bytes)
        self.tenants[name] = t
        return t

    def shared_ingest(self, prefix: str,
                      batch_records: int = 1024) -> SharedIngest:
        """The one physical reader for ``prefix`` — created on first use,
        shared by every later subscriber."""
        key = prefix.rstrip("/")
        if key not in self.ingests:
            self.ingests[key] = SharedIngest(self.bus, self.store, prefix,
                                             batch_records=batch_records)
        return self.ingests[key]

    # -- control-plane verbs -------------------------------------------------
    def submit(self, tenant: str, program, *, source_prefix: str,
               options: RunOptions | None = None,
               resume: bool = False) -> str:
        """Register a program for a tenant against a shared source.

        The registry enforces global job-id uniqueness and rejects
        cross-job sink-prefix collisions on the shared store before the
        job can write anything; ``resume=True`` re-attaches a job that a
        crashed server had already registered — its checkpoint (if any)
        is honored on first drive, so recovery is exactly-once.

        Admission runs planlint first: a program with error-level
        findings (a ring that must overflow, colliding sinks, an unfed
        join side) raises :class:`~repro.analysis.diagnostics.PlanRejected`
        *before* the job registers — the plan-level twin of the
        ``QuotaExceeded`` pattern, failing only this tenant's submit.
        """
        if tenant not in self.tenants:
            raise KeyError(f"unknown tenant {tenant!r}; add_tenant first")
        bad = errors(program.check(options))
        if bad:
            raise PlanRejected(bad)
        t = self.tenants[tenant]
        fresh = self.registry.register(
            program.job_id, tenant,
            [t.qualify(p) for p in program.output_prefixes()],
            resume=resume)
        ingest = self.shared_ingest(source_prefix,
                                    batch_records=program.batch_records)
        sub = ingest.subscribe(program.job_id,
                               batch_records=program.batch_records)
        job = _Job(job_id=program.job_id, tenant=t, program=program,
                   options=options or RunOptions(),
                   store=t.store_view(self.store), ingest=ingest, sub=sub)
        self.jobs[job.job_id] = job
        if fresh:
            self._transition(job, JobStatus.PENDING, verb="submitted")
        else:
            self._transition(job, JobStatus.PENDING, verb="reattached")
        return job.job_id

    def pause(self, job_id: str) -> None:
        """Park immediately on explicit request; only resume() wakes it
        (arriving events do not)."""
        job = self._job(job_id)
        self._check_live(job, "pause")
        if job.coord is not None:
            self._checkpoint(job)
            job.coord = None
        self._transition(job, JobStatus.PAUSED, verb="paused")
        self._maybe_scale_to_zero()

    def resume(self, job_id: str) -> None:
        """Wake a paused job — a cold restore if it had checkpointed."""
        job = self._job(job_id)
        if job.state != JobStatus.PAUSED:
            raise ValueError(f"job {job_id!r} is {job.state}, not PAUSED")
        self._restore(job, verb="resumed")

    def cancel(self, job_id: str) -> None:
        """Stop a job for good.  No flush — half-open windows are
        abandoned; already-persisted windows (and the prefix claim) stay,
        as S3 objects would."""
        job = self._job(job_id)
        self._check_live(job, "cancel")
        job.coord = None
        self._transition(job, JobStatus.CANCELLED, verb="cancelled")
        self._maybe_scale_to_zero()

    def status(self, job_id: str) -> dict[str, Any]:
        """The registry record plus live drive state — what the paper's
        client renders while polling."""
        job = self._job(job_id)
        rec = self.registry.record(job_id)
        rec.update({
            "job_id": job_id,
            "cursor": job.cursor,
            "lag": job.ingest.lag(job.cursor),
            "batches": job.report.batches,
            "records_in": job.report.records_in,
            "windows_emitted": job.report.windows_emitted,
            "error": job.error,
        })
        return rec

    # -- the drive loop ------------------------------------------------------
    def step(self) -> int:
        """One cooperative scheduling round: pump every shared ingest
        once (the only physical log reads), wake parked jobs with lag,
        drive every runnable job over its available tail, park the idle.
        Returns records moved (pumped + folded) — 0 means quiescent."""
        moved = 0
        for ingest in self.ingests.values():
            moved += ingest.pump()
        for job in list(self.jobs.values()):
            if job.state == JobStatus.PARKED and job.ingest.lag(job.cursor):
                self._restore(job, verb="restored")
            if job.state in (JobStatus.PENDING, JobStatus.RUNNING):
                moved += self._drive(job)
        return moved

    def run_until_complete(self, flush: bool = True) -> dict[str, str]:
        """Drive until no ingest yields new records and every job is
        drained, then finish each live job (end-of-stream flush).  Paused
        jobs stay paused — completing them would override an explicit
        operator verb.  Returns {job_id: final state}."""
        while self.step():
            pass
        for job in list(self.jobs.values()):
            if job.state not in JobStatus.TERMINAL + (JobStatus.PAUSED,):
                self.finish(job.job_id, flush=flush)
        return {jid: j.state for jid, j in self.jobs.items()}

    def finish(self, job_id: str, flush: bool = True) -> StreamReport:
        """Drain a job's remaining tail and finalize it: end-of-stream
        watermark through every stage, sinks flushed, final checkpoint —
        the sink bytes now match a standalone flushed run's exactly."""
        job = self._job(job_id)
        self._check_live(job, "finish")
        job.ingest.pump()
        if job.coord is None:
            self._restore(job, verb="restored")
        self._drive(job, park_when_idle=False)
        if job.state == JobStatus.FAILED:
            return job.report
        try:
            if flush:
                job.coord.flush_end_of_stream(job.report)
        except StorageError as exc:
            self._fail(job, exc)
            return job.report
        job.coord = None
        self._transition(job, JobStatus.DONE, verb="done")
        self.registry.update(job_id, cursor=job.cursor)
        self._maybe_scale_to_zero()
        return job.report

    # -- lifecycle internals -------------------------------------------------
    def _job(self, job_id: str) -> _Job:
        if job_id not in self.jobs:
            raise KeyError(f"unknown job: {job_id}")
        return self.jobs[job_id]

    def _check_live(self, job: _Job, verb: str) -> None:
        if job.state in JobStatus.TERMINAL:
            raise ValueError(f"cannot {verb} job {job.job_id!r}: "
                             f"already {job.state}")

    def _transition(self, job: _Job, state: str, *, verb: str) -> None:
        job.state = state
        self.registry.update(job.job_id, state=state, cursor=job.cursor)
        self.bus.produce(TOPIC_JOB_LIFECYCLE,
                         job_lifecycle_event(job.job_id, job.tenant.name,
                                             verb, {"cursor": job.cursor}))

    def _checkpoint(self, job: _Job) -> None:
        """Barrier checkpoint: the drive loop only rests at micro-batch
        barriers (lanes drained), so the one-pytree carry snapshot is
        always consistent here."""
        if job.report.batches:
            job.coord.save_state()
        self.registry.update(job.job_id, cursor=job.cursor)

    def _restore(self, job: _Job, *, verb: str) -> None:
        """Build (or cold-rebuild) the job's coordinator and restore its
        checkpoint.  Timed end to end — pool activation, carry download,
        tracker/dictionary rebuild — because this *is* the serverless
        cold start the lifecycle trades against idle cost."""
        cold = job.state in (JobStatus.PARKED, JobStatus.PAUSED)
        t0 = time.perf_counter()
        self.pool.ensure_scale(1)
        job.coord = StreamingCoordinator(
            job.store, self.meta, bus=self.bus, program=job.program,
            options=job.options, pool=self.pool)
        job.cursor = job.coord.restore_state()
        dt = time.perf_counter() - t0
        job.idle_rounds = 0
        if cold:
            job.cold_start_latencies.append(dt)
            self.registry.bump(job.job_id, "restores")
            self.registry.bump(job.job_id, "cold_start_seconds", dt)
        self._transition(job, JobStatus.RUNNING, verb=verb)

    def _drive(self, job: _Job, park_when_idle: bool = True) -> int:
        """Fold the job's currently-available tail, batch by batch, at
        its own cursor.  No new records → an idle round; enough idle
        rounds → park (unless the caller — ``finish`` — is about to flush
        this very coordinator)."""
        if job.coord is None:
            self._restore(job, verb="started")
        if not job.ingest.lag(job.cursor):
            job.idle_rounds += 1
            if park_when_idle and job.idle_rounds >= self.park_after_idle \
                    and job.state == JobStatus.RUNNING:
                self._park(job)
            return 0
        job.idle_rounds = 0
        start = job.cursor
        try:
            job.coord.announce(job.sub, start_record=start)
            for batch in job.sub.batches(start_record=start):
                job.coord.process_batch(batch, job.report)
                job.cursor += len(batch)
        except StorageError as exc:
            self._fail(job, exc)
            return job.cursor - start
        return job.cursor - start

    def _park(self, job: _Job) -> None:
        """Scale-to-zero: checkpoint at the barrier, drop the coordinator
        (frees the device carries), retire pool instances if nothing else
        runs.  The job's next matching event cold-restores it."""
        self._checkpoint(job)
        job.coord = None
        self.registry.bump(job.job_id, "parks")
        self._transition(job, JobStatus.PARKED, verb="parked")
        self._maybe_scale_to_zero()

    def _fail(self, job: _Job, exc: Exception) -> None:
        job.error = f"{type(exc).__name__}: {exc}"
        job.coord = None
        self.registry.update(job.job_id, error=job.error)
        self._transition(job, JobStatus.FAILED, verb="failed")
        self._maybe_scale_to_zero()

    def _maybe_scale_to_zero(self) -> None:
        if not any(j.state == JobStatus.RUNNING
                   for j in self.jobs.values()):
            self.pool.scale_to_zero()

    # -- introspection -------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        return {
            "jobs": {jid: j.state for jid, j in self.jobs.items()},
            "pool": self.pool.stats(),
            "ingests": {key: {"pumped": ing.pumped, "pumps": ing.pumps,
                              "subscribers": len(ing.subscribers)}
                        for key, ing in self.ingests.items()},
        }
