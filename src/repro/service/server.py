"""The JobServer — many tenants, one engine pool, a parked-job lifecycle.

One server instance holds the four shared substrates — ObjectStore,
MetadataStore, EventBus, ServerlessPool — and multiplexes any number of
``BuiltPipeline`` programs over them:

* **submit/pause/resume/cancel/status** are the control-plane verbs the
  paper's client exercises over HTTP against the Coordinator; here they
  drive metadata-backed :class:`~repro.service.registry.JobRegistry`
  records, so any process holding the MetadataStore observes the same
  lifecycle.  (``launch.serve.JobSocketServer`` puts them behind a real
  socket; :class:`~repro.core.client.JobServiceClient` dials it.)
* **Ingest is physical-once**: every source prefix gets one
  :class:`~repro.service.ingest_share.SharedIngest` (optionally
  N-partitioned — subscribers may drain disjoint partition subsets);
  jobs subscribe with private cursors and ``step()`` pumps each ingest
  exactly once per round regardless of subscriber count.
* **Scale-to-zero lifecycle**: a job whose backlog stays at or below
  ``ParkPolicy.max_lag`` for ``ParkPolicy.idle_seconds`` of wall-clock
  time is *parked* — its lanes drain at the micro-batch barrier (they
  always do), its one-pytree carry state is checkpointed, its
  coordinator is dropped, and when no job remains running the pool
  retires every instance.  Backlog above ``max_lag`` *unparks* it: a
  fresh coordinator cold-restores the checkpoint (measured — this is
  the cold start the paper's Fig. 6 charges) and resumes from the
  checkpointed record offset.  Emission idempotence makes the round
  trip exactly-once: re-finalized windows re-write the same bytes,
  already-persisted ones are skipped.
* **Compute is metered**: every job folds through a
  :class:`~repro.core.autoscaler.MeteredPool` view of the one shared
  pool, so ``status()`` reports per-job pool-seconds and fold
  invocations — the quantities the paper bills — and a tenant's
  ``quota_pool_seconds`` fails only that tenant's jobs with
  :class:`~repro.service.tenancy.ComputeQuotaExceeded`.

The drive loop stays deterministic either way it runs.  Serially,
``step()`` round-robins jobs, each folding its tail to completion.
With ``overlap=True`` (the default) and more than one lagging job,
``step()`` multiplexes the PR 6 three-lane scheduler across jobs: each
job gets a private prefetch thread host-preparing its next micro-batch
while the driver thread round-robins the fold/drain lanes, so tenant
A's device fold overlaps tenant B's host prepare.  Within a job nothing
leaves the serial order — prepare is pure, folds and key-table
mutations happen on the driver thread batch-by-batch, checkpoints only
at barriers — and across jobs nothing is shared but the pool, bus, and
store (all order-insensitive for sink bytes), so the overlapped drive
is byte-identical to the serial one, crash included (property-tested
in ``tests/test_job_service.py``).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Iterable

from ..analysis.diagnostics import PlanRejected, errors
from ..core.autoscaler import (AutoscalerConfig, ComputeMeter, MeteredPool,
                               ServerlessPool)
from ..core.events import (TOPIC_JOB_LIFECYCLE, EventBus,
                           job_lifecycle_event)
from ..core.metadata import MetadataStore
from ..core.storage import ObjectStore, StorageError
from ..streaming.coordinator import (Prefetcher, RunOptions,
                                     StreamingCoordinator, StreamReport,
                                     saved_offset)
from .ingest_share import SharedIngest, SubscriberSource
from .registry import JobRegistry
from .tenancy import ComputeQuotaExceeded, Tenant

__all__ = ["JobServer", "JobStatus", "ParkPolicy"]


class JobStatus:
    """Lifecycle states — string constants, mirrored into the metadata
    records so clients need no enum import to poll them."""

    PENDING = "PENDING"      # submitted, coordinator not yet built
    RUNNING = "RUNNING"      # live coordinator, folding batches
    PAUSED = "PAUSED"        # parked by explicit request; only resume() wakes
    PARKED = "PARKED"        # scaled to zero; next matching event wakes
    DONE = "DONE"
    CANCELLED = "CANCELLED"
    FAILED = "FAILED"

    TERMINAL = (DONE, CANCELLED, FAILED)


@dataclass(frozen=True)
class ParkPolicy:
    """Wall-clock/lag thresholds for the scale-to-zero lifecycle.

    A RUNNING job whose backlog stays at or below ``max_lag`` records
    for ``idle_seconds`` of wall-clock time parks (barrier checkpoint,
    coordinator dropped, pool retired when nothing else runs); a PARKED
    job wakes only when its backlog exceeds ``max_lag``.  ``max_lag > 0``
    lets small dribbles batch up instead of paying a cold start per
    record; ``idle_seconds=0.0`` parks on the first idle observation
    (what the round-based threshold used to approximate).  The server
    holds one default policy; ``submit(park_policy=...)`` overrides it
    per job.
    """

    idle_seconds: float = 0.25
    max_lag: int = 0

    def validate(self) -> None:
        """Reject unusable thresholds (negative time or lag)."""
        if self.idle_seconds < 0:
            raise ValueError("idle_seconds must be >= 0")
        if self.max_lag < 0:
            raise ValueError("max_lag must be >= 0")


@dataclass
class _Job:
    """Server-side live state for one submitted job.  Everything durable
    lives in the registry records; this holds only what a crash may lose
    (and restore rebuilds): the coordinator and its drive bookkeeping."""

    job_id: str
    tenant: Tenant
    program: Any
    options: RunOptions
    store: ObjectStore                  # the tenant's namespaced view
    ingest: SharedIngest
    sub: SubscriberSource
    park_policy: ParkPolicy
    state: str = JobStatus.PENDING
    coord: StreamingCoordinator | None = None
    report: StreamReport = None
    cursor: int = 0                     # records consumed (live offset)
    idle_since: float | None = None     # monotonic time the backlog emptied
    meter: ComputeMeter = field(default_factory=ComputeMeter)
    error: str | None = None
    cold_start_latencies: list = field(default_factory=list)

    def __post_init__(self) -> None:
        if self.report is None:
            self.report = StreamReport(self.job_id)


class JobServer:
    """Control plane + drive loop over the shared substrates.

    ``park_policy`` sets the default park/wake thresholds (see
    :class:`ParkPolicy`), ``overlap`` turns the multi-tenant overlapped
    drive on (byte-identical to serial, so there is no correctness
    reason to turn it off), and ``ingest_partitions`` is the default
    partition count for newly created shared ingests.
    """

    def __init__(self, store: ObjectStore, meta: MetadataStore | None = None,
                 bus: EventBus | None = None, *,
                 autoscaler: AutoscalerConfig | None = None,
                 park_policy: ParkPolicy | None = None,
                 overlap: bool = True,
                 ingest_partitions: int = 1) -> None:
        self.store = store
        self.meta = meta if meta is not None else MetadataStore()
        self.bus = bus if bus is not None else EventBus()
        self.pool = ServerlessPool("job-server",
                                   autoscaler or AutoscalerConfig())
        self.registry = JobRegistry(self.meta)
        self.park_policy = park_policy if park_policy is not None \
            else ParkPolicy()
        self.park_policy.validate()
        self.overlap = overlap
        self.ingest_partitions = max(1, int(ingest_partitions))
        self.tenants: dict[str, Tenant] = {}
        self.ingests: dict[str, SharedIngest] = {}
        self.jobs: dict[str, _Job] = {}

    # -- tenancy / ingest setup ---------------------------------------------
    def add_tenant(self, name: str, quota_bytes: int | None = None,
                   quota_pool_seconds: float | None = None) -> Tenant:
        """Register (or fetch) a tenant; quotas bound its bytes in the
        shared store and its seconds on the shared pool."""
        if name in self.tenants:
            return self.tenants[name]
        t = Tenant(name, quota_bytes, quota_pool_seconds)
        self.tenants[name] = t
        return t

    def shared_ingest(self, prefix: str, batch_records: int = 1024,
                      n_partitions: int | None = None) -> SharedIngest:
        """The one physical reader for ``prefix`` — created on first use
        (with ``n_partitions`` or the server default), shared by every
        later subscriber."""
        key = prefix.rstrip("/")
        if key not in self.ingests:
            self.ingests[key] = SharedIngest(
                self.bus, self.store, prefix, batch_records=batch_records,
                n_partitions=n_partitions or self.ingest_partitions)
        return self.ingests[key]

    # -- control-plane verbs -------------------------------------------------
    def submit(self, tenant: str, program, *, source_prefix: str,
               options: RunOptions | None = None,
               resume: bool = False,
               partitions: Iterable[int] | None = None,
               park_policy: ParkPolicy | None = None) -> str:
        """Register a program for a tenant against a shared source.

        The registry enforces global job-id uniqueness and rejects
        cross-job sink-prefix collisions on the shared store before the
        job can write anything; ``resume=True`` re-attaches a job that a
        crashed server had already registered — its checkpoint (if any)
        is honored on first drive, so recovery is exactly-once.
        ``partitions`` restricts the job's subscriber to a subset of the
        shared ingest's partitions (parallel jobs splitting one source);
        ``park_policy`` overrides the server's default thresholds.

        Admission runs planlint first: a program with error-level
        findings (a ring that must overflow, colliding sinks, an unfed
        join side) raises :class:`~repro.analysis.diagnostics.PlanRejected`
        *before* the job registers — the plan-level twin of the
        ``QuotaExceeded`` pattern, failing only this tenant's submit.
        """
        if tenant not in self.tenants:
            raise KeyError(f"unknown tenant {tenant!r}; add_tenant first")
        bad = errors(program.check(options))
        if bad:
            raise PlanRejected(bad)
        if park_policy is not None:
            park_policy.validate()
        t = self.tenants[tenant]
        fresh = self.registry.register(
            program.job_id, tenant,
            [t.qualify(p) for p in program.output_prefixes()],
            resume=resume)
        ingest = self.shared_ingest(source_prefix,
                                    batch_records=program.batch_records)
        sub = ingest.subscribe(program.job_id,
                               batch_records=program.batch_records,
                               partitions=partitions)
        job = _Job(job_id=program.job_id, tenant=t, program=program,
                   options=options or RunOptions(),
                   store=t.store_view(self.store), ingest=ingest, sub=sub,
                   park_policy=park_policy or self.park_policy)
        self.jobs[job.job_id] = job
        if fresh:
            self._transition(job, JobStatus.PENDING, verb="submitted")
        else:
            self._transition(job, JobStatus.PENDING, verb="reattached")
        return job.job_id

    def pause(self, job_id: str) -> None:
        """Park immediately on explicit request; only resume() wakes it
        (arriving events do not)."""
        job = self._job(job_id)
        self._check_live(job, "pause")
        if job.coord is not None:
            self._checkpoint(job)
            job.coord = None
        self._transition(job, JobStatus.PAUSED, verb="paused")
        self._maybe_scale_to_zero()

    def resume(self, job_id: str) -> None:
        """Wake a paused job — a cold restore if it had checkpointed."""
        job = self._job(job_id)
        if job.state != JobStatus.PAUSED:
            raise ValueError(f"job {job_id!r} is {job.state}, not PAUSED")
        self._restore(job, verb="resumed")

    def cancel(self, job_id: str) -> None:
        """Stop a job for good.  No flush — half-open windows are
        abandoned; already-persisted windows (and the prefix claim) stay,
        as S3 objects would."""
        job = self._job(job_id)
        self._check_live(job, "cancel")
        job.coord = None
        self._transition(job, JobStatus.CANCELLED, verb="cancelled")
        self._maybe_scale_to_zero()

    def status(self, job_id: str) -> dict[str, Any]:
        """The registry record plus live drive state — what the paper's
        client renders while polling.  Includes the job's compute bill
        (``pool_seconds``/``fold_invocations``) and its durable
        ``checkpointed_offset``.

        A job without a live coordinator (parked, paused, or freshly
        re-attached after a crash) reports position from the barrier
        checkpoint, not from the in-memory cursor — the pre-park live
        counters die with the coordinator, and a re-attached job's
        cursor is 0 until its first drive, which would misreport the
        whole log as lag."""
        job = self._job(job_id)
        rec = self.registry.record(job_id)
        checkpointed = saved_offset(self.meta, job_id)
        cursor = job.cursor if job.coord is not None \
            else max(job.cursor, checkpointed)
        rec.update({
            "job_id": job_id,
            "cursor": cursor,
            "checkpointed_offset": checkpointed,
            "lag": job.sub.lag(cursor),
            "batches": job.report.batches,
            "records_in": job.report.records_in,
            "windows_emitted": job.report.windows_emitted,
            "error": job.error,
            **job.meter.as_dict(),
        })
        return rec

    # -- the drive loop ------------------------------------------------------
    def step(self) -> int:
        """One scheduling round: pump every shared ingest once (the only
        physical log reads), wake parked jobs whose backlog crossed their
        policy's ``max_lag``, drive every runnable job over its available
        tail — overlapped across jobs when more than one has backlog and
        ``overlap`` is on — and park the idle.  Returns records moved
        (pumped + folded) — 0 means quiescent."""
        moved = 0
        for ingest in self.ingests.values():
            moved += ingest.pump()
        runnable: list[_Job] = []
        for job in list(self.jobs.values()):
            if job.state == JobStatus.PARKED \
                    and job.sub.lag(job.cursor) > job.park_policy.max_lag:
                self._restore(job, verb="restored")
            if job.state in (JobStatus.PENDING, JobStatus.RUNNING):
                runnable.append(job)
        lagging = [j for j in runnable
                   if j.sub.lag(j.cursor) > j.park_policy.max_lag]
        if self.overlap and len(lagging) > 1:
            moved += self._drive_overlapped(lagging)
            lagging_ids = {j.job_id for j in lagging}
            rest = [j for j in runnable if j.job_id not in lagging_ids]
        else:
            rest = runnable
        for job in rest:
            if job.state in (JobStatus.PENDING, JobStatus.RUNNING):
                moved += self._drive(job)
        return moved

    def run_until_complete(self, flush: bool = True) -> dict[str, str]:
        """Drive until no ingest yields new records and every job is
        drained, then finish each live job (end-of-stream flush).  Paused
        jobs stay paused — completing them would override an explicit
        operator verb.  Returns {job_id: final state}."""
        while self.step():
            pass
        for job in list(self.jobs.values()):
            if job.state not in JobStatus.TERMINAL + (JobStatus.PAUSED,):
                self.finish(job.job_id, flush=flush)
        return {jid: j.state for jid, j in self.jobs.items()}

    def finish(self, job_id: str, flush: bool = True) -> StreamReport:
        """Drain a job's remaining tail and finalize it: end-of-stream
        watermark through every stage, sinks flushed, final checkpoint —
        the sink bytes now match a standalone flushed run's exactly."""
        job = self._job(job_id)
        self._check_live(job, "finish")
        job.ingest.pump()
        if job.coord is None:
            self._restore(job, verb="restored")
        self._drive(job, park_when_idle=False)
        if job.state == JobStatus.FAILED:
            return job.report
        try:
            if flush:
                job.coord.flush_end_of_stream(job.report)
        except StorageError as exc:
            self._fail(job, exc)
            return job.report
        job.coord = None
        self._transition(job, JobStatus.DONE, verb="done")
        self.registry.update(job_id, cursor=job.cursor)
        self._maybe_scale_to_zero()
        return job.report

    # -- lifecycle internals -------------------------------------------------
    def _job(self, job_id: str) -> _Job:
        if job_id not in self.jobs:
            raise KeyError(f"unknown job: {job_id}")
        return self.jobs[job_id]

    def _check_live(self, job: _Job, verb: str) -> None:
        if job.state in JobStatus.TERMINAL:
            raise ValueError(f"cannot {verb} job {job.job_id!r}: "
                             f"already {job.state}")

    def _transition(self, job: _Job, state: str, *, verb: str) -> None:
        job.state = state
        self.registry.update(job.job_id, state=state, cursor=job.cursor,
                             **job.meter.as_dict())
        self.bus.produce(TOPIC_JOB_LIFECYCLE,
                         job_lifecycle_event(job.job_id, job.tenant.name,
                                             verb, {"cursor": job.cursor}))

    def _checkpoint(self, job: _Job) -> None:
        """Barrier checkpoint: the drive loop only rests at micro-batch
        barriers (lanes drained), so the one-pytree carry snapshot is
        always consistent here."""
        if job.report.batches:
            job.coord.save_state()
        self.registry.update(job.job_id, cursor=job.cursor,
                             **job.meter.as_dict())

    def _restore(self, job: _Job, *, verb: str) -> None:
        """Build (or cold-rebuild) the job's coordinator and restore its
        checkpoint.  Timed end to end — pool activation, carry download,
        tracker/dictionary rebuild — because this *is* the serverless
        cold start the lifecycle trades against idle cost.  The
        coordinator folds through a per-job ``MeteredPool`` view of the
        one shared pool, so its compute bills to this job alone."""
        cold = job.state in (JobStatus.PARKED, JobStatus.PAUSED)
        t0 = time.perf_counter()
        self.pool.ensure_scale(1)
        job.coord = StreamingCoordinator(
            job.store, self.meta, bus=self.bus, program=job.program,
            options=job.options, pool=MeteredPool(self.pool, job.meter))
        job.cursor = job.coord.restore_state()
        dt = time.perf_counter() - t0
        job.idle_since = None
        if cold:
            job.cold_start_latencies.append(dt)
            self.registry.bump(job.job_id, "restores")
            self.registry.bump(job.job_id, "cold_start_seconds", dt)
        self._transition(job, JobStatus.RUNNING, verb=verb)

    def _drive(self, job: _Job, park_when_idle: bool = True) -> int:
        """Fold the job's currently-available tail, batch by batch, at
        its own cursor.  Backlog at or below the job's ``max_lag`` counts
        as idle; idle past ``idle_seconds`` of wall clock parks the job
        (unless the caller — ``finish`` — is about to flush this very
        coordinator, in which case any backlog at all drains)."""
        if job.coord is None:
            self._restore(job, verb="started")
        policy = job.park_policy
        threshold = policy.max_lag if park_when_idle else 0
        if job.sub.lag(job.cursor) <= threshold:
            if park_when_idle:
                now = time.monotonic()
                if job.idle_since is None:
                    job.idle_since = now
                if now - job.idle_since >= policy.idle_seconds \
                        and job.state == JobStatus.RUNNING:
                    self._park(job)
            return 0
        job.idle_since = None
        start = job.cursor
        try:
            job.coord.announce(job.sub, start_record=start)
            for batch in job.sub.batches(start_record=start):
                job.coord.process_batch(batch, job.report)
                job.cursor += len(batch)
                if not self._within_compute_quota(job):
                    break
        except StorageError as exc:
            self._fail(job, exc)
        return job.cursor - start

    def _drive_overlapped(self, jobs: list[_Job]) -> int:
        """Multiplex the three-lane scheduler across jobs: one private
        prefetch thread per job host-prepares its next micro-batches
        (bounded by its own ``RunOptions.prefetch_batches``) while this
        driver thread round-robins ``process_prepared`` — device fold,
        watermark, sink/stats drains — across jobs in per-job batch
        order.

        Byte-identity with the serial drive holds by construction:
        prepare is pure (``@lane("prefetch")``), every mutation of a
        job's key tables, carries, and sinks happens here on the driver
        thread in that job's batch order, and jobs share nothing whose
        bytes depend on cross-job order (per-job consumer groups on the
        bus, tenant-namespaced stores, a synchronous pool).  A failing
        job closes its own lanes and fails alone; a crash behaves like
        the serial crash — prepared-but-unconsumed batches simply never
        advance the checkpoint, so restart replays them exactly-once.
        """
        lanes: list[tuple[_Job, Any, Any]] = []
        for job in jobs:
            if job.coord is None:
                self._restore(job, verb="started")
            job.idle_since = None
            job.coord.announce(job.sub, start_record=job.cursor)
            prefetch = Prefetcher(job.sub.batches(start_record=job.cursor),
                                  job.coord.prepare_batch,
                                  job.options.prefetch_batches)
            lanes.append((job, iter(prefetch), prefetch))
        moved = 0
        try:
            while lanes:
                still: list[tuple[_Job, Any, Any]] = []
                for lane in lanes:
                    job, batches, prefetch = lane
                    try:
                        prep = next(batches)
                    except StopIteration:
                        prefetch.close()
                        continue
                    except StorageError as exc:
                        prefetch.close()
                        self._fail(job, exc)
                        continue
                    try:
                        job.coord.process_prepared(prep, job.report)
                    except StorageError as exc:
                        prefetch.close()
                        self._fail(job, exc)
                        continue
                    job.cursor += prep.n_records
                    moved += prep.n_records
                    if self._within_compute_quota(job):
                        still.append(lane)
                    else:
                        prefetch.close()
                lanes = still
        finally:
            for _, _, prefetch in lanes:
                prefetch.close()
        return moved

    def _within_compute_quota(self, job: _Job) -> bool:
        """Enforce the tenant's pool-time quota against the summed meters
        of all its jobs; over quota fails THIS job (its neighbors keep
        their own accounts) and reports False so drive loops stop charging
        it."""
        quota = job.tenant.quota_pool_seconds
        if quota is None:
            return True
        used = sum(j.meter.pool_seconds for j in self.jobs.values()
                   if j.tenant.name == job.tenant.name)
        if used <= quota:
            return True
        self._fail(job, ComputeQuotaExceeded(
            f"tenant {job.tenant.name!r} used {used:.6f}s of its "
            f"{quota:.6f}s pool-time quota"))
        return False

    def _park(self, job: _Job) -> None:
        """Scale-to-zero: checkpoint at the barrier, drop the coordinator
        (frees the device carries), retire pool instances if nothing else
        runs.  The job's next matching event cold-restores it."""
        self._checkpoint(job)
        job.coord = None
        self.registry.bump(job.job_id, "parks")
        self._transition(job, JobStatus.PARKED, verb="parked")
        self._maybe_scale_to_zero()

    def _fail(self, job: _Job, exc: Exception) -> None:
        job.error = f"{type(exc).__name__}: {exc}"
        job.coord = None
        self.registry.update(job.job_id, error=job.error)
        self._transition(job, JobStatus.FAILED, verb="failed")
        self._maybe_scale_to_zero()

    def _maybe_scale_to_zero(self) -> None:
        if not any(j.state == JobStatus.RUNNING
                   for j in self.jobs.values()):
            self.pool.scale_to_zero()

    # -- introspection -------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        """Server-wide snapshot: job states, shared-pool counters,
        per-ingest pump accounting, and per-job compute meters."""
        return {
            "jobs": {jid: j.state for jid, j in self.jobs.items()},
            "pool": self.pool.stats(),
            "ingests": {key: {"pumped": ing.pumped, "pumps": ing.pumps,
                              "partitions": ing.n_partitions,
                              "subscribers": len(ing.subscribers)}
                        for key, ing in self.ingests.items()},
            "meters": {jid: j.meter.as_dict()
                       for jid, j in self.jobs.items()},
        }
