"""Multi-tenant job service — the control plane over the streaming engine.

The paper's framework is one job per deployment: a client submits a JSON
config, the coordinator spins workers up from zero, and everything is
torn down at the end.  This package is the *service* form of the same
five components — many ``BuiltPipeline`` programs from many tenants
registered against one engine pool:

* :mod:`tenancy` — tenants as namespaced, quota-bounded views of one
  shared object store (per-team S3 prefixes + IAM, in miniature);
* :mod:`ingest_share` — ONE physical read per source: a ``SharedIngest``
  materializes the event log onto a single-partition bus topic and every
  subscribing job replays it from a private record cursor (late
  registrants catch up from offset 0);
* :mod:`registry` — metadata-backed job records (the Redis schema) plus
  the cross-job sink-prefix collision check;
* :mod:`server` — the ``JobServer`` control plane: submit / pause /
  resume / cancel / status verbs, a shared ``ServerlessPool``, and the
  lag-driven lifecycle that parks an idle job (barrier checkpoint →
  drop its coordinator → scale the pool to zero) and cold-restores it
  on the next matching event, exactly-once across the round trip.

``repro.core.client.JobServiceClient`` is the user-facing package over
this control plane, polling the same metadata records the paper's
Python client polls in Redis.
"""

from .ingest_share import SharedIngest, SubscriberSource
from .registry import JobRegistry
from .server import JobServer, JobStatus
from .tenancy import Tenant

__all__ = ["JobServer", "JobStatus", "JobRegistry", "SharedIngest",
           "SubscriberSource", "Tenant"]
