"""Multi-tenant job service — the control plane over the streaming engine.

The paper's framework is one job per deployment: a client submits a JSON
config, the coordinator spins workers up from zero, and everything is
torn down at the end.  This package is the *service* form of the same
five components — many ``BuiltPipeline`` programs from many tenants
registered against one engine pool:

* :mod:`tenancy` — tenants as namespaced, quota-bounded views of one
  shared object store (per-team S3 prefixes + IAM, in miniature), with
  byte quotas on storage and pool-second quotas on compute;
* :mod:`ingest_share` — ONE physical read per source: a ``SharedIngest``
  materializes the event log onto a bus topic (optionally N-partitioned
  by record key) and every subscribing job replays it from a private
  record cursor (late registrants catch up from offset 0; parallel
  subscribers may drain disjoint partition subsets);
* :mod:`registry` — metadata-backed job records (the Redis schema) plus
  the cross-job sink-prefix collision check;
* :mod:`server` — the ``JobServer`` control plane: submit / pause /
  resume / cancel / status verbs, a shared ``ServerlessPool`` metered
  per job, an overlapped multi-tenant drive loop (byte-identical to the
  serial round-robin), and the ``ParkPolicy``-driven lifecycle that
  parks an idle job (barrier checkpoint → drop its coordinator → scale
  the pool to zero) and cold-restores it on the next matching event,
  exactly-once across the round trip.

``repro.core.client.JobServiceClient`` is the user-facing package over
this control plane — polling the same metadata records the paper's
Python client polls in Redis, or dialing the socket transport
(``launch.serve.JobSocketServer``) across a process boundary.
"""

from .ingest_share import SharedIngest, SubscriberSource
from .registry import JobRegistry
from .server import JobServer, JobStatus, ParkPolicy
from .tenancy import ComputeQuotaExceeded, Tenant

__all__ = ["ComputeQuotaExceeded", "JobServer", "JobStatus", "JobRegistry",
           "ParkPolicy", "SharedIngest", "SubscriberSource", "Tenant"]
