"""Shared ingest — one physical log read, fanned out to every job.

When N jobs consume the same source, the naive deployment reads the
event log N times (N× the GET traffic the paper bills for).  The job
server instead materializes each source ONCE: a :class:`SharedIngest`
owns the only :class:`~repro.streaming.source.StreamSource` over the
physical log and ``pump()`` appends its unread tail onto a private bus
topic (``repro.ingest.<source>``) — the "materialized intermediate
stream".  Every subscribing job reads that topic through a
:class:`SubscriberSource` with a *private record cursor* (the bus's
group-less ``fetch``), so:

* subscribers never advance each other's positions,
* a job registering late replays from offset 0 and catches up,
* a restored job resumes from its checkpointed record offset — cursor
  addressing is identical to the coordinator's record-addressed resume.

Partitioning.  The topic may carry ``n_partitions`` partitions routed
by record key (the bus's stable FNV-1a ``partition_for``), so parallel
subscribers can each drain a disjoint partition subset of one source
concurrently.  Determinism survives partitioning because every
materialized event carries its global ``seq`` (the record's index in
the physical log): a subscriber's view is the seq-sorted merge of its
assigned partitions, which is a pure function of the log — independent
of pump timing, partition interleaving, or crash/re-materialization.
A subscriber's scalar cursor counts records of *its own merged view*,
and :meth:`SharedIngest.partition_cursors` dissects that scalar into
the equivalent per-(subscriber, partition) replay cursors — the prefix
of length ``cursor`` always splits into the same per-partition
prefixes, which is what makes replay exactly-once per partition across
a crash/re-attach.
"""

from __future__ import annotations

import heapq
from itertools import islice
from typing import Iterable, Iterator, Sequence

from ..core.events import CloudEvent, EventBus, Record, ingest_topic
from ..core.storage import ObjectStore
from ..streaming.source import StreamSource

__all__ = ["SharedIngest", "SubscriberSource"]


def _record_event(source_id: str, record: tuple, seq: int) -> CloudEvent:
    """Materialized-record envelope: the ``(ts, key, value)`` triple plus
    ``seq``, the record's global index in the physical log — the anchor
    that lets any partition subset merge back into log order."""
    return CloudEvent(type="repro.ingest.record", source=source_id,
                      data={"record": list(record), "seq": seq})


def _seq(rec: Record) -> int:
    return rec.value.data["seq"]


class SharedIngest:
    """One source's single physical reader plus its materialized topic.

    ``n_partitions`` controls the materialized topic's width: 1 (the
    default) mirrors the log one-to-one; N > 1 routes records by key so
    subscribers can drain disjoint partition subsets in parallel.  Every
    subscriber view — whole topic or subset — is deterministic because
    records merge by their global ``seq``.
    """

    def __init__(self, bus: EventBus, store: ObjectStore, prefix: str, *,
                 source_id: str | None = None,
                 batch_records: int = 1024,
                 n_partitions: int = 1) -> None:
        if n_partitions < 1:
            raise ValueError("n_partitions must be >= 1")
        self.bus = bus
        self.prefix = prefix
        self.source_id = source_id or prefix.strip("/")
        self.source = StreamSource(store=store, prefix=prefix,
                                   batch_records=batch_records)
        self.topic = ingest_topic(self.source_id)
        topic = bus.create_topic(self.topic, n_partitions=n_partitions)
        # create_topic returns the existing topic if someone else made it
        # first — adopt its width so cursors stay consistent
        self.n_partitions = len(topic.partitions)
        self.pumped = 0          # records materialized so far
        self.pumps = 0           # physical tail reads taken
        self.subscribers: dict[str, "SubscriberSource"] = {}

    # -- the one physical read ----------------------------------------------
    def pump(self) -> int:
        """Materialize the log's unread tail onto the topic — the only
        place the physical log is ever read, however many jobs subscribe.
        Records are routed to partitions by record key (stable FNV-1a, so
        a crashed server re-materializes the identical layout) and carry
        their global ``seq``.  Returns new records appended."""
        n = 0
        for rec in self.source.events_from(self.pumped):
            self.bus.produce(self.topic,
                             _record_event(self.source_id, rec,
                                           self.pumped + n),
                             key=str(rec[1]))
            n += 1
        self.pumped += n
        self.pumps += 1
        return n

    # -- subscriber fan-out --------------------------------------------------
    def subscribe(self, subscriber_id: str, batch_records: int = 1024,
                  partitions: Iterable[int] | None = None,
                  ) -> "SubscriberSource":
        """A private replay cursor over the materialized stream.  Always
        starts at offset 0 — a late registrant catches up from the log's
        beginning; an already-checkpointed job resumes further in because
        the *coordinator* passes its record offset to ``batches()``.
        ``partitions`` restricts the view to a partition subset (default:
        all) so parallel subscribers can split one source between them."""
        if subscriber_id in self.subscribers:
            raise ValueError(f"subscriber {subscriber_id!r} already "
                             f"registered on {self.topic}")
        sub = SubscriberSource(self, subscriber_id,
                               batch_records=batch_records,
                               partitions=partitions)
        self.subscribers[subscriber_id] = sub
        return sub

    def _parts(self, partitions: Sequence[int] | None) -> tuple[int, ...]:
        if partitions is None:
            return tuple(range(self.n_partitions))
        return tuple(partitions)

    def end_offset(self, partitions: Sequence[int] | None = None) -> int:
        """Total materialized records across ``partitions`` (default all)
        — the length of that view's merged log."""
        return sum(self.bus.end_offset(self.topic, p)
                   for p in self._parts(partitions))

    def records_from(self, offset: int,
                     partitions: Sequence[int] | None = None,
                     ) -> Iterator[tuple]:
        """The merged ``(ts, key, value)`` view of ``partitions`` in
        global ``seq`` order, skipping its first ``offset`` records.
        Single-partition views read the partition log directly (offset ==
        partition offset); multi-partition views seq-merge — both yield
        the identical deterministic sequence for a given log."""
        parts = self._parts(partitions)
        if len(parts) == 1:
            records = iter(self.bus.fetch(self.topic, parts[0], offset))
        else:
            logs = [self.bus.fetch(self.topic, p, 0) for p in parts]
            records = islice(heapq.merge(*logs, key=_seq), offset, None)
        for rec in records:
            ts, key, value = rec.value.data["record"]
            yield (ts, key, value)

    def partition_cursors(self, cursor: int,
                          partitions: Sequence[int] | None = None,
                          ) -> dict[int, int]:
        """Dissect a subscriber's scalar cursor into per-(subscriber,
        partition) replay cursors: for each assigned partition, how many
        of its records fall inside the first ``cursor`` records of the
        merged view.  Because the merge order is a pure function of the
        log (global ``seq``), this dissection is stable across pump
        timing and crash/re-attach — replaying partition ``p`` from
        ``partition_cursors(c)[p]`` is exactly-once per partition."""
        parts = self._parts(partitions)
        cursors = {p: 0 for p in parts}
        logs = [[(_seq(r), p) for r in self.bus.fetch(self.topic, p, 0)]
                for p in parts]
        for _, p in islice(heapq.merge(*logs), cursor):
            cursors[p] += 1
        return cursors

    def lag(self, cursor: int,
            partitions: Sequence[int] | None = None) -> int:
        """Materialized records a subscriber at ``cursor`` has not yet
        consumed from its view — the unpark signal."""
        return max(0, self.end_offset(partitions) - cursor)


class SubscriberSource(StreamSource):
    """One job's view of a shared ingest: a ``StreamSource`` whose log is
    the materialized topic (or a partition subset of it), read from a
    private record cursor.

    Subclassing matters — the run-time dispatch (``BuiltPipeline.run``'s
    mode inference) and the coordinator's record-addressed ``batches(
    start_record=...)`` contract both see exactly the source type they
    already handle, so a job cannot tell whether it owns its log or
    shares it — or whether its view is the whole topic or a partition
    slice.
    """

    def __init__(self, ingest: SharedIngest, subscriber_id: str, *,
                 batch_records: int = 1024,
                 partitions: Iterable[int] | None = None) -> None:
        # deliberately not calling super().__init__: the log lives on the
        # shared topic, not in a store prefix or an in-memory record list
        if batch_records < 1:
            raise ValueError("batch_records must be >= 1")
        if partitions is None:
            parts = None
        else:
            parts = tuple(sorted(set(int(p) for p in partitions)))
            if not parts:
                raise ValueError("partitions must be non-empty when given")
            bad = [p for p in parts if not 0 <= p < ingest.n_partitions]
            if bad:
                raise ValueError(
                    f"partition(s) {bad} out of range for "
                    f"{ingest.topic} with {ingest.n_partitions} partitions")
        self.ingest = ingest
        self.subscriber_id = subscriber_id
        self.batch_records = batch_records
        self.partitions = parts
        self.store = None
        self.prefix = ingest.prefix
        self._records = None

    def _events_from(self, skip: int) -> Iterator[tuple]:
        return self.ingest.records_from(skip, self.partitions)

    def batch_sizes(self, start_record: int = 0) -> list[int]:
        total = max(0, self.ingest.end_offset(self.partitions) - start_record)
        sizes = []
        while total > 0:
            sizes.append(min(total, self.batch_records))
            total -= sizes[-1]
        return sizes

    def lag(self, cursor: int) -> int:
        """Unconsumed records in this subscriber's view — the park/unpark
        signal the job server polls."""
        return self.ingest.lag(cursor, self.partitions)

    def partition_cursors(self, cursor: int) -> dict[int, int]:
        """This subscriber's per-partition replay cursors at scalar
        position ``cursor`` (see ``SharedIngest.partition_cursors``)."""
        return self.ingest.partition_cursors(cursor, self.partitions)
