"""Shared ingest — one physical log read, fanned out to every job.

When N jobs consume the same source, the naive deployment reads the
event log N times (N× the GET traffic the paper bills for).  The job
server instead materializes each source ONCE: a :class:`SharedIngest`
owns the only :class:`~repro.streaming.source.StreamSource` over the
physical log and ``pump()`` appends its unread tail onto a private
single-partition bus topic (``repro.ingest.<source>``) — the
"materialized intermediate stream".  Every subscribing job reads that
topic through a :class:`SubscriberSource` with a *private record
cursor* (the bus's group-less ``fetch``), so:

* subscribers never advance each other's positions,
* a job registering late replays from offset 0 and catches up,
* a restored job resumes from its checkpointed record offset — cursor
  addressing is identical to the coordinator's record-addressed resume.

Single-partition is by construction, not limitation: the physical log
is totally ordered and exactly-once replay requires every subscriber to
see the same order, so the topic mirrors the log one-to-one (offset ==
record index).
"""

from __future__ import annotations

from typing import Iterator

from ..core.events import CloudEvent, EventBus, ingest_topic
from ..core.storage import ObjectStore
from ..streaming.source import StreamSource

__all__ = ["SharedIngest", "SubscriberSource"]


def _record_event(source_id: str, record: tuple) -> CloudEvent:
    return CloudEvent(type="repro.ingest.record", source=source_id,
                      data={"record": list(record)})


class SharedIngest:
    """One source's single physical reader plus its materialized topic."""

    def __init__(self, bus: EventBus, store: ObjectStore, prefix: str, *,
                 source_id: str | None = None,
                 batch_records: int = 1024) -> None:
        self.bus = bus
        self.prefix = prefix
        self.source_id = source_id or prefix.strip("/")
        self.source = StreamSource(store=store, prefix=prefix,
                                   batch_records=batch_records)
        self.topic = ingest_topic(self.source_id)
        bus.create_topic(self.topic, n_partitions=1)
        self.pumped = 0          # records materialized so far
        self.pumps = 0           # physical tail reads taken
        self.subscribers: dict[str, "SubscriberSource"] = {}

    # -- the one physical read ----------------------------------------------
    def pump(self) -> int:
        """Materialize the log's unread tail onto the topic — the only
        place the physical log is ever read, however many jobs subscribe.
        Returns new records appended."""
        n = 0
        for rec in self.source.events_from(self.pumped):
            self.bus.produce(self.topic, _record_event(self.source_id, rec))
            n += 1
        self.pumped += n
        self.pumps += 1
        return n

    # -- subscriber fan-out --------------------------------------------------
    def subscribe(self, subscriber_id: str,
                  batch_records: int = 1024) -> "SubscriberSource":
        """A private replay cursor over the materialized stream.  Always
        starts at offset 0 — a late registrant catches up from the log's
        beginning; an already-checkpointed job resumes further in because
        the *coordinator* passes its record offset to ``batches()``."""
        if subscriber_id in self.subscribers:
            raise ValueError(f"subscriber {subscriber_id!r} already "
                             f"registered on {self.topic}")
        sub = SubscriberSource(self, subscriber_id,
                               batch_records=batch_records)
        self.subscribers[subscriber_id] = sub
        return sub

    def end_offset(self) -> int:
        return self.bus.end_offset(self.topic)

    def records_from(self, offset: int) -> Iterator[tuple]:
        for rec in self.bus.fetch(self.topic, 0, offset):
            ts, key, value = rec.value.data["record"]
            yield (ts, key, value)

    def lag(self, cursor: int) -> int:
        """Materialized records a subscriber at ``cursor`` has not yet
        consumed — the unpark signal."""
        return max(0, self.end_offset() - cursor)


class SubscriberSource(StreamSource):
    """One job's view of a shared ingest: a ``StreamSource`` whose log is
    the materialized topic, read from a private record cursor.

    Subclassing matters — the run-time dispatch (``BuiltPipeline.run``'s
    mode inference) and the coordinator's record-addressed ``batches(
    start_record=...)`` contract both see exactly the source type they
    already handle, so a job cannot tell whether it owns its log or
    shares it.
    """

    def __init__(self, ingest: SharedIngest, subscriber_id: str, *,
                 batch_records: int = 1024) -> None:
        # deliberately not calling super().__init__: the log lives on the
        # shared topic, not in a store prefix or an in-memory record list
        if batch_records < 1:
            raise ValueError("batch_records must be >= 1")
        self.ingest = ingest
        self.subscriber_id = subscriber_id
        self.batch_records = batch_records
        self.store = None
        self.prefix = ingest.prefix
        self._records = None

    def _events_from(self, skip: int) -> Iterator[tuple]:
        return self.ingest.records_from(skip)

    def batch_sizes(self, start_record: int = 0) -> list[int]:
        total = max(0, self.ingest.end_offset() - start_record)
        sizes = []
        while total > 0:
            sizes.append(min(total, self.batch_records))
            total -= sizes[-1]
        return sizes
