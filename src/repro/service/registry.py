"""Job registry — the control plane's metadata records.

The paper keeps all workflow state in Redis so that workers (and the
coordinator itself) stay stateless; the job server does the same with
one hash per job (``job_record_key``) plus an index of all job ids
(``job_index_key``).  A monitoring process holding only the
``MetadataStore`` — the :class:`~repro.core.client.JobServiceClient` —
reads exactly what the server wrote; nothing about a job's lifecycle
lives solely in server memory, which is what makes crash re-attach
(``resume=True``) possible.

Registration is also where the *cross-job* sink-prefix collision check
runs (the build-time check only sees one program): every job's
tenant-qualified output prefixes are claimed in the record, and a new
job whose prefixes overlap any claim on the same shared store is
rejected with ``PipelineError`` before it can write a byte.
"""

from __future__ import annotations

import time
from typing import Any

from ..core.metadata import MetadataStore, job_index_key, job_record_key
from ..pipeline.lower import assert_no_prefix_collision

__all__ = ["JobRegistry"]


class JobRegistry:
    """Metadata-backed job records + the cross-job prefix claim table."""

    def __init__(self, meta: MetadataStore) -> None:
        self.meta = meta

    def jobs(self) -> list[str]:
        return list(self.meta.get(job_index_key(), []))

    def record(self, job_id: str) -> dict[str, Any]:
        rec = self.meta.hgetall(job_record_key(job_id))
        if not rec:
            raise KeyError(f"unknown job: {job_id}")
        return rec

    def claimed_prefixes(self) -> dict[str, str]:
        """Normalized store-absolute prefix → owning job id, across every
        registered job.  Cancelled and done jobs keep their claims —
        their objects persist in the store, so a new job nesting under
        them would still scoop up foreign windows."""
        claimed: dict[str, str] = {}
        for jid in self.jobs():
            for pfx in self.meta.hget(job_record_key(jid), "prefixes", []):
                claimed[pfx] = jid
        return claimed

    def register(self, job_id: str, tenant: str,
                 prefixes: "tuple[str, ...] | list[str]", *,
                 resume: bool = False) -> bool:
        """Claim a job id and its tenant-qualified sink prefixes.

        Job ids are globally unique (they key the coordinator's shared
        metadata schema — ``job:<id>:...`` — which tenancy does not
        namespace), and prefixes must not overlap any existing claim.
        With ``resume=True`` an existing record is re-attached instead of
        rejected, provided the tenant matches — the crash-recovery path.
        Returns True if a fresh record was created, False on re-attach.
        """
        ids = self.jobs()
        normed = [p.rstrip("/") + "/" for p in prefixes]
        if job_id in ids:
            rec = self.record(job_id)
            if resume and rec.get("tenant") == tenant:
                return False
            raise ValueError(
                f"job id {job_id!r} already registered"
                + (f" to tenant {rec.get('tenant')!r}" if resume else
                   " (rebuild with a distinct job_id=, or pass "
                   "resume=True to re-attach after a crash)"))
        assert_no_prefix_collision(normed, self.claimed_prefixes())
        self.meta.set(job_index_key(), sorted(ids + [job_id]))
        key = job_record_key(job_id)
        self.meta.hset(key, "tenant", tenant)
        self.meta.hset(key, "prefixes", normed)
        self.meta.hset(key, "state", "PENDING")
        self.meta.hset(key, "submitted", time.time())
        self.meta.hset(key, "parks", 0)
        self.meta.hset(key, "restores", 0)
        self.meta.hset(key, "cold_start_seconds", 0.0)
        return True

    def update(self, job_id: str, **fields: Any) -> None:
        key = job_record_key(job_id)
        for name, value in fields.items():
            self.meta.hset(key, name, value)

    def bump(self, job_id: str, field: str, amount: float = 1) -> None:
        key = job_record_key(job_id)
        self.meta.hset(key, field,
                       self.meta.hget(key, field, 0) + amount)

    def state(self, job_id: str) -> str:
        return self.record(job_id)["state"]
