"""Tenants — namespaced, quota-bounded slices of one shared bucket.

Multi-tenancy in the paper's deployment is S3 prefix conventions plus
IAM policy: every team writes under its own prefix and a bucket quota
bounds its footprint.  Here a :class:`Tenant` is exactly that, made
mechanical: ``store_view`` wraps the shared :class:`~repro.core.storage.
ObjectStore` in a :class:`~repro.core.storage.NamespacedStore`, so every
key a tenant's jobs write — sink windows, carry checkpoints, spills —
lands under ``tenants/<name>/`` and counts against the tenant's byte
quota.  Two tenants running the *same* program (same job id, same sink
prefix) therefore never collide in the store, and a runaway job fails
with :class:`~repro.core.storage.QuotaExceeded` instead of filling the
bucket.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.storage import NamespacedStore, ObjectStore

__all__ = ["ComputeQuotaExceeded", "Tenant"]


class ComputeQuotaExceeded(RuntimeError):
    """A tenant's jobs have spent more pool-time than the tenant's
    ``quota_pool_seconds`` allows — the compute-side twin of storage's
    :class:`~repro.core.storage.QuotaExceeded`.  Raised by the job
    server's drive loop (metered per job via ``ComputeMeter``), failing
    only the offending tenant's job, never its neighbors."""


@dataclass(frozen=True)
class Tenant:
    """One tenant: a namespace under the shared bucket, an optional byte
    quota for everything its jobs persist there, and an optional
    pool-time quota (seconds of shared-pool compute across all the
    tenant's jobs — the paper bills invocations, so compute is metered
    like storage)."""

    name: str
    quota_bytes: int | None = None
    quota_pool_seconds: float | None = None

    def __post_init__(self) -> None:
        if not self.name or "/" in self.name:
            raise ValueError(f"tenant name must be non-empty and "
                             f"slash-free, got {self.name!r}")
        if self.quota_pool_seconds is not None and self.quota_pool_seconds < 0:
            raise ValueError("quota_pool_seconds must be >= 0")

    @property
    def namespace(self) -> str:
        return f"tenants/{self.name}"

    def store_view(self, shared: ObjectStore) -> NamespacedStore:
        """This tenant's view of the shared bucket — every job of the
        tenant runs its coordinator against this, so checkpoints and sink
        windows are isolated and quota-accounted without the engine
        knowing tenancy exists."""
        return NamespacedStore(shared, self.namespace, self.quota_bytes)

    def qualify(self, prefix: str) -> str:
        """A store-absolute key prefix for this tenant's ``prefix`` — what
        the cross-job collision check compares, since collisions only
        matter in the shared bucket's one key space."""
        return f"{self.namespace}/{prefix.lstrip('/')}"
