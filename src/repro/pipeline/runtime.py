"""Executing a built pipeline — one program, two drive modes.

Streaming mode hands the ``BuiltPipeline`` to the ``StreamingCoordinator``
(micro-batches, watermarks, checkpoints, backpressure).  Batch mode drives
the *same* compiled program once over the full input: all records fold in
a single pass and the end-of-input flush finalizes every window, rippling
carry handoffs through the stage DAG in topological order — so the
per-window output bytes are identical to the streaming run's (on every
tee'd branch), which the pipeline tests assert bit-for-bit.  A fan-out
program's batch outputs collect across all of its terminal sinks.

``JoinSource`` merges two event logs into one side-tagged record stream
(``(ts, key, value, side)``), in event-time order with a deterministic
left-before-right tie-break, so a two-input program — a join, even over
multi-stage sides — replays identically in both modes and across
restarts (the tag selects the record's ingestion stage via
``BuiltPipeline.inputs``).
"""

from __future__ import annotations

import heapq
from itertools import islice
from typing import Iterator

from ..core.metadata import MetadataStore
from ..core.storage import MemoryStore, ObjectStore
from ..streaming.source import MicroBatch, StreamSource
from .lower import BuiltPipeline, SourceSpec


class JoinSource:
    """Two event logs as one merged, side-tagged micro-batch stream."""

    def __init__(self, left: StreamSource, right: StreamSource,
                 batch_records: int) -> None:
        self.left = left
        self.right = right
        self.batch_records = batch_records

    @staticmethod
    def _tagged(src: StreamSource, side: int) -> Iterator[tuple]:
        for r in src.events():
            yield (r[0], side, r)

    def _merged(self, skip: int) -> Iterator[tuple]:
        merged = heapq.merge(self._tagged(self.left, 0),
                             self._tagged(self.right, 1),
                             key=lambda t: (t[0], t[1]))
        for _ts, side, rec in islice(merged, skip, None):
            yield (rec[0], rec[1], rec[2], side)

    def batch_sizes(self, start_record: int = 0) -> list[int]:
        total = sum(sum(src.batch_sizes()) for src in (self.left, self.right))
        total = max(0, total - start_record)
        sizes = []
        while total > 0:
            sizes.append(min(total, self.batch_records))
            total -= sizes[-1]
        return sizes

    def batches(self, start_record: int = 0) -> Iterator[MicroBatch]:
        chunk: list = []
        index = 0
        for rec in self._merged(start_record):
            chunk.append(rec)
            if len(chunk) >= self.batch_records:
                yield MicroBatch(index, chunk)
                index += 1
                chunk = []
        if chunk:
            yield MicroBatch(index, chunk)


def _side_source(spec: SourceSpec, store: ObjectStore | None,
                 batch_records: int, override=None) -> StreamSource:
    if override is not None:
        if isinstance(override, StreamSource):
            return override
        return StreamSource.from_records(override,
                                         batch_records=batch_records)
    if spec.kind == "log":
        if store is None:
            raise ValueError("a log-backed pipeline needs a store")
        return StreamSource(store=store, prefix=spec.prefix,
                            batch_records=batch_records)
    if spec.kind == "records":
        return StreamSource.from_records(spec.records,
                                         batch_records=batch_records)
    raise ValueError("this pipeline's source is unbound — pass source= "
                     "(or sources= for a join) at run time")


def resolve_source(built: BuiltPipeline, store: ObjectStore | None,
                   source=None, sources=None):
    """The graph's sources (or run-time overrides) as one drivable
    micro-batch stream.  A two-input program (a join, whether its sides
    are single- or multi-stage chains) merges both logs into one
    side-tagged stream whose tag selects the record's ingestion point
    (``BuiltPipeline.inputs``)."""
    specs = [built.stages[si].sides[side].source
             for si, side in built.inputs]
    if len(specs) == 2:
        overrides = sources or (None, None)
        left = _side_source(specs[0], store, built.batch_records,
                            overrides[0])
        right = _side_source(specs[1], store, built.batch_records,
                             overrides[1])
        return JoinSource(left, right, built.batch_records)
    return _side_source(specs[0], store, built.batch_records, source)


def run_streaming(built: BuiltPipeline, store, meta, *, source=None,
                  sources=None, bus=None, autoscaler=None,
                  announce: bool = True, flush: bool = True):
    """Continuous mode: micro-batches through the StreamingCoordinator."""
    from ..streaming.coordinator import StreamingCoordinator
    coord = StreamingCoordinator(store, meta, bus=bus, autoscaler=autoscaler,
                                 program=built)
    src = resolve_source(built, store, source, sources)
    return coord.run_stream(src, announce=announce, flush=flush)


def run_batch(built: BuiltPipeline, store=None, *, data=None, source=None,
              sources=None):
    """One-shot mode over the full input.

    Array pipelines run the compiled batch plan over ``data`` (or the
    graph's bound shards) and return its ``(result, stats)``.  Windowed
    pipelines fold every record in one pass through the same compiled
    program streaming mode drives — checkpointing disabled, end-of-input
    flush on — and return ``(outputs, report)`` where ``outputs`` maps
    each window's object-store key to its emitted bytes.
    """
    if built.is_array:
        shards = data if data is not None else built.sides[0].source.shards
        if shards is None:
            raise ValueError("array pipelines need data= (device shards)")
        return built.batch_plan.run(shards)

    from ..streaming.coordinator import StreamingCoordinator
    store = store if store is not None else MemoryStore()
    src = resolve_source(built, store, source, sources)
    prog = built.one_shot(sum(src.batch_sizes()))
    src = resolve_source(prog, store, source, sources)
    coord = StreamingCoordinator(store, MetadataStore(), program=prog)
    report = coord.run_stream(src, announce=False, flush=True)
    return built.collect_outputs(store), report
