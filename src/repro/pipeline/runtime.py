"""Executing a built pipeline — one program, one front door, two modes.

``run(built, source_or_data, options=RunOptions(...))`` — surfaced as
``BuiltPipeline.run`` — is the single public entry point.  It dispatches
by source kind: a ``StreamSource``/``JoinSource`` (or a pair of them)
drives **streaming** mode through the ``StreamingCoordinator``
(micro-batches, watermarks, checkpoints, backpressure, the pipelined
scheduler's prepare/fold/drain lanes); an in-memory record list (or the
graph's bound ``records=``) drives **batch** mode — the *same* compiled
program once over the full input, where the end-of-input flush finalizes
every window and carry handoffs ripple through the stage DAG in
topological order, so per-window output bytes are identical to the
streaming run's (on every tee'd branch), which the pipeline tests assert
bit-for-bit.  ``None`` falls back to the graph's bound source: a log
prefix streams, bound records run as one batch.  ``run_streaming`` and
``run_batch`` remain as thin delegates that pin the mode explicitly.

``RunOptions`` (re-exported here from the coordinator) carries the
scheduler knobs — overlap, prefetch depth, sink batching, carry donation,
checkpoint spacing, and key-space sharding — so no drive path grows an
ad-hoc kwarg list.

``JoinSource`` merges two event logs into one side-tagged record stream
(``(ts, key, value, side)``), in event-time order with a deterministic
left-before-right tie-break, so a two-input program — a join, even over
multi-stage sides — replays identically in both modes and across
restarts (the tag selects the record's ingestion stage via
``BuiltPipeline.inputs``).
"""

from __future__ import annotations

import dataclasses
import heapq
from itertools import islice
from typing import Iterator

from ..core.metadata import MetadataStore
from ..core.storage import MemoryStore, ObjectStore
from ..engine.stages import fold_key24
from ..streaming.coordinator import RunOptions
from ..streaming.source import MicroBatch, StreamSource
from .lower import BuiltPipeline, SourceSpec


class JoinSource:
    """Two event logs as one merged, side-tagged micro-batch stream."""

    def __init__(self, left: StreamSource, right: StreamSource,
                 batch_records: int) -> None:
        self.left = left
        self.right = right
        self.batch_records = batch_records

    @staticmethod
    def _tagged(src: StreamSource, side: int) -> Iterator[tuple]:
        for r in src.events():
            yield (r[0], side, r)

    def _merged(self, skip: int) -> Iterator[tuple]:
        merged = heapq.merge(self._tagged(self.left, 0),
                             self._tagged(self.right, 1),
                             key=lambda t: (t[0], t[1]))
        for _ts, side, rec in islice(merged, skip, None):
            yield (rec[0], rec[1], rec[2], side)

    def batch_sizes(self, start_record: int = 0) -> list[int]:
        total = sum(sum(src.batch_sizes()) for src in (self.left, self.right))
        total = max(0, total - start_record)
        sizes = []
        while total > 0:
            sizes.append(min(total, self.batch_records))
            total -= sizes[-1]
        return sizes

    def batches(self, start_record: int = 0) -> Iterator[MicroBatch]:
        chunk: list = []
        index = 0
        for rec in self._merged(start_record):
            chunk.append(rec)
            if len(chunk) >= self.batch_records:
                yield MicroBatch(index, chunk)
                index += 1
                chunk = []
        if chunk:
            yield MicroBatch(index, chunk)


def _side_source(spec: SourceSpec, store: ObjectStore | None,
                 batch_records: int, override=None) -> StreamSource:
    if override is not None:
        if isinstance(override, StreamSource):
            return override
        return StreamSource.from_records(override,
                                         batch_records=batch_records)
    if spec.kind == "log":
        if store is None:
            raise ValueError("a log-backed pipeline needs a store")
        return StreamSource(store=store, prefix=spec.prefix,
                            batch_records=batch_records)
    if spec.kind == "records":
        return StreamSource.from_records(spec.records,
                                         batch_records=batch_records)
    raise ValueError("this pipeline's source is unbound — pass source= "
                     "(or sources= for a join) at run time")


def resolve_source(built: BuiltPipeline, store: ObjectStore | None,
                   source=None, sources=None):
    """The graph's sources (or run-time overrides) as one drivable
    micro-batch stream.  A two-input program (a join, whether its sides
    are single- or multi-stage chains) merges both logs into one
    side-tagged stream whose tag selects the record's ingestion point
    (``BuiltPipeline.inputs``)."""
    specs = [built.stages[si].sides[side].source
             for si, side in built.inputs]
    if len(specs) == 2:
        overrides = sources or (None, None)
        left = _side_source(specs[0], store, built.batch_records,
                            overrides[0])
        right = _side_source(specs[1], store, built.batch_records,
                             overrides[1])
        return JoinSource(left, right, built.batch_records)
    return _side_source(specs[0], store, built.batch_records, source)


def _resolve(built: BuiltPipeline, store, source, sources):
    """``resolve_source`` plus the one case it cannot express: an
    already-merged ``JoinSource`` passed as the single drivable source."""
    if isinstance(source, JoinSource):
        return source
    return resolve_source(built, store, source, sources)


def _infer_mode(built: BuiltPipeline, source, sources) -> str:
    """Dispatch by source kind: live streams stream, in-memory records run
    as one batch, and ``None`` falls back to what the graph bound (a log
    prefix is an unbounded stream; bound records are a dataset)."""
    if isinstance(source, (StreamSource, JoinSource)):
        return "streaming"
    if sources is not None:
        return ("streaming"
                if any(isinstance(s, StreamSource)
                       for s in sources if s is not None) else "batch")
    if source is not None:
        return "batch"
    specs = [built.stages[si].sides[side].source
             for si, side in built.inputs]
    return ("streaming" if any(sp.kind == "log" for sp in specs)
            else "batch")


def _shard_source(built: BuiltPipeline, store, source, sources,
                  shard: tuple[int, int]):
    """Restrict the run to one partition of the key space.

    Partitioning hashes each record's key through ``fold_key24`` — the
    same stable fold the engine uses for device bucketing — so every
    shard of a job agrees on the assignment and the union of all shards'
    outputs equals the unsharded run's.  Each shard writes under a
    suffixed job id so sibling shards never collide in the store or the
    metadata table.
    """
    index, count = shard
    if len(built.inputs) != 1:
        raise ValueError("shard= currently drives single-input pipelines; "
                         "shard a join by sharding its upstream logs")
    src = _resolve(built, store, source, sources)
    recs = [r for r in src.events()
            if fold_key24(r[1]) % count == index]
    sharded = StreamSource.from_records(recs,
                                        batch_records=built.batch_records)
    built = dataclasses.replace(
        built, job_id=f"{built.job_id}-shard{index}of{count}")
    return built, sharded


def run(built: BuiltPipeline, source_or_data=None, *,
        options: RunOptions | None = None, store=None, meta=None,
        sources=None, bus=None, autoscaler=None, pool=None,
        announce: bool = True, flush: bool = True, mode: str | None = None):
    """The one front door for driving a built pipeline.

    ``source_or_data`` picks the mode: a ``StreamSource``/``JoinSource``
    (or a ``(left, right)`` pair with a live side) streams; a list of
    records — or an array pipeline's device shards — runs as one batch;
    ``None`` falls back to the graph's bound source (log prefix →
    streaming, bound records → batch).  ``mode="streaming"|"batch"``
    forces the choice (what the ``run_streaming``/``run_batch`` delegates
    do).  ``options`` is the scheduler's knob block — see ``RunOptions``
    for the lane each knob drives.  ``pool=`` injects a shared
    ``ServerlessPool`` so many programs (the job server's tenants) fold
    on one physical worker pool instead of each owning a private one.

    Returns a ``StreamReport`` in streaming mode, ``(outputs, report)``
    for a windowed batch run, and ``(result, stats)`` for an array
    pipeline.
    """
    opts = options if options is not None else RunOptions()
    opts.validate()
    if mode not in (None, "streaming", "batch"):
        raise ValueError(f"mode must be 'streaming' or 'batch', got {mode!r}")

    if built.is_array:
        if mode == "streaming":
            raise ValueError("array pipelines have no streaming mode")
        if opts.shard is not None:
            raise ValueError("shard= partitions a keyed record stream; "
                             "array pipelines shard via their input shards")
        shards = (source_or_data if source_or_data is not None
                  else built.sides[0].source.shards)
        if shards is None:
            raise ValueError("array pipelines need data (device shards)")
        return built.batch_plan.run(shards)

    # One positional accepts a join's (left, right) pair too.
    source = None
    if source_or_data is not None:
        if (len(built.inputs) == 2 and sources is None
                and isinstance(source_or_data, (tuple, list))
                and len(source_or_data) == 2
                and all(isinstance(s, (StreamSource, list))
                        for s in source_or_data)):
            sources = tuple(source_or_data)
        else:
            source = source_or_data

    if mode is None:
        mode = _infer_mode(built, source, sources)

    if opts.shard is not None:
        built, source = _shard_source(built, store, source, sources,
                                      opts.shard)
        sources = None

    from ..streaming.coordinator import StreamingCoordinator

    if mode == "streaming":
        store = store if store is not None else MemoryStore()
        meta = meta if meta is not None else MetadataStore()
        coord = StreamingCoordinator(store, meta, bus=bus,
                                     autoscaler=autoscaler, pool=pool,
                                     program=built, options=opts)
        src = _resolve(built, store, source, sources)
        return coord.run_stream(src, announce=announce, flush=flush)

    # Batch: the same compiled program, one pass, end-of-input flush.
    # Checkpoint spacing is a streaming knob — a one-shot drive has no
    # mid-run offsets worth persisting, so the override is dropped here.
    opts = dataclasses.replace(opts, checkpoint_interval=None)
    store = store if store is not None else MemoryStore()
    src = _resolve(built, store, source, sources)
    prog = built.one_shot(sum(src.batch_sizes()))
    src = _resolve(prog, store, source, sources)
    coord = StreamingCoordinator(store, MetadataStore(), program=prog,
                                 options=opts)
    report = coord.run_stream(src, announce=False, flush=True)
    return built.collect_outputs(store), report


def run_streaming(built: BuiltPipeline, store, meta, *, source=None,
                  sources=None, bus=None, autoscaler=None, pool=None,
                  announce: bool = True, flush: bool = True,
                  options: RunOptions | None = None):
    """Continuous mode, pinned: a thin delegate through :func:`run` with
    ``mode="streaming"`` (so a records-bound graph still streams)."""
    return run(built, source, store=store, meta=meta, sources=sources,
               bus=bus, autoscaler=autoscaler, pool=pool, announce=announce,
               flush=flush, options=options, mode="streaming")


def run_batch(built: BuiltPipeline, store=None, *, data=None, source=None,
              sources=None, options: RunOptions | None = None):
    """One-shot mode, pinned: a thin delegate through :func:`run` with
    ``mode="batch"``.

    Array pipelines run the compiled batch plan over ``data`` (or the
    graph's bound shards) and return its ``(result, stats)``.  Windowed
    pipelines fold every record in one pass through the same compiled
    program streaming mode drives — checkpointing disabled, end-of-input
    flush on — and return ``(outputs, report)`` where ``outputs`` maps
    each window's object-store key to its emitted bytes.
    """
    if built.is_array:
        return run(built, data, options=options, mode="batch")
    return run(built, source, store=store, sources=sources,
               options=options, mode="batch")
