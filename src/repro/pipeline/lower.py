"""Graph validation and lowering — pipeline nodes → execution plans.

``build_pipeline`` walks a ``Pipeline`` graph, validates the stage grammar
(one source; maps fuse; ``window`` before ``reduce``; ``top_k`` only over
aggregate reduces; joins windowed and reduced on both sides), and lowers
each stage chain onto ``repro.engine``:

* record chains → one ``ExecutionPlan`` per side, compiled once; adjacent
  ``map`` nodes fuse into a single host transform (one stage, not N);
* a chain that continues *past* a reduce — ``…reduce(...).map(...)
  .key_by(...).window(...).reduce(...)`` — splits at each reduce boundary
  into a **sequence of stages**, each with its own plan and carry; a
  finalized window of stage N becomes stage N+1's input batch through a
  carry *handoff* (``engine.stages.carry_handoff_rows`` — on-device when
  the boundary has no host transform, the host record path otherwise);
* ``tee(branch, …)`` → a stage **DAG**: the teed stage keeps one carry but
  gains several out-*edges* (``BuiltPipeline.edges``), one per branch; each
  edge picks its own transport (device for identity boundaries, host
  records otherwise) and, at run time, its own bucket → next-key relabel
  table — one finalized window fans out to every successor's carry;
  conversely a join's two inputs may be multi-stage chains, so a stage may
  also have two in-edges (one per join side).  Stages are emitted in
  topological order (every edge points forward) and every terminal stage
  of a fan-out carries its own distinct sink prefix;
* stage-local ``reduce(..., num_buckets=, n_slots=)`` options override the
  build-wide defaults per ``StagePlan`` — each stage's carry width and
  ring depth are resolved (and validated) independently at lower time;
* a windowed join → **two plans sharing one carry**: each side's plan folds
  its ``[value, 1]`` pair into a disjoint channel pair
  (``ReduceSpec.channel_base``) of the same scattered aggregate carry;
  per-side key-space sizes (``num_buckets=(left, right)``) widen the
  shared carry to the larger side (``ReduceSpec.carry_buckets``) while
  each side buckets within its own declared space;
* ``Windowing.session(gap)`` → the engine's ``WindowSpec.session`` variant
  (host-wire fold, cell-addressed carry);
* ``top_k(k)`` → ``ReduceSpec(mode="top_k")`` — the aggregate fold plus the
  fixed-capacity heavy-hitters selection at finalization;
* array chains → one batch ``ExecutionPlan`` (no window), the lowering
  ``core.mapreduce`` rides on.

The result is a ``BuiltPipeline`` — the compiled program the
``StreamingCoordinator`` drives (streaming mode) and the batch runner
drives once over a store prefix (batch mode), with bit-identical
per-window output bytes.
"""

from __future__ import annotations

import dataclasses
import math
import uuid
from dataclasses import dataclass
from typing import Any, Callable

from ..engine.plan import ExecutionPlan, KeySpace, ReduceSpec, WindowSpec
from ..engine.stages import SEGMENT_REDUCE_KINDS
from ..streaming.sessions import SessionTracker
from ..streaming.state import WindowTracker
from ..streaming.windows import SlidingWindows, TumblingWindows
from .graph import Pipeline, PipelineError, Windowing

AGGREGATE_KINDS = ("count", "sum", "mean")

#: canonical stage order within one chain (source implicit at rank 0)
_STAGE_RANK = {"source": 0, "map": 1, "key_by": 2, "window": 3,
               "reduce": 4, "top_k": 5, "join": 6, "tee": 6, "sink": 7}

_ORDER_HINT = ("stage order is source → map* → key_by → window → reduce "
               "→ top_k → join/tee → sink; a chain may continue past a "
               "reduce with another map* → key_by → window → reduce stage")


def _default_key(rec) -> Any:
    return rec[1]


def _default_value(rec) -> float:
    return float(rec[2])


def fuse_maps(fns: list[Callable]) -> Callable | None:
    """Fuse adjacent record maps into one stage: apply in order, treating
    ``None`` as filter and an iterable of records as flat-map."""
    if not fns:
        return None
    if len(fns) == 1:
        return fns[0]

    def fused(rec):
        pending = [rec]
        for fn in fns:
            nxt = []
            for r in pending:
                out = fn(r)
                if out is None:
                    continue
                if isinstance(out, tuple):
                    nxt.append(out)
                else:
                    nxt.extend(out)
            pending = nxt
        return pending

    return fused


@dataclass(frozen=True)
class SourceSpec:
    """Where one side's records come from (bound at build or at run).
    ``kind="carry"`` marks a continued stage: its input is the previous
    stage's finalized windows, handed off through the carry."""

    kind: str           # "log" | "records" | "array" | "unbound" | "carry"
    prefix: str | None = None
    records: list | None = None
    shards: Any = None
    batch_records: int = 1024


@dataclass(frozen=True)
class _Chain:
    """One parsed linear stage chain (a join has two; a multi-stage
    pipeline has one per reduce boundary)."""

    source: SourceSpec
    transform: Callable | None
    key_fn: Callable
    value_fn: Callable
    windowing: Windowing | None
    reduce_spec: str | Callable
    reduce_mode: str
    capacity: int
    top: dict | None = None         # this stage's top_k node, if any
    options: dict = dataclasses.field(default_factory=dict)  # stage-local


@dataclass(frozen=True)
class SidePlan:
    """One side's lowered stage chain: the fused host transform plus the
    compiled execution plan folding into its channel pair of the carry.
    ``num_buckets`` is the side's *own* key-space width — for asymmetric
    joins it can be narrower than the shared carry."""

    name: str
    source: SourceSpec
    transform: Callable | None
    key_fn: Callable
    value_fn: Callable
    compiled: Any
    channel_base: int
    num_buckets: int = 0


@dataclass(frozen=True)
class EmitSpec:
    """How a finalized window turns into output records — the store
    emission of the final stage, or the handoff records of an
    intermediate one."""

    kind: str                       # "aggregate" | "group" | "top_k" | "join"
    aggregation: str = "count"      # aggregate / session emission kind
    reduce_fn: str | Callable = "sum"
    k: int = 0
    rank_by: str = "sum"            # top_k ranking kind
    join_aggs: tuple = ("sum", "sum")


@dataclass(frozen=True)
class StageEdge:
    """One edge of the stage DAG: finalized windows of stage ``src``
    become input batches of stage ``dst``, folding into side ``dst_side``
    of its carry (a join destination has two sides).  ``device`` picks the
    on-device handoff transport; ``eager`` marks an identity boundary
    whose destination key dictionary registers eagerly.  Each edge owns
    its own bucket → next-key relabel table at run time — a teed stage
    with several out-edges relabels independently per successor."""

    src: int
    dst: int
    dst_side: int = 0
    device: bool = False
    eager: bool = False


@dataclass(frozen=True)
class StagePlan:
    """One lowered stage of the DAG: its compiled side plan(s), window
    shape, and emission/handoff spec.  A plain pipeline has one stage; a
    windowed join has one stage with two sides; a multi-stage chain has
    one per reduce boundary; a tee'd graph has one per branch stage.
    ``BuiltPipeline.edges`` wires them together — a stage with no
    out-edges emits to the store (under ``output_prefix`` when set, the
    pipeline default otherwise)."""

    index: int
    sides: tuple[SidePlan, ...]
    window: Windowing | None        # None → array (pure batch) stage
    mode: str                       # fold machinery: "aggregate" | "group"
    emit: EmitSpec
    num_buckets: int                # carry bucket width (max over sides)
    n_slots: int
    allowed_lateness: float
    capacity: int
    handoff_device: bool = False    # every out-edge hands off on device
    #: every out-edge passes keys through unchanged (no host transform,
    #: default key_by, aggregate emission) — each successor's dense
    #: dictionary registers a key the moment this stage first sees it, so
    #: both handoff transports (and every checkpoint) agree on the id
    #: order
    eager_boundary: bool = False
    output_prefix: str | None = None    # terminal stages: this sink's prefix

    @property
    def is_session(self) -> bool:
        return self.window is not None and self.window.is_session

    @property
    def is_join(self) -> bool:
        return len(self.sides) == 2

    def assigner(self):
        """Fixed-window assigner (None for session windows)."""
        w = self.window
        if w is None or w.is_session:
            return None
        if w.kind == "tumbling":
            return TumblingWindows(w.size)
        return SlidingWindows(w.size, w.slide)

    def make_tracker(self):
        if self.window.is_session:
            return SessionTracker(self.window.gap, self.n_slots,
                                  self.allowed_lateness)
        return WindowTracker(self.assigner(), self.n_slots,
                             self.allowed_lateness)


@dataclass
class BuiltPipeline:
    """A validated, lowered pipeline — the compiled program both execution
    modes drive.  ``run_streaming`` hands it to the ``StreamingCoordinator``;
    ``run_batch`` drives the same program once over the full input.
    ``stages`` is the executable DAG in topological order: one entry for a
    plain chain or join, several for a multi-stage or tee'd graph wired by
    the carry-handoff ``edges`` (every edge points forward).  ``inputs``
    maps each external input stream to its ``(stage, side)`` ingestion
    point — one entry for a plain pipeline, two for a join (whether its
    sides are single- or multi-stage chains)."""

    stages: tuple[StagePlan, ...]
    num_buckets: int                # stage-0 carry bucket width
    n_workers: int
    n_slots: int
    batch_records: int
    key_space: str
    fanout: str
    allowed_lateness: float
    checkpoint_interval: int
    backend: str
    output_prefix: str
    job_id: str
    handoff: str = "device"
    batch_plan: Any = None          # array pipelines: CompiledBatchPlan
    edges: tuple[StageEdge, ...] = ()
    inputs: tuple[tuple[int, int], ...] = ((0, 0),)
    jit: bool = True                # donation needs a jitted fold (PL006)

    # -- stage-0 / final-stage views (the single-stage API surface) -----------
    @property
    def sides(self) -> tuple[SidePlan, ...]:
        return self.stages[0].sides

    @property
    def emit(self) -> EmitSpec:
        return self.stages[-1].emit

    @property
    def window(self) -> Windowing | None:
        return self.stages[0].window

    @property
    def mode(self) -> str:
        return self.stages[0].mode

    @property
    def capacity(self) -> int:
        return self.stages[0].capacity

    @property
    def is_array(self) -> bool:
        return self.window is None

    @property
    def is_join(self) -> bool:
        return any(st.is_join for st in self.stages)

    @property
    def is_multistage(self) -> bool:
        return len(self.stages) > 1

    @property
    def final_stages(self) -> tuple[int, ...]:
        """Stages with no out-edge — the DAG's terminal stages, each
        emitting finalized windows to its own output prefix."""
        srcs = {e.src for e in self.edges}
        return tuple(i for i in range(len(self.stages)) if i not in srcs)

    def stage_prefix(self, si: int) -> str:
        """The output prefix stage ``si`` emits under (its own sink, or
        the pipeline default)."""
        return self.stages[si].output_prefix or self.output_prefix

    def output_prefixes(self) -> tuple[str, ...]:
        """One normalized ``<sink>/<job_id>/`` key prefix per terminal
        stage — everywhere this program's windows land in the store."""
        return tuple(dict.fromkeys(
            f"{self.stage_prefix(si).rstrip('/')}/{self.job_id}/"
            for si in self.final_stages))

    def collect_outputs(self, store) -> dict:
        """Every window this program has persisted, across all of its
        terminal sinks, keyed by object key."""
        return {m.key: store.get(m.key)
                for prefix in self.output_prefixes()
                for m in store.list_objects(prefix)}

    def assigner(self):
        return self.stages[0].assigner()

    def make_tracker(self):
        return self.stages[0].make_tracker()

    def one_shot(self, total_records: int) -> "BuiltPipeline":
        """The same program re-sized to fold the whole input as one batch
        with checkpointing off — how ``run_batch`` drives it."""
        return dataclasses.replace(self, batch_records=max(total_records, 1),
                                   checkpoint_interval=0)

    # -- static analysis -------------------------------------------------------
    def check(self, options=None, *, source_prefixes=()) -> list:
        """Run planlint over the lowered program: a list of
        ``Diagnostic(rule_id, level, message, loc)`` records, empty when
        clean.  ``Pipeline.build`` warns on these automatically;
        ``JobServer.submit`` rejects error-level findings.  Pass the
        ``RunOptions`` the program will run under to enable the donation
        checks (PL006)."""
        # function-level: analysis.diagnostics imports pipeline.graph, so
        # a module-level edge back into analysis would cycle the package
        from ..analysis.planlint import check_plan
        return check_plan(self, options, source_prefixes=source_prefixes)

    def explain(self, options=None, *, source_prefixes=()) -> str:
        """Human-readable program summary — every stage's window/ring/
        bucket geometry, every edge's transport, and the full planlint
        report including advisory findings."""
        from ..analysis.planlint import explain_plan
        return explain_plan(self, options, source_prefixes=source_prefixes)

    # -- execution -------------------------------------------------------------
    def run(self, source_or_data=None, *, options=None, store=None,
            meta=None, sources=None, bus=None, autoscaler=None, pool=None,
            announce: bool = True, flush: bool = True,
            mode: str | None = None):
        """The one front door for executing the program.  Dispatches by
        source kind — a ``StreamSource``/``JoinSource`` (or a pair with a
        live side) streams through the pipelined coordinator, an
        in-memory record list (or an array pipeline's shards) runs as one
        batch, and ``None`` falls back to the graph's bound source.
        ``options=RunOptions(...)`` carries the scheduler knobs (overlap,
        prefetch depth, sink batching, carry donation, checkpoint
        spacing, key-space sharding); ``mode=`` pins the dispatch.
        Returns a ``StreamReport`` (streaming), ``(outputs, report)``
        (windowed batch) or ``(result, stats)`` (array)."""
        from .runtime import run
        return run(self, source_or_data, options=options, store=store,
                   meta=meta, sources=sources, bus=bus,
                   autoscaler=autoscaler, pool=pool, announce=announce,
                   flush=flush, mode=mode)

    def run_streaming(self, store, meta, *, source=None, sources=None,
                      bus=None, autoscaler=None, announce: bool = True,
                      flush: bool = True, options=None):
        """Streaming pinned explicitly — a thin delegate through
        :meth:`run` with ``mode="streaming"``.  Sources default to the
        graph's (``prefix=``/``records=``); joins take
        ``sources=(left, right)`` overrides.  Returns a ``StreamReport``."""
        from .runtime import run_streaming
        return run_streaming(self, store, meta, source=source,
                             sources=sources, bus=bus, autoscaler=autoscaler,
                             announce=announce, flush=flush, options=options)

    def run_batch(self, store=None, *, data=None, source=None, sources=None,
                  options=None):
        """One-shot pinned explicitly — a thin delegate through
        :meth:`run` with ``mode="batch"``: array pipelines run the batch
        plan over ``data``; windowed pipelines fold everything in one
        pass and flush — emitting bit-identical window bytes to the
        streaming mode.  Returns ``(outputs, report)`` for windowed
        pipelines (outputs keyed by object-store key) or
        ``(result, stats)`` for array pipelines."""
        from .runtime import run_batch
        return run_batch(self, store, data=data, source=source,
                         sources=sources, options=options)


def assert_no_prefix_collision(prefixes: "tuple[str, ...] | list[str]",
                               claimed: dict[str, str]) -> None:
    """Cross-job twin of the build-time distinctness check: reject a new
    job whose normalized output prefixes collide with — equal, contain, or
    fall under — a prefix another job already claimed on the *same* shared
    ObjectStore.  ``claimed`` maps normalized prefix → owning job id.
    Overlap (not just equality) is the collision condition because
    ``collect_outputs`` and resume scans are prefix listings: a job whose
    prefix nests inside another's would see — and count — its neighbor's
    windows.
    """
    for pfx in prefixes:
        p_norm = pfx.rstrip("/") + "/"
        for other, owner in claimed.items():
            if p_norm.startswith(other) or other.startswith(p_norm):
                raise PipelineError(
                    f"output prefix {p_norm!r} collides with {other!r} "
                    f"already claimed by job {owner!r} on this store — "
                    f"jobs sharing one ObjectStore need disjoint sink "
                    f"prefixes (distinct sinks, job ids, or tenant "
                    f"namespaces)")


# ---------------------------------------------------------------------------
# Parsing + validation
# ---------------------------------------------------------------------------

def _parse_chain(p: Pipeline, *, side: str, allow_join: bool,
                 allow_stages: bool = False, on: Callable | None = None,
                 allow_tee: bool = False):
    """Walk one pipeline's nodes into stage chains (split at each reduce
    boundary when ``allow_stages``); returns ``(chains, join_node,
    tee_node, sink_prefix)`` where ``chains[i].top`` carries stage i's
    top_k node and ``tee_node`` is the trailing fan-out, if any."""
    if not p.nodes or p.nodes[0].op != "source":
        raise PipelineError(f"{side}: a pipeline starts at "
                            f"Pipeline.from_source(...)")
    src = p.nodes[0].params
    source = SourceSpec(
        kind="carry" if src["kind"] == "carry-stub" else src["kind"],
        prefix=src["prefix"], records=src["records"], shards=src["shards"],
        batch_records=src["batch_records"])
    chains: list[_Chain] = []
    join_node = None
    tee_node = None
    sink_prefix = None

    def _fresh():
        return {"maps": [], "key_fn": None, "windowing": None,
                "reduce": None, "top": None}

    def _close(stage: dict) -> None:
        n = len(chains)
        if stage["reduce"] is None:
            what = "a pipeline" if n == 0 else f"stage {n + 1} of the chain"
            raise PipelineError(
                f"{side}: {what} needs a reduce node ({_ORDER_HINT})")
        chains.append(_Chain(
            source=source if n == 0 else SourceSpec(kind="carry"),
            transform=fuse_maps(stage["maps"]),
            key_fn=stage["key_fn"] or _default_key,
            value_fn=_default_value,
            windowing=stage["windowing"],
            reduce_spec=stage["reduce"]["spec"],
            reduce_mode=stage["reduce"]["mode"],
            capacity=stage["reduce"]["capacity"],
            top=stage["top"],
            options={k: stage["reduce"][k]
                     for k in ("num_buckets", "n_slots")
                     if stage["reduce"].get(k) is not None}))

    stage = _fresh()
    rank = 0
    for node in p.nodes[1:]:
        r = _STAGE_RANK.get(node.op)
        if r is None:
            raise PipelineError(f"unknown node op {node.op!r}")
        if node.op == "source":
            raise PipelineError(f"{side}: more than one source")
        if sink_prefix is not None:
            raise PipelineError(f"{side}: sink must be the last node")
        if tee_node is not None:
            raise PipelineError(f"{side}: tee is a terminal node — the "
                                f"branches carry their own sinks and "
                                f"continuations")
        if node.op == "tee" and join_node is not None:
            raise PipelineError("tee and join cannot combine in one "
                                "pipeline (tee a downstream pipeline over "
                                "the join output instead)")
        if r < rank or (r == rank and node.op not in ("map",)):
            # past this stage's reduce the chain may continue with a new
            # stage; anything else is an ordering error
            if stage["reduce"] is not None and node.op in (
                    "map", "key_by", "window", "reduce"):
                if not allow_stages:
                    raise PipelineError(
                        f"{side}: this chain ends at its reduce node")
                if join_node is not None:
                    raise PipelineError(
                        "the chain cannot continue past a join (rank the "
                        "join output in a downstream pipeline instead)")
                _close(stage)
                stage = _fresh()
                rank = 0
                r = _STAGE_RANK[node.op]
            else:
                raise PipelineError(
                    f"{side}: {node.op!r} cannot follow a "
                    f"{[k for k, v in _STAGE_RANK.items() if v == rank][0]!r}"
                    f" node — {_ORDER_HINT}")
        rank = r
        if node.op == "map":
            stage["maps"].append(node.params["fn"])
        elif node.op == "key_by":
            stage["key_fn"] = node.params["fn"]
        elif node.op == "window":
            stage["windowing"] = node.params["windowing"]
        elif node.op == "reduce":
            stage["reduce"] = node.params
        elif node.op == "top_k":
            stage["top"] = node.params
        elif node.op == "join":
            if not allow_join:
                raise PipelineError(f"{side}: nested joins are not "
                                    f"supported")
            join_node = node
        elif node.op == "tee":
            if not allow_tee:
                raise PipelineError(f"{side}: tee is not allowed here")
            if stage["reduce"] is None:
                raise PipelineError(f"{side}: tee fans out a *reduced* "
                                    f"stage ({_ORDER_HINT})")
            tee_node = node
        elif node.op == "sink":
            sink_prefix = node.params["prefix"]
    if stage["top"] is not None and join_node is not None:
        raise PipelineError("top_k and join cannot combine (rank the join "
                            "output downstream instead)")
    _close(stage)
    if on is not None:
        chains[-1] = dataclasses.replace(chains[-1], key_fn=on)
    return chains, (join_node if allow_join else None), tee_node, sink_prefix


def _check_windowing(w: Windowing, n_slots: int, lateness: float) -> None:
    if w.kind == "tumbling":
        if w.size <= 0:
            raise PipelineError("tumbling windows need a positive size")
    elif w.kind == "sliding":
        if w.size <= 0 or not w.slide or w.slide <= 0:
            raise PipelineError("sliding windows need positive size and "
                                "slide")
        if w.slide > w.size:
            raise PipelineError("slide > size leaves event-time gaps")
    elif w.kind == "session":
        if w.gap <= 0:
            raise PipelineError("session windows need a positive gap")
        return
    else:
        raise PipelineError(f"unknown windowing kind {w.kind!r}")
    # the ring must hold every window open at one instant — the same
    # bound planlint's PL001 reports and WindowTracker enforces at
    # construction, derived once in analysis.planlint
    from ..analysis.planlint import min_slots_required
    need = min_slots_required(w.size, w.slide, lateness)
    if need > n_slots:
        step = w.slide or w.size
        raise PipelineError(
            f"n_slots={n_slots} cannot hold the window span; need >= "
            f"{need} for size={w.size}, slide={step}, lateness={lateness}")


def _check_reduce(chain: _Chain, *, in_join: bool) -> None:
    spec, mode = chain.reduce_spec, chain.reduce_mode
    if mode == "aggregate":
        if not isinstance(spec, str) or spec not in AGGREGATE_KINDS:
            raise PipelineError(f"aggregate reduce must be one of "
                                f"{AGGREGATE_KINDS}, got {spec!r}")
    elif mode == "group":
        if in_join:
            raise PipelineError("join sides must reduce in aggregate mode")
        if chain.capacity < 1:
            raise PipelineError("group mode needs capacity >= 1")
        if isinstance(spec, str) and spec not in SEGMENT_REDUCE_KINDS:
            raise PipelineError(f"group reduce kind must be a callable or "
                                f"one of {SEGMENT_REDUCE_KINDS}")
    else:
        raise PipelineError(f"unknown reduce mode {mode!r}")


def _check_channels_disjoint(sides: "tuple[tuple[int, int], ...]",
                             channels: int) -> None:
    """Plans sharing one carry must claim non-overlapping [base, base+2)
    channel pairs inside the carry's channel count."""
    claimed: set[int] = set()
    for base, width in sides:
        span = set(range(base, base + width))
        if base < 0 or base + width > channels:
            raise PipelineError(
                f"channel window [{base}, {base + width}) exceeds the "
                f"carry's {channels} channels")
        if claimed & span:
            raise PipelineError(
                f"channel window [{base}, {base + width}) overlaps another "
                f"side's channels — plans sharing a carry must stay "
                f"disjoint")
        claimed |= span


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def _key_space_obj(key_space, num_buckets: int,
                   track_collisions: bool) -> KeySpace:
    """Normalize the build option: a ``KeySpace`` instance passes through
    verbatim (callers control collision tracking); a kind string builds
    one."""
    if isinstance(key_space, KeySpace):
        return key_space
    if key_space == "hashed":
        return KeySpace.hashed(num_buckets,
                               track_collisions=track_collisions)
    return KeySpace.dense(num_buckets)


def _lower_side(chain: _Chain, name: str, *, num_buckets: int,
                n_workers: int, n_slots: int, key_space, fanout: str,
                backend: str, mesh, jit: bool, combine_fn,
                axis_name: str, channels: int, channel_base: int,
                carry_buckets: int = 0, top_k: int = 0,
                rank_by: str = "sum") -> SidePlan:
    # streaming sides default collision tracking off: the coordinator's
    # host-side label table already reports collisions exactly
    ks = _key_space_obj(key_space, num_buckets, track_collisions=False)
    w = chain.windowing
    if w.is_session:
        window = WindowSpec.session(w.gap, n_slots=n_slots)
    else:
        window = WindowSpec(size=w.size, slide=w.slide, n_slots=n_slots,
                            fanout_on_device=fanout == "device")
    carry = 0 if carry_buckets == ks.num_buckets else carry_buckets
    if chain.reduce_mode == "group":
        reduce = ReduceSpec("group", reduce_fn=chain.reduce_spec,
                            capacity=chain.capacity)
    elif top_k:
        reduce = ReduceSpec(mode="top_k", reduce_fn=rank_by, k=top_k,
                            combine_fn=combine_fn, channels=channels,
                            channel_base=channel_base, carry_buckets=carry)
    else:
        reduce = ReduceSpec("aggregate", combine_fn=combine_fn,
                            channels=channels, channel_base=channel_base,
                            carry_buckets=carry)
    plan = ExecutionPlan(key_space=ks, reduce=reduce, n_workers=n_workers,
                         window=window, axis_name=axis_name)
    compiled = plan.compile(backend=backend, mesh=mesh, jit=jit)
    return SidePlan(name=name, source=chain.source,
                    transform=chain.transform, key_fn=chain.key_fn,
                    value_fn=chain.value_fn, compiled=compiled,
                    channel_base=channel_base,
                    num_buckets=ks.num_buckets)


def _lower_array(chain: _Chain, top_node, *, num_buckets: int, n_workers: int,
                 key_space, backend: str, mesh, data_spec, finalize: bool,
                 jit: bool, combine_fn, axis_name: str) -> tuple[Any, EmitSpec]:
    if chain.transform is None:
        raise PipelineError("array pipelines need exactly one map node "
                            "(the device UDF)")
    ks = _key_space_obj(key_space, num_buckets, track_collisions=True)
    if top_node is not None:
        k = top_node["k"]
        reduce = ReduceSpec(mode="top_k", reduce_fn=top_node["by"] or "sum",
                            k=k, combine_fn=combine_fn)
        emit = EmitSpec("top_k", k=k, rank_by=top_node["by"] or "sum")
    elif chain.reduce_mode == "group":
        reduce = ReduceSpec("group", reduce_fn=chain.reduce_spec,
                            capacity=chain.capacity)
        emit = EmitSpec("group", reduce_fn=chain.reduce_spec)
    else:
        reduce = ReduceSpec("aggregate", combine_fn=combine_fn)
        emit = EmitSpec("aggregate", aggregation=chain.reduce_spec)
    plan = ExecutionPlan(key_space=ks, reduce=reduce, n_workers=n_workers,
                         axis_name=axis_name)
    compiled = plan.compile(chain.transform, backend=backend, mesh=mesh,
                            data_spec=data_spec, finalize=finalize, jit=jit)
    return compiled, emit


def _stage_emit(chain: _Chain, num_buckets: int) -> tuple[EmitSpec, int, str]:
    """One record stage's emission spec + validated top-k parameters."""
    top_k, rank_by = 0, "sum"
    if chain.top is not None:
        if chain.reduce_mode != "aggregate":
            raise PipelineError("top_k ranks an aggregate reduce")
        if chain.top["k"] > num_buckets:
            raise PipelineError("top_k k exceeds the bucket space")
        top_k = chain.top["k"]
        rank_by = chain.top["by"] or chain.reduce_spec
        if rank_by not in AGGREGATE_KINDS:
            raise PipelineError(f"top_k ranks by one of {AGGREGATE_KINDS}")
        emit = EmitSpec("top_k", aggregation=chain.reduce_spec,
                        k=top_k, rank_by=rank_by)
    elif chain.reduce_mode == "group":
        emit = EmitSpec("group", reduce_fn=chain.reduce_spec)
    else:
        emit = EmitSpec("aggregate", aggregation=chain.reduce_spec)
    return emit, top_k, rank_by


def _check_record_stage(chain: _Chain, *, name: str, n_slots: int,
                        lateness: float, fanout: str, num_buckets: int,
                        n_workers: int) -> None:
    """The per-stage validation shared by every record stage of the DAG —
    run with the stage's *resolved* (possibly stage-local) options."""
    where = f"{name}: " if name else ""
    if chain.windowing is None:
        raise PipelineError(where + "record pipelines need a window node "
                            "before reduce (use Windowing.tumbling(...) "
                            "with a large size for a single global window)")
    _check_windowing(chain.windowing, n_slots, lateness)
    _check_reduce(chain, in_join=False)
    if chain.windowing.is_session:
        if chain.reduce_mode != "aggregate":
            raise PipelineError("session windows reduce in aggregate mode "
                                "only")
        if chain.top is not None:
            raise PipelineError("top_k over session windows is meaningless "
                                "(a session holds one key)")
    if chain.reduce_mode == "group" and fanout != "device":
        raise PipelineError(where + "group mode runs with fanout='device'")
    if chain.reduce_mode == "aggregate" and num_buckets % n_workers != 0:
        raise PipelineError(where + "num_buckets must divide by n_workers "
                            "so window slices stay aligned to the "
                            "scattered carry")


def _stage_options(chain: _Chain, *, name: str, num_buckets: int,
                   n_slots: int) -> tuple[int, int]:
    """Resolve one stage's carry sizing: stage-local ``reduce(...,
    num_buckets=, n_slots=)`` overrides win over the build-wide defaults;
    both are validated here, per stage."""
    nb = chain.options.get("num_buckets", num_buckets)
    ns = chain.options.get("n_slots", n_slots)
    where = f"{name}: " if name else ""
    if nb < 1:
        raise PipelineError(where + "num_buckets must be >= 1")
    if ns < 2:
        raise PipelineError(where + "need >= 2 window slots (one closing, "
                            "one open)")
    return int(nb), int(ns)


def _identity_boundary(src: _Chain, src_emit: EmitSpec, dst: _Chain) -> bool:
    """True when the src → dst boundary passes every emitted key through
    unchanged: an aggregate source stage with fixed windows feeding a
    destination with no host transform and the default key.  On such a
    boundary the destination's dictionary can register keys *eagerly*
    (the moment the source first sees them), which keeps the id order
    identical across handoff transports and closed in every checkpoint."""
    return (src_emit.kind == "aggregate"
            and not src.windowing.is_session
            and dst.transform is None
            and dst.key_fn is _default_key
            and not dst.windowing.is_session)


def _handoff_on_device(src: _Chain, src_emit: EmitSpec, dst: _Chain, *,
                       key_space_str: str, fanout: str,
                       handoff: str) -> bool:
    """True when the src → dst boundary can re-key/re-window finalized
    aggregates entirely on device: a dense identity boundary under the
    device fan-out wire.  Any host map/key_by between the stages falls
    back to the host record path — the same records, materialized."""
    return (handoff == "device" and fanout == "device"
            and key_space_str == "dense"
            and _identity_boundary(src, src_emit, dst))


def build_pipeline(p: Pipeline, *, num_buckets=128, n_workers: int = 8,
                   n_slots: int = 8,
                   key_space: "str | KeySpace" = "dense",
                   fanout: str = "device", allowed_lateness: float = 0.0,
                   backend: str = "vmap", checkpoint_interval: int = 1,
                   batch_records: int | None = None, job_id: str | None = None,
                   output_prefix: str | None = None, mesh=None, data_spec=None,
                   finalize: bool = True, jit: bool = True, combine_fn=None,
                   axis_name: str = "workers",
                   handoff: str = "device") -> BuiltPipeline:
    """Validate ``p`` and lower it to a runnable ``BuiltPipeline``.
    ``key_space`` is ``"dense"`` / ``"hashed"`` or a ``KeySpace`` instance
    (passed to the plans verbatim, e.g. to control collision tracking).
    ``num_buckets`` takes a ``(left, right)`` pair on a join to size the
    two key spaces independently (dense only); the shared carry widens to
    the larger side.  ``handoff`` picks the multi-stage boundary transport:
    ``"device"`` re-keys/re-windows finalized aggregates on-chip where the
    boundary allows it, ``"host"`` always materializes the records."""
    side_buckets: tuple[int, int] | None = None
    if isinstance(num_buckets, (tuple, list)):
        if len(num_buckets) != 2:
            raise PipelineError("num_buckets takes an int or a "
                                "(left, right) pair")
        side_buckets = (int(num_buckets[0]), int(num_buckets[1]))
        if min(side_buckets) < 1:
            raise PipelineError("per-side num_buckets must be >= 1")
        num_buckets = max(side_buckets)
    if isinstance(key_space, KeySpace):
        if side_buckets is not None:
            raise PipelineError("per-side num_buckets cannot combine with "
                                "a KeySpace instance")
        num_buckets = key_space.num_buckets
        key_space_str = key_space.mode
    elif key_space in ("dense", "hashed"):
        key_space_str = key_space
    else:
        raise PipelineError("key_space must be 'dense', 'hashed', or a "
                            "KeySpace")
    if fanout not in ("device", "host"):
        raise PipelineError("fanout must be 'device' or 'host'")
    if handoff not in ("device", "host"):
        raise PipelineError("handoff must be 'device' or 'host'")
    if checkpoint_interval < 1:
        raise PipelineError("checkpoint_interval must be >= 1")
    chains, join_node, tee_node, sink_prefix = _parse_chain(
        p, side="pipeline", allow_join=True, allow_stages=True,
        allow_tee=True)
    chain = chains[0]
    job_id = job_id or "p" + uuid.uuid4().hex[:11]
    output_prefix = output_prefix or sink_prefix or "stream-output/"
    batch_records = batch_records or chain.source.batch_records
    if side_buckets is not None and join_node is None:
        raise PipelineError("per-side num_buckets only applies to joins")

    # -- array (pure batch) pipelines ----------------------------------------
    if chain.source.kind == "array":
        if chain.windowing is not None or join_node is not None \
                or tee_node is not None or len(chains) > 1:
            raise PipelineError("array pipelines are one-shot batch jobs: "
                                "no window/join/tee nodes and no continued "
                                "stages")
        if chain.options:
            raise PipelineError("array pipelines take build-wide options "
                                "only (stage-local num_buckets / n_slots "
                                "size windowed record-stage carries)")
        batch_plan, emit = _lower_array(
            chain, chain.top, num_buckets=num_buckets, n_workers=n_workers,
            key_space=key_space, backend=backend, mesh=mesh,
            data_spec=data_spec, finalize=finalize, jit=jit,
            combine_fn=combine_fn, axis_name=axis_name)
        side = SidePlan("main", chain.source, chain.transform, chain.key_fn,
                        chain.value_fn, batch_plan, 0,
                        num_buckets=num_buckets)
        stage = StagePlan(0, (side,), None, chain.reduce_mode, emit,
                          num_buckets, n_slots, allowed_lateness,
                          chain.capacity)
        built = BuiltPipeline(
            stages=(stage,), num_buckets=num_buckets, n_workers=n_workers,
            n_slots=n_slots, batch_records=batch_records,
            key_space=key_space_str, fanout=fanout,
            allowed_lateness=allowed_lateness,
            checkpoint_interval=checkpoint_interval, backend=backend,
            output_prefix=output_prefix, job_id=job_id, handoff=handoff,
            batch_plan=batch_plan, jit=jit)
        from ..analysis.diagnostics import warn_diagnostics
        warn_diagnostics(built.check())
        return built

    # -- record pipelines: assemble the stage DAG -----------------------------
    stages: list[StagePlan] = []
    side_chains: list[tuple[_Chain, ...]] = []   # per stage, its side chains
    raw_edges: list[tuple[int, int, int]] = []   # (src, dst, dst_side)

    def _add_stage(ch: _Chain, *, name: str, lateness: float,
                   prefix: str | None) -> int:
        idx = len(stages)
        nb, ns = _stage_options(ch, name=name, num_buckets=num_buckets,
                                n_slots=n_slots)
        if ch.options and isinstance(key_space, KeySpace):
            raise PipelineError("stage-local options cannot combine with a "
                                "KeySpace instance (it fixes one bucket "
                                "width for the whole graph)")
        _check_record_stage(ch, name=name, n_slots=ns, lateness=lateness,
                            fanout=fanout, num_buckets=nb,
                            n_workers=n_workers)
        emit, top_k, rank_by = _stage_emit(ch, nb)
        side = _lower_side(ch, name or "main", num_buckets=nb,
                           n_workers=n_workers, n_slots=ns,
                           key_space=key_space, fanout=fanout,
                           backend=backend, mesh=mesh, jit=jit,
                           combine_fn=combine_fn, axis_name=axis_name,
                           channels=2, channel_base=0, top_k=top_k,
                           rank_by=rank_by)
        stages.append(StagePlan(idx, (side,), ch.windowing, ch.reduce_mode,
                                emit, nb, ns, lateness, ch.capacity,
                                output_prefix=prefix))
        side_chains.append((ch,))
        return idx

    def _lower_seq(seq, tee, sink, *, upstream: int | None,
                   label: str) -> tuple[int, int]:
        """Lower one linear chain sequence — fed by stage ``upstream``
        through the carry, or by an external source when ``upstream`` is
        None — plus its trailing tee fan-out (each branch recursing here).
        Returns the (first, last) stage indices of the linear part."""
        prev = upstream
        first = last = None
        for j, ch in enumerate(seq):
            terminal = j == len(seq) - 1 and tee is None
            name = f"{label}stage {j + 1}" if (label or len(seq) > 1) else ""
            # stages fed through the carry see finalized windows in
            # watermark order — no out-of-order slack needed
            lateness = allowed_lateness if prev is None else 0.0
            idx = _add_stage(ch, name=name, lateness=lateness,
                             prefix=sink if terminal else None)
            if prev is not None:
                raw_edges.append((prev, idx, 0))
            prev = idx
            last = idx
            if first is None:
                first = idx
        if tee is not None:
            for bi, bp in enumerate(tee.params["branches"]):
                blabel = f"{label}branch {bi + 1}"
                bchains, _, btee, bsink = _parse_chain(
                    bp, side=blabel, allow_join=False, allow_stages=True,
                    allow_tee=True)
                _lower_seq(bchains, btee, bsink, upstream=prev,
                           label=blabel + " ")
        return first, last

    def _finish(inputs: tuple[tuple[int, int], ...],
                carry_width: int) -> BuiltPipeline:
        """Shared tail of every record lowering: derive each edge's
        transport, validate terminal sinks and session placement, and
        assemble the built program."""
        edges = []
        for src, dst, dst_side in raw_edges:
            src_ch = side_chains[src][0]
            dst_ch = side_chains[dst][dst_side]
            eager = _identity_boundary(src_ch, stages[src].emit, dst_ch)
            device = eager and _handoff_on_device(
                src_ch, stages[src].emit, dst_ch,
                key_space_str=key_space_str, fanout=fanout, handoff=handoff)
            edges.append(StageEdge(src, dst, dst_side, device, eager))
        srcs: dict[int, list[StageEdge]] = {}
        for e in edges:
            srcs.setdefault(e.src, []).append(e)
        for si, es in srcs.items():
            # back-compat stage view: the stage counts as eager/device when
            # every out-edge is (per-edge truth lives on the edges)
            stages[si] = dataclasses.replace(
                stages[si], eager_boundary=all(x.eager for x in es),
                handoff_device=all(x.device for x in es))
        if len(stages) > 1:
            for st in stages:
                if st.is_session:
                    raise PipelineError(
                        "session windows run in a single-stage pipeline "
                        "only: sessions finalize out of start order, so "
                        "wiring them into a stage DAG would break the "
                        "deterministic batch ↔ streaming replay")
        finals = [i for i in range(len(stages)) if i not in srcs]
        if len(finals) > 1:
            prefixes = [stages[i].output_prefix for i in finals]
            if any(not pfx for pfx in prefixes):
                raise PipelineError(
                    "a fan-out pipeline writes several output streams: "
                    "every terminal branch needs its own .sink(prefix)")
            # output keys normalize the trailing slash away, so the
            # distinctness check must too ("out" and "out/" collide)
            normed = [pfx.rstrip("/") for pfx in prefixes]
            if len(set(normed)) != len(normed):
                raise PipelineError("terminal branches must sink to "
                                    "distinct prefixes (two branches share "
                                    "one, so their windows would collide)")
        else:
            # single output stream: the pipeline-level prefix (which the
            # build option may override) stays authoritative, as ever
            stages[finals[0]] = dataclasses.replace(
                stages[finals[0]], output_prefix=None)
        built = BuiltPipeline(
            stages=tuple(stages), num_buckets=carry_width,
            n_workers=n_workers, n_slots=n_slots,
            batch_records=batch_records, key_space=key_space_str,
            fanout=fanout, allowed_lateness=allowed_lateness,
            checkpoint_interval=checkpoint_interval, backend=backend,
            output_prefix=output_prefix, job_id=job_id, handoff=handoff,
            edges=tuple(edges), inputs=inputs, jit=jit)
        from ..analysis.diagnostics import warn_diagnostics
        warn_diagnostics(built.check())
        return built

    # -- joins (either side may be a multi-stage chain) -----------------------
    if join_node is not None:
        on = join_node.params["on"]
        lchain = chains[-1]
        if on is not None:
            lchain = dataclasses.replace(lchain, key_fn=on)
        rchains, _, rtee, rsink = _parse_chain(
            join_node.right, side="right", allow_join=False,
            allow_stages=True, on=on)
        rchain = rchains[-1]
        if rsink is not None or rtee is not None or rchain.top is not None:
            raise PipelineError("the join's right side ends at its reduce "
                                "node")
        if rchains[0].source.kind == "array":
            raise PipelineError("join sides are record pipelines")
        if lchain.windowing is None or rchain.windowing is None:
            raise PipelineError("record pipelines need a window node before "
                                "reduce (use Windowing.tumbling(...) with a "
                                "large size for a single global window)")
        if rchain.windowing != lchain.windowing:
            raise PipelineError("join sides must share one window "
                                f"({lchain.windowing} != {rchain.windowing})")
        if lchain.windowing.is_session:
            raise PipelineError("session windows cannot join (window "
                                "bounds are per-key)")
        if fanout != "device":
            raise PipelineError("joins run with fanout='device'")
        _check_reduce(lchain, in_join=True)
        _check_reduce(rchain, in_join=True)
        if lchain.options or rchain.options:
            raise PipelineError("stage-local options cannot size a join's "
                                "final stage — size its key spaces with "
                                "build(num_buckets=(left, right))")
        lb, rb = side_buckets or (num_buckets, num_buckets)
        if key_space_str == "hashed" and lb != rb:
            raise PipelineError(
                "hashed joins need symmetric num_buckets: both sides must "
                "hash keys into the same bucket space to match")
        if num_buckets % n_workers != 0:
            raise PipelineError("num_buckets must divide by n_workers so "
                                "window slices stay aligned to the "
                                "scattered carry (asymmetric joins: the "
                                "larger side)")
        # the join stage itself still sees raw external events on any
        # single-stage side, so it keeps the out-of-order slack; a side fed
        # through the carry arrives in watermark order
        jlat = allowed_lateness if (len(chains) == 1 or len(rchains) == 1) \
            else 0.0
        _check_windowing(lchain.windowing, n_slots, jlat)
        lfirst = llast = rfirst = rlast = None
        if len(chains) > 1:
            lfirst, llast = _lower_seq(chains[:-1], None, None,
                                       upstream=None, label="left ")
        if len(rchains) > 1:
            rfirst, rlast = _lower_seq(rchains[:-1], None, None,
                                       upstream=None, label="right ")
        jidx = len(stages)
        layout = ((0, 2), (2, 2))       # per-side [sum, count] channel pairs
        _check_channels_disjoint(layout, channels=4)
        common = dict(n_workers=n_workers, n_slots=n_slots,
                      key_space=key_space, fanout=fanout, backend=backend,
                      mesh=mesh, jit=jit, combine_fn=combine_fn,
                      axis_name=axis_name, channels=4,
                      carry_buckets=num_buckets)
        sides = (_lower_side(lchain, "left", num_buckets=lb,
                             channel_base=layout[0][0], **common),
                 _lower_side(rchain, "right", num_buckets=rb,
                             channel_base=layout[1][0], **common))
        emit = EmitSpec("join", join_aggs=(lchain.reduce_spec,
                                           rchain.reduce_spec))
        stages.append(StagePlan(jidx, sides, lchain.windowing, "aggregate",
                                emit, num_buckets, n_slots, jlat, 0,
                                output_prefix=sink_prefix))
        side_chains.append((lchain, rchain))
        if llast is not None:
            raw_edges.append((llast, jidx, 0))
        if rlast is not None:
            raw_edges.append((rlast, jidx, 1))
        inputs = ((jidx, 0) if lfirst is None else (lfirst, 0),
                  (jidx, 1) if rfirst is None else (rfirst, 0))
        return _finish(inputs, num_buckets)

    # -- a linear chain (split at each reduce boundary) + optional tee --------
    first, _last = _lower_seq(chains, tee_node, sink_prefix, upstream=None,
                              label="")
    return _finish(((first, 0),), stages[0].num_buckets)
