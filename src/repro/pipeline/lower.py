"""Graph validation and lowering — pipeline nodes → execution plans.

``build_pipeline`` walks a ``Pipeline`` graph, validates the stage grammar
(one source; maps fuse; ``window`` before ``reduce``; ``top_k`` only over
aggregate reduces; joins windowed and reduced on both sides), and lowers
each stage chain onto ``repro.engine``:

* record chains → one ``ExecutionPlan`` per side, compiled once; adjacent
  ``map`` nodes fuse into a single host transform (one stage, not N);
* a windowed join → **two plans sharing one carry**: each side's plan folds
  its ``[value, 1]`` pair into a disjoint channel pair
  (``ReduceSpec.channel_base``) of the same scattered aggregate carry;
* ``Windowing.session(gap)`` → the engine's ``WindowSpec.session`` variant
  (host-wire fold, cell-addressed carry);
* ``top_k(k)`` → ``ReduceSpec(mode="top_k")`` — the aggregate fold plus the
  fixed-capacity heavy-hitters selection at finalization;
* array chains → one batch ``ExecutionPlan`` (no window), the lowering
  ``core.mapreduce`` rides on.

The result is a ``BuiltPipeline`` — the compiled program the
``StreamingCoordinator`` drives (streaming mode) and the batch runner
drives once over a store prefix (batch mode), with bit-identical
per-window output bytes.
"""

from __future__ import annotations

import dataclasses
import math
import uuid
from dataclasses import dataclass
from typing import Any, Callable

from ..engine.plan import ExecutionPlan, KeySpace, ReduceSpec, WindowSpec
from ..engine.stages import SEGMENT_REDUCE_KINDS
from ..streaming.sessions import SessionTracker
from ..streaming.state import WindowTracker
from ..streaming.windows import SlidingWindows, TumblingWindows
from .graph import Pipeline, PipelineError, Windowing

AGGREGATE_KINDS = ("count", "sum", "mean")

#: canonical stage order within one chain (source implicit at rank 0)
_STAGE_RANK = {"source": 0, "map": 1, "key_by": 2, "window": 3,
               "reduce": 4, "top_k": 5, "join": 6, "sink": 7}


def _default_key(rec) -> Any:
    return rec[1]


def _default_value(rec) -> float:
    return float(rec[2])


def fuse_maps(fns: list[Callable]) -> Callable | None:
    """Fuse adjacent record maps into one stage: apply in order, treating
    ``None`` as filter and an iterable of records as flat-map."""
    if not fns:
        return None
    if len(fns) == 1:
        return fns[0]

    def fused(rec):
        pending = [rec]
        for fn in fns:
            nxt = []
            for r in pending:
                out = fn(r)
                if out is None:
                    continue
                if isinstance(out, tuple):
                    nxt.append(out)
                else:
                    nxt.extend(out)
            pending = nxt
        return pending

    return fused


@dataclass(frozen=True)
class SourceSpec:
    """Where one side's records come from (bound at build or at run)."""

    kind: str                       # "log" | "records" | "array" | "unbound"
    prefix: str | None = None
    records: list | None = None
    shards: Any = None
    batch_records: int = 1024


@dataclass(frozen=True)
class _Chain:
    """One parsed linear chain (a join has two)."""

    source: SourceSpec
    transform: Callable | None
    key_fn: Callable
    value_fn: Callable
    windowing: Windowing | None
    reduce_spec: str | Callable
    reduce_mode: str
    capacity: int


@dataclass(frozen=True)
class SidePlan:
    """One side's lowered stage chain: the fused host transform plus the
    compiled execution plan folding into its channel pair of the carry."""

    name: str
    source: SourceSpec
    transform: Callable | None
    key_fn: Callable
    value_fn: Callable
    compiled: Any
    channel_base: int


@dataclass(frozen=True)
class EmitSpec:
    """How a finalized window turns into output records."""

    kind: str                       # "aggregate" | "group" | "top_k" | "join"
    aggregation: str = "count"      # aggregate / session emission kind
    reduce_fn: str | Callable = "sum"
    k: int = 0
    rank_by: str = "sum"            # top_k ranking kind
    join_aggs: tuple = ("sum", "sum")


@dataclass
class BuiltPipeline:
    """A validated, lowered pipeline — the compiled program both execution
    modes drive.  ``run_streaming`` hands it to the ``StreamingCoordinator``;
    ``run_batch`` drives the same program once over the full input."""

    sides: tuple[SidePlan, ...]
    emit: EmitSpec
    window: Windowing | None        # None → array (pure batch) pipeline
    mode: str                       # fold machinery: "aggregate" | "group"
    num_buckets: int
    n_workers: int
    n_slots: int
    batch_records: int
    key_space: str
    fanout: str
    allowed_lateness: float
    checkpoint_interval: int
    backend: str
    output_prefix: str
    job_id: str
    capacity: int
    batch_plan: Any = None          # array pipelines: CompiledBatchPlan

    @property
    def is_array(self) -> bool:
        return self.window is None

    @property
    def is_join(self) -> bool:
        return len(self.sides) == 2

    def assigner(self):
        """Fixed-window assigner (None for session windows)."""
        w = self.window
        if w is None or w.is_session:
            return None
        if w.kind == "tumbling":
            return TumblingWindows(w.size)
        return SlidingWindows(w.size, w.slide)

    def make_tracker(self):
        if self.window.is_session:
            return SessionTracker(self.window.gap, self.n_slots,
                                  self.allowed_lateness)
        return WindowTracker(self.assigner(), self.n_slots,
                             self.allowed_lateness)

    def one_shot(self, total_records: int) -> "BuiltPipeline":
        """The same program re-sized to fold the whole input as one batch
        with checkpointing off — how ``run_batch`` drives it."""
        return dataclasses.replace(self, batch_records=max(total_records, 1),
                                   checkpoint_interval=0)

    # -- execution -------------------------------------------------------------
    def run_streaming(self, store, meta, *, source=None, sources=None,
                      bus=None, autoscaler=None, announce: bool = True,
                      flush: bool = True):
        """Drive the program continuously over micro-batches.  Sources
        default to the graph's (``prefix=``/``records=``); joins take
        ``sources=(left, right)`` overrides.  Returns a ``StreamReport``."""
        from .runtime import run_streaming
        return run_streaming(self, store, meta, source=source,
                             sources=sources, bus=bus, autoscaler=autoscaler,
                             announce=announce, flush=flush)

    def run_batch(self, store=None, *, data=None, source=None, sources=None):
        """Drive the same program once over the full input (batch mode):
        array pipelines run the batch plan over ``data``; windowed
        pipelines fold everything in one pass and flush — emitting
        bit-identical window bytes to the streaming mode.  Returns
        ``(outputs, report)`` for windowed pipelines (outputs keyed by
        object-store key) or ``(result, stats)`` for array pipelines."""
        from .runtime import run_batch
        return run_batch(self, store, data=data, source=source,
                         sources=sources)


# ---------------------------------------------------------------------------
# Parsing + validation
# ---------------------------------------------------------------------------

def _parse_chain(p: Pipeline, *, side: str, allow_join: bool,
                 on: Callable | None = None):
    """Walk one pipeline's nodes; returns (chain, join_node, sink_prefix,
    top_node)."""
    if not p.nodes or p.nodes[0].op != "source":
        raise PipelineError(f"{side}: a pipeline starts at "
                            f"Pipeline.from_source(...)")
    rank = 0
    maps: list[Callable] = []
    key_fn = None
    windowing = None
    reduce_node = None
    top_node = None
    join_node = None
    sink_prefix = None
    src = p.nodes[0].params
    for node in p.nodes[1:]:
        r = _STAGE_RANK.get(node.op)
        if r is None:
            raise PipelineError(f"unknown node op {node.op!r}")
        if node.op == "source":
            raise PipelineError(f"{side}: more than one source")
        if r < rank or (r == rank and node.op != "map"):
            raise PipelineError(
                f"{side}: {node.op!r} cannot follow a "
                f"{[k for k, v in _STAGE_RANK.items() if v == rank][0]!r} "
                f"node — stage order is source → map* → key_by → window → "
                f"reduce → top_k → join → sink")
        rank = r
        if node.op == "map":
            maps.append(node.params["fn"])
        elif node.op == "key_by":
            key_fn = node.params["fn"]
        elif node.op == "window":
            windowing = node.params["windowing"]
        elif node.op == "reduce":
            reduce_node = node.params
        elif node.op == "top_k":
            top_node = node.params
        elif node.op == "join":
            if not allow_join:
                raise PipelineError(f"{side}: nested joins are not "
                                    f"supported")
            join_node = node
        elif node.op == "sink":
            sink_prefix = node.params["prefix"]
    if reduce_node is None:
        raise PipelineError(f"{side}: a pipeline needs a reduce node")
    if top_node is not None and join_node is not None:
        raise PipelineError("top_k and join cannot combine (rank the join "
                            "output downstream instead)")
    chain = _Chain(
        source=SourceSpec(kind=src["kind"], prefix=src["prefix"],
                          records=src["records"], shards=src["shards"],
                          batch_records=src["batch_records"]),
        transform=fuse_maps(maps),
        key_fn=on or key_fn or _default_key,
        value_fn=_default_value,
        windowing=windowing,
        reduce_spec=reduce_node["spec"],
        reduce_mode=reduce_node["mode"],
        capacity=reduce_node["capacity"])
    return chain, (join_node if allow_join else None), sink_prefix, top_node


def _check_windowing(w: Windowing, n_slots: int, lateness: float) -> None:
    if w.kind == "tumbling":
        if w.size <= 0:
            raise PipelineError("tumbling windows need a positive size")
    elif w.kind == "sliding":
        if w.size <= 0 or not w.slide or w.slide <= 0:
            raise PipelineError("sliding windows need positive size and "
                                "slide")
        if w.slide > w.size:
            raise PipelineError("slide > size leaves event-time gaps")
    elif w.kind == "session":
        if w.gap <= 0:
            raise PipelineError("session windows need a positive gap")
        return
    else:
        raise PipelineError(f"unknown windowing kind {w.kind!r}")
    # the ring must hold every window open at one instant
    step = w.slide or w.size
    need = math.ceil((w.size + lateness) / step) + 1
    if need > n_slots:
        raise PipelineError(
            f"n_slots={n_slots} cannot hold the window span; need >= "
            f"{need} for size={w.size}, slide={step}, lateness={lateness}")


def _check_reduce(chain: _Chain, *, in_join: bool) -> None:
    spec, mode = chain.reduce_spec, chain.reduce_mode
    if mode == "aggregate":
        if not isinstance(spec, str) or spec not in AGGREGATE_KINDS:
            raise PipelineError(f"aggregate reduce must be one of "
                                f"{AGGREGATE_KINDS}, got {spec!r}")
    elif mode == "group":
        if in_join:
            raise PipelineError("join sides must reduce in aggregate mode")
        if chain.capacity < 1:
            raise PipelineError("group mode needs capacity >= 1")
        if isinstance(spec, str) and spec not in SEGMENT_REDUCE_KINDS:
            raise PipelineError(f"group reduce kind must be a callable or "
                                f"one of {SEGMENT_REDUCE_KINDS}")
    else:
        raise PipelineError(f"unknown reduce mode {mode!r}")


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def _key_space_obj(key_space, num_buckets: int,
                   track_collisions: bool) -> KeySpace:
    """Normalize the build option: a ``KeySpace`` instance passes through
    verbatim (callers control collision tracking); a kind string builds
    one."""
    if isinstance(key_space, KeySpace):
        return key_space
    if key_space == "hashed":
        return KeySpace.hashed(num_buckets,
                               track_collisions=track_collisions)
    return KeySpace.dense(num_buckets)


def _lower_side(chain: _Chain, name: str, *, num_buckets: int,
                n_workers: int, n_slots: int, key_space, fanout: str,
                backend: str, mesh, jit: bool, combine_fn,
                axis_name: str, channels: int, channel_base: int,
                top_k: int = 0, rank_by: str = "sum") -> SidePlan:
    # streaming sides default collision tracking off: the coordinator's
    # host-side label table already reports collisions exactly
    ks = _key_space_obj(key_space, num_buckets, track_collisions=False)
    w = chain.windowing
    if w.is_session:
        window = WindowSpec.session(w.gap, n_slots=n_slots)
    else:
        window = WindowSpec(size=w.size, slide=w.slide, n_slots=n_slots,
                            fanout_on_device=fanout == "device")
    if chain.reduce_mode == "group":
        reduce = ReduceSpec("group", reduce_fn=chain.reduce_spec,
                            capacity=chain.capacity)
    elif top_k:
        reduce = ReduceSpec(mode="top_k", reduce_fn=rank_by, k=top_k,
                            combine_fn=combine_fn, channels=channels,
                            channel_base=channel_base)
    else:
        reduce = ReduceSpec("aggregate", combine_fn=combine_fn,
                            channels=channels, channel_base=channel_base)
    plan = ExecutionPlan(key_space=ks, reduce=reduce, n_workers=n_workers,
                         window=window, axis_name=axis_name)
    compiled = plan.compile(backend=backend, mesh=mesh, jit=jit)
    return SidePlan(name=name, source=chain.source,
                    transform=chain.transform, key_fn=chain.key_fn,
                    value_fn=chain.value_fn, compiled=compiled,
                    channel_base=channel_base)


def _lower_array(chain: _Chain, top_node, *, num_buckets: int, n_workers: int,
                 key_space, backend: str, mesh, data_spec, finalize: bool,
                 jit: bool, combine_fn, axis_name: str) -> tuple[Any, EmitSpec]:
    if chain.transform is None:
        raise PipelineError("array pipelines need exactly one map node "
                            "(the device UDF)")
    ks = _key_space_obj(key_space, num_buckets, track_collisions=True)
    if top_node is not None:
        k = top_node["k"]
        reduce = ReduceSpec(mode="top_k", reduce_fn=top_node["by"] or "sum",
                            k=k, combine_fn=combine_fn)
        emit = EmitSpec("top_k", k=k, rank_by=top_node["by"] or "sum")
    elif chain.reduce_mode == "group":
        reduce = ReduceSpec("group", reduce_fn=chain.reduce_spec,
                            capacity=chain.capacity)
        emit = EmitSpec("group", reduce_fn=chain.reduce_spec)
    else:
        reduce = ReduceSpec("aggregate", combine_fn=combine_fn)
        emit = EmitSpec("aggregate", aggregation=chain.reduce_spec)
    plan = ExecutionPlan(key_space=ks, reduce=reduce, n_workers=n_workers,
                         axis_name=axis_name)
    compiled = plan.compile(chain.transform, backend=backend, mesh=mesh,
                            data_spec=data_spec, finalize=finalize, jit=jit)
    return compiled, emit


def build_pipeline(p: Pipeline, *, num_buckets: int = 128, n_workers: int = 8,
                   n_slots: int = 8,
                   key_space: "str | KeySpace" = "dense",
                   fanout: str = "device", allowed_lateness: float = 0.0,
                   backend: str = "vmap", checkpoint_interval: int = 1,
                   batch_records: int | None = None, job_id: str | None = None,
                   output_prefix: str | None = None, mesh=None, data_spec=None,
                   finalize: bool = True, jit: bool = True, combine_fn=None,
                   axis_name: str = "workers") -> BuiltPipeline:
    """Validate ``p`` and lower it to a runnable ``BuiltPipeline``.
    ``key_space`` is ``"dense"`` / ``"hashed"`` or a ``KeySpace`` instance
    (passed to the plans verbatim, e.g. to control collision tracking)."""
    if isinstance(key_space, KeySpace):
        num_buckets = key_space.num_buckets
        key_space_str = key_space.mode
    elif key_space in ("dense", "hashed"):
        key_space_str = key_space
    else:
        raise PipelineError("key_space must be 'dense', 'hashed', or a "
                            "KeySpace")
    if fanout not in ("device", "host"):
        raise PipelineError("fanout must be 'device' or 'host'")
    if checkpoint_interval < 1:
        raise PipelineError("checkpoint_interval must be >= 1")
    chain, join_node, sink_prefix, top_node = _parse_chain(
        p, side="pipeline", allow_join=True)
    job_id = job_id or "p" + uuid.uuid4().hex[:11]
    output_prefix = output_prefix or sink_prefix or "stream-output/"
    batch_records = batch_records or chain.source.batch_records

    # -- array (pure batch) pipelines ----------------------------------------
    if chain.source.kind == "array":
        if chain.windowing is not None or join_node is not None:
            raise PipelineError("array pipelines are one-shot batch jobs: "
                                "no window/join nodes")
        if chain.reduce_mode != "group":
            _ = chain.reduce_spec  # any aggregate kind labels the output
        batch_plan, emit = _lower_array(
            chain, top_node, num_buckets=num_buckets, n_workers=n_workers,
            key_space=key_space, backend=backend, mesh=mesh,
            data_spec=data_spec, finalize=finalize, jit=jit,
            combine_fn=combine_fn, axis_name=axis_name)
        side = SidePlan("main", chain.source, chain.transform, chain.key_fn,
                        chain.value_fn, batch_plan, 0)
        return BuiltPipeline(
            sides=(side,), emit=emit, window=None, mode=chain.reduce_mode,
            num_buckets=num_buckets, n_workers=n_workers, n_slots=n_slots,
            batch_records=batch_records, key_space=key_space_str,
            fanout=fanout,
            allowed_lateness=allowed_lateness,
            checkpoint_interval=checkpoint_interval, backend=backend,
            output_prefix=output_prefix, job_id=job_id,
            capacity=chain.capacity, batch_plan=batch_plan)

    # -- record pipelines -----------------------------------------------------
    if chain.windowing is None:
        raise PipelineError("record pipelines need a window node before "
                            "reduce (use Windowing.tumbling(...) with a "
                            "large size for a single global window)")
    _check_windowing(chain.windowing, n_slots, allowed_lateness)
    _check_reduce(chain, in_join=join_node is not None)
    w = chain.windowing
    if w.is_session:
        if chain.reduce_mode != "aggregate":
            raise PipelineError("session windows reduce in aggregate mode "
                                "only")
        if top_node is not None:
            raise PipelineError("top_k over session windows is meaningless "
                                "(a session holds one key)")
        if join_node is not None:
            raise PipelineError("session windows cannot join (window "
                                "bounds are per-key)")
    if chain.reduce_mode == "group" and fanout != "device":
        raise PipelineError("group mode runs with fanout='device'")
    if top_node is not None and chain.reduce_mode != "aggregate":
        raise PipelineError("top_k ranks an aggregate reduce")
    if chain.reduce_mode == "aggregate" and num_buckets % n_workers != 0:
        raise PipelineError("num_buckets must divide by n_workers so "
                            "window slices stay aligned to the scattered "
                            "carry")

    if join_node is not None:
        if fanout != "device":
            raise PipelineError("joins run with fanout='device'")
        on = join_node.params["on"]
        rchain, _, rsink, rtop = _parse_chain(join_node.right, side="right",
                                              allow_join=False, on=on)
        if rsink is not None or rtop is not None:
            raise PipelineError("the join's right side ends at its reduce "
                                "node")
        if rchain.windowing != chain.windowing:
            raise PipelineError("join sides must share one window "
                                f"({chain.windowing} != {rchain.windowing})")
        if rchain.source.kind == "array":
            raise PipelineError("join sides are record pipelines")
        _check_reduce(rchain, in_join=True)
        if on is not None:
            chain = dataclasses.replace(chain, key_fn=on)
        common = dict(num_buckets=num_buckets, n_workers=n_workers,
                      n_slots=n_slots, key_space=key_space, fanout=fanout,
                      backend=backend, mesh=mesh, jit=jit,
                      combine_fn=combine_fn, axis_name=axis_name, channels=4)
        sides = (_lower_side(chain, "left", channel_base=0, **common),
                 _lower_side(rchain, "right", channel_base=2, **common))
        emit = EmitSpec("join", join_aggs=(chain.reduce_spec,
                                           rchain.reduce_spec))
        return BuiltPipeline(
            sides=sides, emit=emit, window=chain.windowing, mode="aggregate",
            num_buckets=num_buckets, n_workers=n_workers, n_slots=n_slots,
            batch_records=batch_records, key_space=key_space_str,
            fanout=fanout,
            allowed_lateness=allowed_lateness,
            checkpoint_interval=checkpoint_interval, backend=backend,
            output_prefix=output_prefix, job_id=job_id, capacity=0)

    top_k, rank_by = 0, "sum"
    if top_node is not None:
        if top_node["k"] > num_buckets:
            raise PipelineError("top_k k exceeds the bucket space")
        top_k = top_node["k"]
        rank_by = top_node["by"] or chain.reduce_spec
        if rank_by not in AGGREGATE_KINDS:
            raise PipelineError(f"top_k ranks by one of {AGGREGATE_KINDS}")
    side = _lower_side(chain, "main", num_buckets=num_buckets,
                       n_workers=n_workers, n_slots=n_slots,
                       key_space=key_space, fanout=fanout, backend=backend,
                       mesh=mesh, jit=jit, combine_fn=combine_fn,
                       axis_name=axis_name, channels=2, channel_base=0,
                       top_k=top_k, rank_by=rank_by)
    if top_node is not None:
        emit = EmitSpec("top_k", aggregation=chain.reduce_spec,
                        k=top_k, rank_by=rank_by)
    elif chain.reduce_mode == "group":
        emit = EmitSpec("group", reduce_fn=chain.reduce_spec)
    else:
        emit = EmitSpec("aggregate", aggregation=chain.reduce_spec)
    return BuiltPipeline(
        sides=(side,), emit=emit, window=chain.windowing,
        mode=chain.reduce_mode, num_buckets=num_buckets, n_workers=n_workers,
        n_slots=n_slots, batch_records=batch_records,
        key_space=key_space_str, fanout=fanout, allowed_lateness=allowed_lateness,
        checkpoint_interval=checkpoint_interval, backend=backend,
        output_prefix=output_prefix, job_id=job_id, capacity=chain.capacity)
