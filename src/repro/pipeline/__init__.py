"""Declarative pipeline dataflow — the one front door for batch + streaming.

``Pipeline.from_source(...).map(fn).key_by(...).window(...).reduce(...)
.top_k(k).join(other).tee(branch, ...).sink(prefix).build(...)`` declares
a dataflow graph; ``build()`` validates it and lowers it to
``repro.engine`` execution plans (fusing adjacent maps, compiling a
windowed join as two plans sharing one carry, splitting a chain that
continues past a reduce into stages chained by carry handoff, and fanning
a ``tee``'d stage out to several branches over per-edge handoffs — the
program is a stage *DAG*, not just a chain); the built program then runs
in batch mode (one drive over an object-store prefix) or streaming mode
(micro-batches via the ``StreamingCoordinator``) with bit-identical
per-window results on every branch.

This package is the only entry point: the ``mapreduce()`` and
``StreamingConfig`` shims that once lowered onto it were removed in PR 8.

Layout: ``graph`` (the chainable node vocabulary), ``lower`` (validation +
plan lowering → ``BuiltPipeline``), ``runtime`` (the batch and streaming
drivers plus the two-log ``JoinSource``).
"""

from .graph import Pipeline, PipelineError, Windowing
from .lower import (BuiltPipeline, EmitSpec, SidePlan, SourceSpec, StageEdge,
                    StagePlan)
from .runtime import JoinSource, RunOptions, resolve_source, run

__all__ = [
    "Pipeline", "PipelineError", "Windowing", "BuiltPipeline", "EmitSpec",
    "SidePlan", "SourceSpec", "StageEdge", "StagePlan", "JoinSource",
    "RunOptions", "resolve_source", "run",
]
