"""The declarative dataflow graph — the one front door for batch + streaming.

A ``Pipeline`` is an immutable chain of nodes::

    Pipeline.from_source(prefix="streams/gps")
        .map(fn)                       # host record transform (fused)
        .key_by(lambda r: r[1])
        .window(Windowing.tumbling(60.0))
        .reduce("mean")
        .top_k(8)                      # optional: heavy hitters per window
        .sink("stream-output/")
        .build(num_buckets=64, n_workers=8)

Each method returns a *new* pipeline (graphs are values, shareable and
re-buildable), following the declarative-chain style of Bauplan-like FaaS
pipelines rather than per-invocation job configs.  ``build()`` validates
the graph and lowers every stage chain to ``repro.engine`` execution plans
(``repro.pipeline.lower``); the built artifact then runs the *same* graph
in batch mode (one drive over an object-store prefix) or streaming mode
(micro-batches through the ``StreamingCoordinator``) with bit-identical
per-window results.

Two source families share the grammar:

* **record pipelines** — events ``(event_time, key, value)`` from an
  object-store event log (``prefix=``) or memory (``records=``); maps are
  host record transforms (return a record, ``None`` to filter, or an
  iterable to flat-map) and adjacent maps fuse into one stage; ``window``
  is required before ``reduce``.
* **array pipelines** — device shards (``shards=``); the single ``map`` is
  the device UDF ``shard -> (keys, values, valid)`` and the chain lowers
  to one batch ``ExecutionPlan`` (no window) — ``core.mapreduce`` is now a
  two-node pipeline of this family.

``a.join(b, on=...)`` makes a two-input node: both sides must be windowed
identically and reduced with aggregate kinds; the join lowers to two plans
sharing one carry (disjoint channel pairs) and emits, per window, every
key present on both sides with ``[left_aggregate, right_aggregate]``.
``build(num_buckets=(left, right))`` sizes the two key spaces
independently (dense joins), widening the shared carry to the larger
side.

A chain may continue **past a reduce**: ``….reduce(...).map(...)
.key_by(...).window(...).reduce(...)`` splits at each reduce boundary
into a sequence of stages — each stage's finalized windows become the
next stage's input records ``(window_start, key, aggregate)``, handed
off through the carry (on-device when the boundary has no host
transform).  Two-phase jobs — count-then-top-k, average-of-averages —
are one graph, and batch and streaming runs of it stay bit-identical
per window.

A chain may also **fan out**: ``….reduce(...).tee(branch, branch, …)``
feeds the finalized windows of one stage to *several* downstream
branches — the graph is a DAG, not just a chain.  Each branch is rooted
at ``Pipeline.branch()`` (or built by a callable receiving that stub)
and continues the grammar — ``map/key_by/window/reduce``, more stages,
``top_k``, its own ``sink`` — so one ingested stream feeds many
concurrent consumers off a single shared intermediate, the Kafka-ML
fan-out shape::

    counts = (Pipeline.from_source(prefix="streams/gps")
              .key_by().window(60.0).reduce("count"))
    dag = counts.tee(
        Pipeline.branch().window(300.0).reduce("sum").top_k(8)
                .sink("gps-top/"),
        Pipeline.branch().map(to_region).key_by().window(300.0)
                .reduce("sum").sink("gps-region/"))

Each fan-out edge picks its own handoff transport (on-device for
identity boundaries, host records otherwise), and a join's two inputs
may themselves be multi-stage chains.  Stage-local build options ride
on ``reduce(..., num_buckets=, n_slots=)`` when one branch needs a
different carry width or ring depth than the rest of the graph.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

__all__ = ["Pipeline", "Windowing", "PipelineError"]


class PipelineError(ValueError):
    """A malformed pipeline graph, rejected at ``build()``."""


@dataclass(frozen=True)
class Windowing:
    """Declarative window description — the graph-level twin of the
    engine's ``WindowSpec``."""

    kind: str                      # "tumbling" | "sliding" | "session"
    size: float = 0.0
    slide: float | None = None
    gap: float = 0.0

    @classmethod
    def tumbling(cls, size: float) -> "Windowing":
        return cls("tumbling", size=size)

    @classmethod
    def sliding(cls, size: float, slide: float) -> "Windowing":
        return cls("sliding", size=size, slide=slide)

    @classmethod
    def session(cls, gap: float) -> "Windowing":
        return cls("session", gap=gap)

    @property
    def is_session(self) -> bool:
        return self.kind == "session"


@dataclass(frozen=True)
class Node:
    """One graph node.  ``right`` holds the other input of a join."""

    op: str
    params: dict = field(default_factory=dict)
    right: "Pipeline | None" = None


@dataclass(frozen=True)
class Pipeline:
    """An immutable dataflow graph under construction."""

    nodes: tuple[Node, ...] = ()

    # -- sources ---------------------------------------------------------------
    @classmethod
    def from_source(cls, *, prefix: str | None = None,
                    records: Iterable | None = None,
                    shards: Any = None,
                    batch_records: int = 1024) -> "Pipeline":
        """Root a pipeline at a source: an event-log ``prefix`` in the
        object store, in-memory ``records``, device ``shards`` (array
        pipelines), or nothing — an *unbound* source whose data arrives at
        run time."""
        given = [x is not None for x in (prefix, records, shards)]
        if sum(given) > 1:
            raise PipelineError("pass at most one of prefix/records/shards")
        if batch_records < 1:
            raise PipelineError("batch_records must be >= 1")
        kind = ("log" if prefix is not None else
                "records" if records is not None else
                "array" if shards is not None else "unbound")
        params = {"kind": kind, "prefix": prefix, "shards": shards,
                  "records": list(records) if records is not None else None,
                  "batch_records": batch_records}
        return cls((Node("source", params),))

    @classmethod
    def branch(cls) -> "Pipeline":
        """Root a tee branch: a pipeline whose input is the finalized
        windows of the stage it is teed from — records
        ``(window_start, key, aggregate)`` delivered through the carry
        handoff.  Only valid as an argument to ``tee``."""
        return cls((Node("source", {"kind": "carry-stub", "prefix": None,
                                    "shards": None, "records": None,
                                    "batch_records": 1024}),))

    # -- chaining --------------------------------------------------------------
    def _append(self, node: Node) -> "Pipeline":
        return Pipeline(self.nodes + (node,))

    def _has(self, op: str) -> bool:
        return any(n.op == op for n in self.nodes)

    def map(self, fn: Callable) -> "Pipeline":
        """Record pipelines: ``fn(record) -> record | None | iterable`` —
        a transform, filter, or flat-map over ``(ts, key, value)`` tuples;
        adjacent maps fuse into one stage at build.  Array pipelines: the
        device UDF ``shard -> (keys, values, valid)``."""
        return self._append(Node("map", {"fn": fn}))

    def key_by(self, fn: Callable | None = None) -> "Pipeline":
        """Declare the shuffle key: ``fn(record) -> raw key`` (default:
        the record's second field)."""
        return self._append(Node("key_by", {"fn": fn}))

    def window(self, w: "Windowing | float") -> "Pipeline":
        """Event-time windows; a bare float means tumbling windows of that
        size."""
        if not isinstance(w, Windowing):
            w = Windowing.tumbling(float(w))
        return self._append(Node("window", {"windowing": w}))

    def reduce(self, spec: str | Callable = "count", *, mode: str | None = None,
               capacity: int = 0, num_buckets: int | None = None,
               n_slots: int | None = None) -> "Pipeline":
        """How each (window ×) key group reduces.

        ``spec`` is an aggregate kind (``count | sum | mean``), a group
        segment-reducer kind name, or a callable group reducer (the
        ``(keys, values, starts) -> (gk, gv, gvalid)`` contract).  A
        callable implies ``mode="group"``; group mode needs ``capacity``
        (records buffered per worker per window slot).

        ``num_buckets`` / ``n_slots`` are *stage-local* build options: the
        stage this reduce closes sizes its own carry (key-bucket width ×
        window-ring depth) instead of inheriting the ``build()``-wide
        defaults — a fan-out branch over few keys need not carry the
        ingest stage's wide bucket space, and a long-window stage can
        deepen only its own ring.  Validated at lower time."""
        if mode is None:
            mode = "group" if callable(spec) else "aggregate"
        return self._append(Node("reduce", {"spec": spec, "mode": mode,
                                            "capacity": capacity,
                                            "num_buckets": num_buckets,
                                            "n_slots": n_slots}))

    def top_k(self, k: int, by: str | None = None) -> "Pipeline":
        """Keep only the k heaviest keys per window, ranked ``by`` an
        aggregate kind (default: the reduce node's kind) — exact on closed
        (dense) key domains, heavy-hitters-up-to-collisions on hashed."""
        if k < 1:
            raise PipelineError("top_k needs k >= 1")
        return self._append(Node("top_k", {"k": k, "by": by}))

    def tee(self, *branches: "Callable[[Pipeline], Pipeline] | Pipeline"
            ) -> "Pipeline":
        """Fan this stage out: every finalized window of the reduce that
        closes the current stage feeds *each* branch as input records
        ``(window_start, key, aggregate)`` — one intermediate stream,
        several concurrent consumers.

        Each branch is a pipeline rooted at ``Pipeline.branch()`` (pass it
        pre-built, or pass a callable that receives the branch stub and
        returns the extended pipeline) and follows the normal grammar:
        ``map/key_by/window/reduce``, further stages, ``top_k``, nested
        ``tee``, and its own ``sink`` — every terminal branch needs a
        distinct sink, since each is a separate output stream.  ``tee`` is
        a terminal node of this pipeline."""
        if len(branches) < 2:
            raise PipelineError("tee needs at least two branches (a single "
                                "continuation is just a longer chain)")
        resolved = []
        for i, b in enumerate(branches):
            bp = b if isinstance(b, Pipeline) else b(Pipeline.branch())
            if not isinstance(bp, Pipeline):
                raise PipelineError(f"tee branch {i} must be (or return) a "
                                    f"Pipeline")
            if not bp.nodes or bp.nodes[0].op != "source" \
                    or bp.nodes[0].params.get("kind") != "carry-stub":
                raise PipelineError(
                    f"tee branch {i} must be rooted at Pipeline.branch() — "
                    f"its input is the teed stage's finalized windows, not "
                    f"an external source")
            resolved.append(bp)
        return self._append(Node("tee", {"branches": tuple(resolved)}))

    def join(self, other: "Pipeline", on: Callable | None = None
             ) -> "Pipeline":
        """Windowed equi-join: per window, emit every key present on both
        sides with both sides' aggregates.  Both sides must be reduced
        record pipelines over the same *final* window; either side may be
        a multi-stage chain (its earlier stages lower to upstream DAG
        stages feeding the join through carry handoffs).  ``on`` overrides
        both sides' final ``key_by``."""
        if not isinstance(other, Pipeline):
            raise PipelineError("join expects another Pipeline")
        return self._append(Node("join", {"on": on}, right=other))

    def sink(self, prefix: str) -> "Pipeline":
        """Where finalized windows land in the object store."""
        return self._append(Node("sink", {"prefix": prefix}))

    # -- building --------------------------------------------------------------
    def build(self, **opts):
        """Validate the graph and lower it to execution plans.  Returns a
        ``BuiltPipeline`` that runs in batch or streaming mode — see
        ``repro.pipeline.lower.build_pipeline`` for the options."""
        from .lower import build_pipeline
        return build_pipeline(self, **opts)
