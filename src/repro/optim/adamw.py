"""AdamW from scratch (no optax in this environment) with:

  * fp32 moments regardless of param dtype (mixed-precision discipline:
    bf16 params, fp32 m/v — the standard large-model recipe),
  * decoupled weight decay, global-norm gradient clipping,
  * schedule as a function of the fp32 step counter,
  * pytree-first: states mirror the param tree, so every sharding rule that
    applies to a parameter applies to its moments (fully-sharded optimizer
    state under FSDP comes for free).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


class TrainState(NamedTuple):
    params: Any
    opt_state: OptState
    step: jax.Array


@dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0

    def init(self, params: Any) -> OptState:
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)
        return OptState(m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params),
                        count=jnp.zeros((), jnp.int32))

    def _lr(self, count: jax.Array) -> jax.Array:
        return self.lr(count) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads: Any, state: OptState, params: Any
               ) -> tuple[Any, OptState, dict[str, jax.Array]]:
        """Returns (updates, new_state, stats).  ``updates`` are deltas to be
        added to params (in param dtype)."""
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(gf)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            gf = jax.tree.map(lambda g: g * scale, gf)
        count = state.count + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, gf)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state.v, gf)
        c = count.astype(jnp.float32)
        mhat_scale = 1.0 / (1 - b1 ** c)
        vhat_scale = 1.0 / (1 - b2 ** c)
        lr = self._lr(count)

        def upd(m_, v_, p):
            step = (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, OptState(m, v, count), {"grad_norm": gnorm, "lr": lr}


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(lambda p, u: p + u, params, updates)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
