"""Gradient compression — smaller 'spill files' for the gradient shuffle.

The paper's combiner exists to cut shuffle *volume* before it hits the
network.  The gradient analogue at pod scale is lossy compression of the
gradient all-reduce: int8 quantization with per-tensor scales and **error
feedback** (residual carried to the next step, which keeps SGD convergence —
1-bit Adam / EF-SGD lineage).  ~4× less ICI traffic on the collective term.

``compressed_psum`` composes with ``shard_map``: quantize → psum the int32
accumulations → dequantize; exact for the scale handling because scales are
psum-maxed first (shared scale across workers).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """→ (int8 values, fp32 scale).  Symmetric per-tensor quantization."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(grads: Any, axis_name: str) -> Any:
    """All-reduce a gradient pytree in int8 over a mesh axis.

    Per leaf: share one scale (max over workers), quantize, psum the int32
    sums (exact — no overflow for ≤ 2^23 workers), dequantize, average.
    """
    n = jax.lax.psum(1, axis_name)

    def leaf(g):
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int32)
        s = jax.lax.psum(q, axis_name)
        return (s.astype(jnp.float32) * scale / n).astype(g.dtype)

    return jax.tree.map(leaf, grads)


def ef_compress_update(grads: Any, residual: Any) -> tuple[Any, Any]:
    """Error-feedback step for host-side compression paths: quantize
    (grad + residual), return (quantized-dequantized grads, new residual)."""
    def leaf(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = compress_int8(gf)
        deq = decompress_int8(q, scale)
        return deq.astype(g.dtype), gf - deq

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                grads)
    out = jax.tree.map(leaf, grads, residual)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_r
