from .adamw import AdamW, OptState, TrainState, apply_updates
from .compression import compress_int8, decompress_int8, compressed_psum
from .schedule import cosine_schedule, linear_warmup

__all__ = ["AdamW", "OptState", "TrainState", "apply_updates",
           "compress_int8", "decompress_int8", "compressed_psum",
           "cosine_schedule", "linear_warmup"]
