"""Event-time window assignment — tumbling and sliding.

Windows are half-open event-time intervals ``[start, end)`` indexed by an
integer so the tracker can address them without materializing interval
objects per event.  Assignment is pure arithmetic on the event timestamp;
an event exactly on a boundary belongs to the window *starting* there
(the half-open convention every stream processor shares).
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class Window:
    """Half-open event-time interval [start, end)."""

    start: float
    end: float

    def __contains__(self, ts: float) -> bool:
        return self.start <= ts < self.end

    @property
    def size(self) -> float:
        return self.end - self.start


class WindowAssigner:
    """Maps event timestamps to integer window indices and back."""

    def assign(self, ts: float) -> list[int]:
        raise NotImplementedError

    def window(self, index: int) -> Window:
        raise NotImplementedError

    def max_windows_per_event(self) -> int:
        raise NotImplementedError

    def min_live_index(self, watermark: float) -> int:
        """Smallest window index the watermark has not yet closed — the
        device fan-out's late-masking bound (``engine.stages.window_fanout``
        drops copies below it).

        Seeds a float64 guess, then corrects with the *same*
        ``window(i).end <= watermark`` predicate ``WindowTracker.is_late``
        uses, so host admission and device masking agree exactly even when
        the watermark sits on a window boundary.
        """
        if watermark == float("-inf"):
            return -(2 ** 31)
        if watermark == float("inf"):
            return 2 ** 31 - 1
        w0 = self.window(0)
        step = self.window(1).start - w0.start
        cand = math.floor((watermark - w0.size - w0.start) / step) + 1
        while self.window(cand).end <= watermark:
            cand += 1
        while self.window(cand - 1).end > watermark:
            cand -= 1
        return cand


@dataclass(frozen=True)
class TumblingWindows(WindowAssigner):
    """Non-overlapping fixed-size windows: index i covers
    [offset + i*size, offset + (i+1)*size)."""

    size: float
    offset: float = 0.0

    def __post_init__(self) -> None:
        if self.size <= 0:
            raise ValueError("window size must be positive")

    def assign(self, ts: float) -> list[int]:
        return [math.floor((ts - self.offset) / self.size)]

    def window(self, index: int) -> Window:
        start = self.offset + index * self.size
        return Window(start, start + self.size)

    def max_windows_per_event(self) -> int:
        return 1


@dataclass(frozen=True)
class SlidingWindows(WindowAssigner):
    """Overlapping windows of ``size`` starting every ``slide``: index i
    covers [offset + i*slide, offset + i*slide + size).  An event belongs to
    every window whose interval contains it — up to ceil(size / slide)."""

    size: float
    slide: float
    offset: float = 0.0

    def __post_init__(self) -> None:
        if self.size <= 0 or self.slide <= 0:
            raise ValueError("size and slide must be positive")
        if self.slide > self.size:
            raise ValueError("slide > size leaves event-time gaps")

    def assign(self, ts: float) -> list[int]:
        rel = ts - self.offset
        last = math.floor(rel / self.slide)
        first = math.floor((rel - self.size) / self.slide) + 1
        return list(range(first, last + 1))

    def window(self, index: int) -> Window:
        start = self.offset + index * self.slide
        return Window(start, start + self.size)

    def max_windows_per_event(self) -> int:
        return math.ceil(self.size / self.slide)
