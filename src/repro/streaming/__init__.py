"""Streaming micro-batch engine — continuous MapReduce over event streams.

The paper's headline workload is *event-driven, real-time* processing of
continuous logistics streams (GPS/IoT events through Kafka + Knative
scale-to-zero), but the batch engine runs one-shot jobs: split a static
input, map, shuffle, reduce, terminate.  This package closes that gap with a
long-lived incremental dataflow:

  * ``StreamSource`` — a replayable event-log reader (object-store segments
    as the Kafka-topic stand-in) that chunks a continuous record stream into
    bounded micro-batches;
  * ``TumblingWindows`` / ``SlidingWindows`` — event-time window assignment;
  * ``WindowTracker`` — watermark bookkeeping: in-flight windows live in a
    bounded ring of carry slots, finalize in event-time order once the
    watermark passes their end, and late events are counted and dropped;
  * ``SessionTracker`` — gap-based session windows: data-dependent
    per-key window bounds, carried as (slot, bucket) *cells* of the same
    aggregate carry, with on-device cell merges for bridged sessions;
  * ``StreamingCoordinator`` — one map→shuffle→reduce round per
    micro-batch through a compiled pipeline program
    (``repro.pipeline.BuiltPipeline`` — the declarative dataflow API is
    the front door): records ship to the device once and fan out into their windows
    on-chip; aggregate-mode per-window partials merge across batches by a
    single fused ``reduce_scatter`` per batch per side (a join's two
    sides share one carry), group-mode records buffer per (worker, window
    slot) and reduce with an arbitrary ``reduce_fn`` at finalization, and
    finalized windows are emitted idempotently to the object store.
    ``key_space="hashed"`` opens the key domain (collisions counted, not
    fatal).

Backpressure: the source produces one CloudEvent per micro-batch on
``TOPIC_STREAM_BATCH``; the coordinator consumes them as a consumer group and
scales its mapper pool from the queue depth (consumer lag), the KEDA-style
signal, instead of a fixed split count.
"""

from .coordinator import (RunOptions, StreamingCoordinator, StreamReport,
                          session_output_key, window_output_key)
from .sessions import Session, SessionTracker
from .source import MicroBatch, StreamSource, write_event_log
from .state import LateEventError, WindowTracker
from .windows import SlidingWindows, TumblingWindows, Window, WindowAssigner

__all__ = [
    "RunOptions", "StreamingCoordinator", "StreamReport",
    "window_output_key", "session_output_key", "MicroBatch", "StreamSource",
    "write_event_log", "LateEventError", "WindowTracker", "Session",
    "SessionTracker", "SlidingWindows", "TumblingWindows", "Window",
    "WindowAssigner",
]
