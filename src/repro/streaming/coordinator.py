"""StreamingCoordinator — continuous MapReduce, one round per micro-batch.

Where ``core.coordinator.Coordinator`` drives a one-shot job to DONE and
terminates, this coordinator runs a long-lived loop: consume the next
micro-batch trigger, fold the batch through the device engine's incremental
entry point (one fused ``reduce_scatter`` folding (window, key) partial
aggregates into the carried state), advance the watermark, and finalize +
emit every window the watermark has passed.  The full streaming state —
consumed record offset, carried window aggregates, watermark/ring tracker,
key dictionary — checkpoints at batch boundaries (metadata + object store),
so a restarted coordinator resumes exactly where it stopped, even over a
log that has grown since — the streaming analogue of
``Coordinator.resume_job``.

Scaling is backpressure-driven: the source announces each batch on
``TOPIC_STREAM_BATCH``; the coordinator is a consumer group on that topic and
sizes its mapper pool from the consumer lag (queue depth) instead of a fixed
split count — KEDA's Kafka-lag signal where the batch engine uses KPA
concurrency.
"""

from __future__ import annotations

import math
import time
import uuid
from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp
import numpy as np

from ..core.autoscaler import AutoscalerConfig, ServerlessPool
from ..core.events import (EventBus, TOPIC_STREAM_BATCH, TOPIC_STREAM_WINDOW,
                           batch_event, window_event)
from ..core.mapreduce import (DeviceJobConfig, clear_window_slot,
                              init_window_carry, make_incremental_step,
                              read_window_slot)
from ..core.metadata import MetadataStore
from ..core.storage import ObjectStore
from ..core.workers import _encode_records
from .source import MicroBatch, StreamSource
from .state import LateEventError, WindowTracker
from .windows import SlidingWindows, TumblingWindows, Window, WindowAssigner

AGGREGATIONS = ("count", "sum", "mean")


@dataclass
class StreamingConfig:
    """Stream-job analogue of the batch ``JobConfig`` JSON document."""

    num_buckets: int = 128          # key-id space (dense bucket width)
    n_workers: int = 8              # device-engine mesh-axis size
    window_size: float = 60.0       # seconds of event time per window
    window_slide: float | None = None  # None → tumbling; else sliding
    allowed_lateness: float = 0.0   # watermark slack for out-of-order events
    n_slots: int = 8                # in-flight window ring capacity
    batch_records: int = 1024       # micro-batch size bound
    aggregation: str = "count"      # count | sum | mean (per window × key)
    checkpoint_interval: int = 1    # save restart state every N batches
    output_prefix: str = "stream-output/"
    backend: str = "vmap"
    job_id: str = field(default_factory=lambda: "s" + uuid.uuid4().hex[:11])

    def validate(self) -> None:
        if self.aggregation not in AGGREGATIONS:
            raise ValueError(f"aggregation must be one of {AGGREGATIONS}")
        if self.num_buckets % self.n_workers != 0:
            raise ValueError("num_buckets must divide by n_workers so window "
                             "slices stay aligned to the scattered carry")
        if self.n_slots < 2:
            raise ValueError("need >= 2 window slots (one closing, one open)")
        if self.checkpoint_interval < 1:
            raise ValueError("checkpoint_interval must be >= 1")
        if self.window_slide is not None and self.window_slide > self.window_size:
            raise ValueError("slide must not exceed window size")
        # the ring must hold every window that can be open at one instant:
        # those covering (watermark, watermark + size + lateness]
        step = self.window_slide or self.window_size
        need = math.ceil((self.window_size + self.allowed_lateness) / step) + 1
        if need > self.n_slots:
            raise ValueError(
                f"n_slots={self.n_slots} cannot hold the "
                f"window_size+allowed_lateness span; need >= {need} slots "
                f"for size={self.window_size}, slide={step}, "
                f"lateness={self.allowed_lateness}")

    def assigner(self) -> WindowAssigner:
        if self.window_slide is None:
            return TumblingWindows(self.window_size)
        return SlidingWindows(self.window_size, self.window_slide)


@dataclass
class StreamReport:
    """Rolling accounting for a streaming run — the Fig. 6/7 quantities
    reinterpreted for sustained throughput."""

    job_id: str
    batches: int = 0
    records_in: int = 0             # raw events consumed
    records_expanded: int = 0       # after window fan-out (sliding > 1×)
    late_dropped: int = 0
    windows_emitted: int = 0
    wall_time: float = 0.0
    batch_latencies: list[float] = field(default_factory=list)
    max_lag: int = 0                # worst backpressure observed
    scale_events: int = 0           # pool resizes driven by lag
    error: str | None = None

    @property
    def records_per_sec(self) -> float:
        return self.records_in / self.wall_time if self.wall_time else 0.0

    @property
    def mean_batch_latency(self) -> float:
        ls = self.batch_latencies
        return sum(ls) / len(ls) if ls else 0.0


def window_output_key(cfg: StreamingConfig, window: Window) -> str:
    return (f"{cfg.output_prefix.rstrip('/')}/{cfg.job_id}/"
            f"window-{window.start:.3f}-{window.end:.3f}")


def _state_key(job_id: str) -> str:
    return f"stream/{job_id}/state"


def _carry_key(job_id: str) -> str:
    return f"jobs/{job_id}/stream/carry"


class StreamingCoordinator:
    """Long-lived coordinator: micro-batch rounds over a continuous stream."""

    CONSUMER_GROUP = "streaming-coordinator"

    def __init__(self, store: ObjectStore, meta: MetadataStore,
                 cfg: StreamingConfig, bus: EventBus | None = None,
                 autoscaler: AutoscalerConfig | None = None) -> None:
        cfg.validate()
        self.store = store
        self.meta = meta
        self.cfg = cfg
        self.bus = bus or EventBus()
        self.assigner = cfg.assigner()
        self.pool = ServerlessPool(
            "stream-mapper", autoscaler or AutoscalerConfig(
                max_scale=cfg.n_workers))
        self.dev_cfg = DeviceJobConfig(num_buckets=cfg.num_buckets,
                                       n_workers=cfg.n_workers)
        # compiled once per stream: the per-batch fold (fused reduce_scatter)
        self._step = make_incremental_step(self.dev_cfg, cfg.n_slots,
                                           backend=cfg.backend)
        self._carry = init_window_carry(self.dev_cfg, cfg.n_slots,
                                        backend=cfg.backend)
        self.tracker = WindowTracker(self.assigner, cfg.n_slots,
                                     cfg.allowed_lateness)
        # bounded key→bucket-id dictionary (the data layer's vocab analogue)
        self._key_ids: dict[Any, int] = {}
        self._id_keys: list[Any] = []
        self._records_consumed = 0      # checkpointed resume point (records)
        # fixed per-batch array capacity so XLA compiles a single program
        fanout = self.assigner.max_windows_per_event()
        cap = cfg.batch_records * fanout
        self._per_worker = -(-cap // cfg.n_workers)

    # -- key dictionary --------------------------------------------------------
    def _key_id(self, key: Any) -> int:
        kid = self._key_ids.get(key)
        if kid is None:
            kid = len(self._id_keys)
            if kid >= self.cfg.num_buckets:
                raise ValueError(
                    f"distinct key count exceeded num_buckets="
                    f"{self.cfg.num_buckets}; raise it (keys seen: {kid})")
            self._key_ids[key] = kid
            self._id_keys.append(key)
        return kid

    # -- batch ingestion -------------------------------------------------------
    def _fold(self, rows: np.ndarray) -> None:
        """Fold admitted [window_slot, key_id, value, valid] rows into the
        carried state through the device step — inside the serverless pool
        so scale-to-zero accounting matches the batch engine's."""
        data = rows.reshape(self.cfg.n_workers, self._per_worker, 4)
        self._carry = self.pool.submit(self._step, data, self._carry)

    # -- window finalization --------------------------------------------------
    def _emit_window(self, window_index: int, slot: int) -> None:
        cfg = self.cfg
        window = self.assigner.window(window_index)
        agg = read_window_slot(self._carry, slot, cfg.num_buckets)
        sums, counts = agg[:, 0], agg[:, 1]
        records: list[tuple[str, Any]] = []
        for kid in np.nonzero(counts > 0)[0]:
            if cfg.aggregation == "count":
                val: Any = int(counts[kid])
            elif cfg.aggregation == "sum":
                val = float(sums[kid])
            else:
                val = float(sums[kid] / counts[kid])
            records.append((str(self._id_keys[kid]), val))
        records.sort(key=lambda kv: kv[0])
        out_key = window_output_key(cfg, window)
        self.store.put(out_key, _encode_records(records))
        self.bus.produce(TOPIC_STREAM_WINDOW,
                         window_event(cfg.job_id, window.start, window.end,
                                      len(records), out_key),
                         key=f"{cfg.job_id}/{window.start}")
        self._carry = clear_window_slot(self._carry, slot, cfg.num_buckets)
        self.tracker.release(window_index)

    def _finalize_ripe(self, report: StreamReport) -> None:
        for window_index, slot in self.tracker.ripe():
            self._emit_window(window_index, slot)
            report.windows_emitted += 1

    # -- checkpoint / restore --------------------------------------------------
    def _save_state(self) -> None:
        """Persist the full streaming state at a batch boundary: carry bytes
        to the object store, tracker + key dictionary + the consumed *record*
        offset to the metadata store.  Record addressing (not batch indices)
        keeps resume correct when the log grows past a previously-partial
        final batch.  A restarted coordinator re-folds at most the batches
        since the last checkpoint; window emissions are idempotent (same
        carry → same bytes), keeping restart effectively exactly-once."""
        carry = np.asarray(self._carry)
        self.store.put(_carry_key(self.cfg.job_id), carry.tobytes())
        self.meta.set(_state_key(self.cfg.job_id), {
            "offset": self._records_consumed,
            "carry_shape": list(carry.shape),
            "carry_dtype": str(carry.dtype),
            "tracker": self.tracker.state_dict(),
            "keys": list(self._id_keys),
        })

    def _restore_state(self) -> int:
        """Load a prior run's checkpoint; returns the record offset to
        resume from (0 when starting fresh)."""
        state = self.meta.get(_state_key(self.cfg.job_id))
        if state is None:
            self._records_consumed = 0
            return 0
        shape = tuple(state["carry_shape"])
        if shape != tuple(self._carry.shape):
            raise ValueError(
                f"checkpointed carry shape {shape} does not match this "
                f"coordinator's {tuple(self._carry.shape)}; the streaming "
                f"config changed under job {self.cfg.job_id}")
        blob = self.store.get(_carry_key(self.cfg.job_id))
        carry = np.frombuffer(blob, dtype=np.dtype(state["carry_dtype"]))
        self._carry = jnp.asarray(carry.reshape(shape))
        self.tracker.load_state_dict(state["tracker"])
        self._id_keys = list(state["keys"])
        self._key_ids = {k: i for i, k in enumerate(self._id_keys)}
        self._records_consumed = int(state["offset"])
        return self._records_consumed

    # -- backpressure ----------------------------------------------------------
    def _autoscale(self, report: StreamReport) -> None:
        lag = self.bus.lag(self.CONSUMER_GROUP, TOPIC_STREAM_BATCH)
        report.max_lag = max(report.max_lag, lag)
        want = self.pool.desired_scale_from_backlog(lag)
        if want > self.pool.replicas():
            self.pool.ensure_scale(want)
            report.scale_events += 1
        elif want < self.pool.replicas():
            if self.pool.reap_idle():
                report.scale_events += 1

    # -- the streaming loop -----------------------------------------------------
    def announce(self, source: StreamSource, start_record: int = 0) -> int:
        """Publish one trigger CloudEvent per available micro-batch — the
        stand-in for a Kafka producer filling the topic ahead of the
        consumer.  The resulting consumer lag drives autoscaling.
        ``start_record`` skips already-processed records on resume so the
        lag signal reflects real backlog, not replayed history.  Uses
        record counts only (``batch_sizes``), so the log's payloads are
        parsed once — by the processing loop, not here."""
        n = 0
        for index, size in enumerate(source.batch_sizes(start_record)):
            self.bus.produce(
                TOPIC_STREAM_BATCH,
                batch_event(self.cfg.job_id, index, size),
                key=f"{self.cfg.job_id}/{index}")
            n += 1
        return n

    def process_batch(self, batch: MicroBatch,
                      report: StreamReport) -> None:
        """One micro-batch round: admit → fold (device) → watermark →
        finalize.  Normally one fused collective per batch; a batch that
        spans more windows than the ring holds (low event rate relative to
        batch size) folds and finalizes mid-batch instead of aborting."""
        cfg = self.cfg
        if len(batch.records) > cfg.batch_records:
            raise ValueError(
                f"micro-batch {batch.index} carries {len(batch.records)} "
                f"records but the coordinator was sized for batch_records="
                f"{cfg.batch_records}; create the StreamSource with "
                f"batch_records <= the coordinator's")
        t0 = time.perf_counter()
        self.bus.poll(self.CONSUMER_GROUP, TOPIC_STREAM_BATCH,
                      timeout=0.01, max_records=1)
        self._autoscale(report)
        late_before = self.tracker.late_dropped
        rows = np.zeros((cfg.n_workers * self._per_worker, 4), np.float32)
        n = 0
        seen = float("-inf")        # stream position within this batch
        for ts, key, value in batch.records:
            report.records_in += 1
            seen = ts if ts > seen else seen
            for widx in self.assigner.assign(ts):
                try:
                    slot = self.tracker.slot_for(widx)
                except LateEventError:
                    # ring full mid-batch: fold what we have, advance the
                    # watermark to the position reached, finalize ripe
                    # windows, then retry (a second failure is a genuine
                    # capacity error and propagates)
                    if n:
                        self._fold(rows)
                        report.records_expanded += n
                        # the dispatched fold may zero-copy-alias the numpy
                        # buffer; a fresh buffer avoids racing the in-flight
                        # computation with our next writes
                        rows = np.zeros_like(rows)
                        n = 0
                    self.tracker.observe(seen)
                    self._finalize_ripe(report)
                    slot = self.tracker.slot_for(widx)
                if slot is None:        # late: window already emitted
                    continue
                rows[n] = (slot, self._key_id(key), value, 1.0)
                n += 1
        report.late_dropped += self.tracker.late_dropped - late_before
        report.records_expanded += n
        self._fold(rows)
        self.tracker.observe(batch.max_event_time)
        self._finalize_ripe(report)
        report.batches += 1
        self._records_consumed += len(batch.records)
        # sparser checkpoints trade restart replay (the log is replayable
        # from the last checkpoint) for hot-path device syncs
        if (batch.index + 1) % self.cfg.checkpoint_interval == 0:
            self._save_state()
        report.batch_latencies.append(time.perf_counter() - t0)

    def run_stream(self, source: StreamSource, *, announce: bool = True,
                   flush: bool = True) -> StreamReport:
        """Consume the whole currently-available log; with ``flush`` also
        finalize the still-open windows at the end (end-of-stream watermark
        → +inf), which a truly continuous deployment would never do."""
        report = StreamReport(self.cfg.job_id)
        t_start = time.perf_counter()
        start = self._restore_state()
        try:
            if announce:
                self.announce(source, start_record=start)
            for batch in source.batches(start_record=start):
                self.process_batch(batch, report)
            if flush:
                # checkpoint BEFORE the artificial end-of-stream watermark:
                # a later run over a grown log must resume with the real
                # watermark, not +inf (which would drop every new event as
                # late); flushed windows then re-finalize idempotently
                if report.batches:
                    self._save_state()
                self.tracker.observe(float("inf"))
                self._finalize_ripe(report)
        except Exception as exc:
            report.error = str(exc)
            raise
        finally:
            report.wall_time = time.perf_counter() - t_start
        return report

    # -- introspection ---------------------------------------------------------
    def checkpointed_offset(self) -> int:
        state = self.meta.get(_state_key(self.cfg.job_id))
        return int(state["offset"]) if state else 0

    def pool_stats(self) -> dict[str, Any]:
        return self.pool.stats()
