"""StreamingCoordinator — continuous MapReduce, one round per micro-batch.

Where ``core.coordinator.Coordinator`` drives a one-shot job to DONE and
terminates, this coordinator runs a long-lived loop: consume the next
micro-batch trigger, fold the batch through a **compiled pipeline program**
(``repro.pipeline.BuiltPipeline`` — the lowered form of the declarative
``Pipeline`` dataflow graph), advance the watermark, and finalize + emit
every window the watermark has passed.  The full streaming state —
consumed record offset, carried window aggregates, watermark/ring (or
session) tracker, key dictionary — checkpoints at batch boundaries
(metadata + object store), so a restarted coordinator resumes exactly
where it stopped, even over a log that has grown since — the streaming
analogue of ``Coordinator.resume_job``.

The coordinator no longer builds its own single plan: the program carries
one compiled ``ExecutionPlan`` per stage chain ("side").  A plain chain
has one side; a windowed join has two, compiled over disjoint channel
pairs of **one shared carry** — left records fold into channels [0, 2),
right into [2, 4), and finalization inner-joins buckets populated on both
sides.  Session windows (``Windowing.session(gap)``) drive the host-wire
fold with a ``SessionTracker`` mapping each open session to a carry *cell*
(slot, bucket), merging bridged sessions on-device.  Fixed windows keep
the PR 2 machinery: on-device fan-out (one row per record, replicated
on-chip), host fan-out as the measured legacy baseline, aggregate or
group-mode reduction, dense or hashed key spaces.

``StreamingConfig`` remains as a deprecated shim: it lowers itself to a
two-node pipeline (``source → key_by → window → reduce → sink``) through
the Pipeline API, so both front doors drive the same program shape.

Restart tightening: on ``_restore_state`` the coordinator lists the
windows already persisted under the job's output prefix; a replayed window
whose bytes match the persisted object is **not** re-written (and not
re-announced), so a crash after an emission no longer causes a duplicate
write — at-least-once becomes effectively exactly-once for unchanged
windows, while a window whose content legitimately changed (a flushed
partial window over a log that since grew) still overwrites.

Scaling is backpressure-driven: the source announces each batch on
``TOPIC_STREAM_BATCH``; the coordinator is a consumer group on that topic
and sizes its mapper pool from the consumer lag (queue depth) instead of a
fixed split count — KEDA's Kafka-lag signal where the batch engine uses
KPA concurrency.
"""

from __future__ import annotations

import io
import math
import time
import uuid
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.autoscaler import AutoscalerConfig, ServerlessPool
from ..core.events import (EventBus, TOPIC_STREAM_BATCH, TOPIC_STREAM_WINDOW,
                           batch_event, window_event)
from ..core.metadata import MetadataStore
from ..core.storage import ObjectStore
from ..core.workers import _encode_records
from ..engine.plan import ExecutionPlan, KeySpace, ReduceSpec, WindowSpec
from ..engine.stages import SEGMENT_REDUCE_KINDS as GROUP_KINDS
from ..engine.stages import RAW_KEY_BITS, fold_key24, host_bucket
from .source import MicroBatch
from .state import LateEventError
from .windows import SlidingWindows, TumblingWindows, Window, WindowAssigner

AGGREGATIONS = ("count", "sum", "mean")
_RAW_KEY_BITS = RAW_KEY_BITS    # raw ids must survive the float32 wire
_MAX_WIRE_INT = 1 << 24  # largest int the float32 wire carries exactly


@dataclass
class StreamingConfig:
    """Stream-job analogue of the batch ``JobConfig`` JSON document.

    .. deprecated::
        ``StreamingConfig`` is now a shim over the declarative Pipeline
        API: ``build_pipeline()`` lowers it to a single-chain record
        pipeline (``repro.pipeline.Pipeline``), and the coordinator drives
        that program.  New call sites should author a ``Pipeline`` —
        it also exposes session windows, windowed joins, top-k, and map
        fusion, which this flat config cannot express.
    """

    num_buckets: int = 128          # key-id space (dense bucket width)
    n_workers: int = 8              # device-engine mesh-axis size
    window_size: float = 60.0       # seconds of event time per window
    window_slide: float | None = None  # None → tumbling; else sliding
    allowed_lateness: float = 0.0   # watermark slack for out-of-order events
    n_slots: int = 8                # in-flight window ring capacity
    batch_records: int = 1024      # micro-batch size bound
    aggregation: str = "count"      # aggregate mode: count | sum | mean
    mode: str = "aggregate"         # aggregate | group (arbitrary reduce_fn)
    reduce_fn: str | Callable = "sum"   # group mode: kind name or callable
    capacity: int = 0               # group mode: per-(worker, slot) records
    key_space: str = "dense"        # dense | hashed (open key domains)
    fanout: str = "device"          # device | host (legacy baseline)
    checkpoint_interval: int = 1    # save restart state every N batches
    output_prefix: str = "stream-output/"
    backend: str = "vmap"
    job_id: str = field(default_factory=lambda: "s" + uuid.uuid4().hex[:11])

    def validate(self) -> None:
        if self.mode not in ("aggregate", "group"):
            raise ValueError("mode must be 'aggregate' or 'group'")
        if self.mode == "aggregate":
            if self.aggregation not in AGGREGATIONS:
                raise ValueError(f"aggregation must be one of {AGGREGATIONS}")
            if self.num_buckets % self.n_workers != 0:
                raise ValueError(
                    "num_buckets must divide by n_workers so window "
                    "slices stay aligned to the scattered carry")
        else:
            if self.capacity < 1:
                raise ValueError("group mode needs capacity >= 1 (records "
                                 "buffered per worker per window slot)")
            if self.fanout != "device":
                raise ValueError("group mode runs with fanout='device'")
            if isinstance(self.reduce_fn, str) \
                    and self.reduce_fn not in GROUP_KINDS:
                raise ValueError(f"reduce_fn must be a callable or one of "
                                 f"{GROUP_KINDS}")
        if self.key_space not in ("dense", "hashed"):
            raise ValueError("key_space must be 'dense' or 'hashed'")
        if self.fanout not in ("device", "host"):
            raise ValueError("fanout must be 'device' or 'host'")
        if self.n_slots < 2:
            raise ValueError("need >= 2 window slots (one closing, one open)")
        if self.checkpoint_interval < 1:
            raise ValueError("checkpoint_interval must be >= 1")
        if self.window_slide is not None and self.window_slide > self.window_size:
            raise ValueError("slide must not exceed window size")
        # the ring must hold every window that can be open at one instant:
        # those covering (watermark, watermark + size + lateness]
        step = self.window_slide or self.window_size
        need = math.ceil((self.window_size + self.allowed_lateness) / step) + 1
        if need > self.n_slots:
            raise ValueError(
                f"n_slots={self.n_slots} cannot hold the "
                f"window_size+allowed_lateness span; need >= {need} slots "
                f"for size={self.window_size}, slide={step}, "
                f"lateness={self.allowed_lateness}")

    def assigner(self) -> WindowAssigner:
        if self.window_slide is None:
            return TumblingWindows(self.window_size)
        return SlidingWindows(self.window_size, self.window_slide)

    def plan(self) -> ExecutionPlan:
        """The streaming job as a point in the execution-plan space."""
        if self.key_space == "hashed":
            keys = KeySpace.hashed(self.num_buckets, track_collisions=False)
        else:
            keys = KeySpace.dense(self.num_buckets)
        window = WindowSpec(size=self.window_size, slide=self.window_slide,
                            n_slots=self.n_slots,
                            fanout_on_device=self.fanout == "device")
        reduce = ReduceSpec(mode=self.mode, reduce_fn=self.reduce_fn,
                            capacity=self.capacity)
        return ExecutionPlan(key_space=keys, reduce=reduce,
                             n_workers=self.n_workers, window=window)

    def build_pipeline(self):
        """Lower this flat config to the compiled pipeline program the
        coordinator drives — the deprecation shim's whole body."""
        from ..pipeline import Pipeline, Windowing
        if self.window_slide is None:
            w = Windowing.tumbling(self.window_size)
        else:
            w = Windowing.sliding(self.window_size, self.window_slide)
        p = (Pipeline.from_source(batch_records=self.batch_records)
             .key_by().window(w))
        if self.mode == "aggregate":
            p = p.reduce(self.aggregation)
        else:
            p = p.reduce(self.reduce_fn, mode="group",
                         capacity=self.capacity)
        p = p.sink(self.output_prefix)
        return p.build(num_buckets=self.num_buckets,
                       n_workers=self.n_workers, n_slots=self.n_slots,
                       key_space=self.key_space, fanout=self.fanout,
                       allowed_lateness=self.allowed_lateness,
                       backend=self.backend,
                       checkpoint_interval=self.checkpoint_interval,
                       batch_records=self.batch_records,
                       job_id=self.job_id,
                       output_prefix=self.output_prefix)


@dataclass
class StreamReport:
    """Rolling accounting for a streaming run — the Fig. 6/7 quantities
    reinterpreted for sustained throughput."""

    job_id: str
    batches: int = 0
    records_in: int = 0             # raw events consumed
    records_expanded: int = 0       # after window fan-out (sliding > 1×)
    late_dropped: int = 0
    windows_emitted: int = 0
    wall_time: float = 0.0
    batch_latencies: list[float] = field(default_factory=list)
    max_lag: int = 0                # worst backpressure observed
    scale_events: int = 0           # pool resizes driven by lag
    hash_collisions: int = 0        # hashed key space: keys sharing a bucket
    capacity_dropped: int = 0       # group mode: window-buffer overflow
    writes_skipped: int = 0         # restart: windows already persisted
    error: str | None = None

    @property
    def records_per_sec(self) -> float:
        return self.records_in / self.wall_time if self.wall_time else 0.0

    @property
    def mean_batch_latency(self) -> float:
        ls = self.batch_latencies
        return sum(ls) / len(ls) if ls else 0.0


def window_output_key(cfg, window: Window) -> str:
    """Object key for a fixed window's emission.  ``cfg`` is anything with
    ``output_prefix`` and ``job_id`` — a ``StreamingConfig`` or a
    ``BuiltPipeline``."""
    return (f"{cfg.output_prefix.rstrip('/')}/{cfg.job_id}/"
            f"window-{window.start:.3f}-{window.end:.3f}")


def session_output_key(cfg, label: str, start: float, end: float) -> str:
    """Object key for a finalized session — the key's label is part of the
    address because two keys' sessions may share identical bounds."""
    return (f"{cfg.output_prefix.rstrip('/')}/{cfg.job_id}/"
            f"session-{label}-{start:.3f}-{end:.3f}")


def _state_key(job_id: str) -> str:
    return f"stream/{job_id}/state"


def _carry_key(job_id: str) -> str:
    return f"jobs/{job_id}/stream/carry"


class StreamingCoordinator:
    """Long-lived coordinator: micro-batch rounds over a continuous stream,
    driving one compiled pipeline program."""

    CONSUMER_GROUP = "streaming-coordinator"

    def __init__(self, store: ObjectStore, meta: MetadataStore,
                 cfg: StreamingConfig | None = None,
                 bus: EventBus | None = None,
                 autoscaler: AutoscalerConfig | None = None, *,
                 program=None) -> None:
        if (cfg is None) == (program is None):
            raise ValueError("pass exactly one of cfg (deprecated shim) or "
                             "program (a BuiltPipeline)")
        if cfg is not None:
            cfg.validate()
            program = cfg.build_pipeline()
        self.store = store
        self.meta = meta
        self.cfg = cfg                  # legacy handle (None for programs)
        self.prog = program
        self.bus = bus or EventBus()
        self.assigner = program.assigner()      # None for session windows
        self.pool = ServerlessPool(
            "stream-mapper", autoscaler or AutoscalerConfig(
                max_scale=program.n_workers))
        # each side's plan was compiled once at build(); a join's two plans
        # share one carry through disjoint channel pairs
        self._carry = program.sides[0].compiled.init_carry()
        self.tracker = program.make_tracker()
        self._is_session = program.window.is_session
        # bounded key→bucket-id dictionary (the data layer's vocab analogue)
        self._key_ids: dict[Any, int] = {}
        self._id_keys: list[Any] = []
        # hashed key space: raw-id cache + bucket → first-seen keys (labels)
        self._raw_ids: dict[Any, int] = {}
        self._bucket_keys: dict[int, list] = {}
        self._hash_collisions = 0
        self._window_base = 0           # per-batch wire-index rebase
        self._records_consumed = 0      # checkpointed resume point (records)
        self._persisted: set[str] = set()   # restart: already-written windows
        # fixed per-batch array capacity so XLA compiles a single program:
        # device fan-out ships one row per record; host fan-out pre-expands;
        # sessions ship host-wire rows with fan-out 1
        if self._is_session:
            cap, self._row_width = program.batch_records, 4
        elif program.fanout == "device":
            cap, self._row_width = program.batch_records, 5
        else:
            fanout = self.assigner.max_windows_per_event()
            cap, self._row_width = program.batch_records * fanout, 4
        self._per_worker = -(-cap // program.n_workers)

    # -- key dictionary --------------------------------------------------------
    def _key_id(self, key: Any) -> int:
        if self.prog.key_space == "hashed":
            return self._raw_key_id(key)
        kid = self._key_ids.get(key)
        if kid is None:
            kid = len(self._id_keys)
            if kid >= self.prog.num_buckets:
                raise ValueError(
                    f"distinct key count exceeded num_buckets="
                    f"{self.prog.num_buckets}; raise it (keys seen: {kid}) "
                    f"or open the domain with key_space='hashed'")
            self._key_ids[key] = kid
            self._id_keys.append(key)
        return kid

    def _raw_key_id(self, key: Any) -> int:
        """Open domain: fold the key to its raw wire id (the engine's
        ``fold_key24``), remember which keys landed in which bucket so
        emissions stay labeled and collisions are counted instead of
        raising."""
        raw = self._raw_ids.get(key)
        if raw is None:
            raw = fold_key24(key)
            self._raw_ids[key] = raw
            seen = self._bucket_keys.setdefault(
                host_bucket(raw, self.prog.num_buckets), [])
            if seen and key not in seen:
                self._hash_collisions += 1
            if key not in seen:
                seen.append(key)
        return raw

    def _bucket_of(self, kid: int) -> int:
        """Host-side bucket for a wire key id — the device folds the same
        id through ``device_hash``, and ``host_bucket`` mirrors it exactly
        (they share the murmur finalizer), so labels cannot drift."""
        if self.prog.key_space == "dense":
            return kid
        return host_bucket(kid, self.prog.num_buckets)

    def _label(self, kid: int) -> str:
        """Output key for bucket/key id ``kid``."""
        if self.prog.key_space == "dense":
            return str(self._id_keys[kid])
        seen = self._bucket_keys.get(kid)
        if not seen:
            return f"bucket-{kid}"
        if len(seen) == 1:
            return str(seen[0])
        return f"bucket-{kid}[{'|'.join(sorted(str(k) for k in seen))}]"

    # -- record transforms -----------------------------------------------------
    def _transformed(self, batch: MicroBatch, report: StreamReport
                     ) -> list[tuple[float, Any, float, int]]:
        """Apply each side's fused map chain and key/value extractors;
        returns side-tagged ``(ts, key, value, side)`` records."""
        recs: list[tuple[float, Any, float, int]] = []
        for rec in batch.records:
            report.records_in += 1
            side = int(rec[3]) if len(rec) > 3 else 0
            sp = self.prog.sides[side]
            if sp.transform is None:
                out = (rec[:3],)
            else:
                o = sp.transform(tuple(rec[:3]))
                out = () if o is None else \
                    ((o,) if isinstance(o, tuple) else tuple(o))
            for r in out:
                recs.append((float(r[0]), sp.key_fn(r),
                             float(sp.value_fn(r)), side))
        # flat-maps may expand a batch past batch_records: grow the wire
        # buffer (and retrace the step once per growth) instead of failing,
        # so the same graph runs in batch mode, where one "micro-batch" is
        # the whole input
        if self._is_session or self.prog.fanout == "device":
            needed = len(recs)
        else:
            needed = len(recs) * self.assigner.max_windows_per_event()
        per = -(-needed // self.prog.n_workers)
        if per > self._per_worker:
            self._per_worker = per
        return recs

    # -- batch ingestion -------------------------------------------------------
    def _wire(self, rows: np.ndarray, width: int) -> np.ndarray:
        """Rows in the backend's wire layout: vmap batches the worker axis,
        shard_map shards the flat global array over the mesh axis."""
        if self.prog.backend == "vmap":
            return rows.reshape(self.prog.n_workers, self._per_worker, width)
        return rows

    def _fold_device(self, rows: np.ndarray, report: StreamReport,
                     side: int = 0) -> None:
        """Fold one-row-per-record [last_window, n_windows, key, value,
        valid] rows through one side's compiled step; the device fans out,
        masks late pairs against the watermark bound, and returns the
        accounting.  Window indices on the wire are rebased by the
        per-batch ``_window_base`` (a multiple of ``n_slots``, so modular
        slots are unchanged) to stay exact in float32 at any absolute
        event time."""
        data = self._wire(rows, 5)
        bound = self.tracker.min_admissible() - self._window_base
        bound = max(min(bound, 2 ** 31 - 1), -(2 ** 31))
        self._carry, stats = self.pool.submit(
            self.prog.sides[side].compiled.step, data, self._carry, bound)
        late, expanded, dropped = (int(x) for x in np.asarray(stats))
        self.tracker.note_late(late)
        report.records_expanded += expanded
        report.capacity_dropped += dropped

    def _fold_host(self, rows: np.ndarray) -> None:
        """Host-wire fold: [window_slot, key, value, valid] rows whose slot
        was assigned host-side (legacy host fan-out, or session cells)."""
        data = self._wire(rows, 4)
        self._carry, _ = self.pool.submit(
            self.prog.sides[0].compiled.step, data, self._carry)

    # -- window finalization --------------------------------------------------
    def _put_window(self, out_key: str, records: list, start: float,
                    end: float, report: StreamReport) -> None:
        """Persist one finalized window, idempotently across restarts: a
        window already in the store with identical bytes (a replayed
        emission from before the crash) is skipped, not re-written; changed
        bytes (a flushed partial window over a since-grown log) overwrite."""
        blob = _encode_records(records)
        if out_key in self._persisted and self.store.get(out_key) == blob:
            report.writes_skipped += 1
            return
        self.store.put(out_key, blob)
        self.bus.produce(TOPIC_STREAM_WINDOW,
                         window_event(self.prog.job_id, start, end,
                                      len(records), out_key),
                         key=f"{self.prog.job_id}/{start}")

    def _aggregate_value(self, kind: str, total: float, count: float) -> Any:
        if kind == "count":
            return int(count)
        if kind == "sum":
            return float(total)
        return float(total / count)

    def _window_records(self, slot: int) -> list[tuple[str, Any]]:
        """One finalized fixed window's output records, per the program's
        emission spec."""
        emit = self.prog.emit
        compiled = self.prog.sides[0].compiled
        records: list[tuple[str, Any]] = []
        if emit.kind == "group":
            gk, gv, gvalid = compiled.finalize_slot(self._carry, slot)
            records = [(self._label(int(k)), float(v))
                       for k, v, ok in zip(gk, gv, gvalid) if ok]
            records.sort(key=lambda kv: kv[0])
        elif emit.kind == "top_k":
            ids, _vals, valid = compiled.top_k_slot(self._carry, slot,
                                                    emit.rank_by)
            agg = compiled.read_slot(self._carry, slot)
            for kid in ids[valid]:
                records.append((self._label(int(kid)), self._aggregate_value(
                    emit.aggregation, agg[kid, 0], agg[kid, 1])))
            # rank order, not label order: the k heaviest keys, heaviest
            # first — deterministic (top_k ties break on bucket id)
        elif emit.kind == "join":
            agg = compiled.read_slot(self._carry, slot)
            lkind, rkind = emit.join_aggs
            both = np.nonzero((agg[:, 1] > 0) & (agg[:, 3] > 0))[0]
            for kid in both:
                records.append((self._label(int(kid)), [
                    self._aggregate_value(lkind, agg[kid, 0], agg[kid, 1]),
                    self._aggregate_value(rkind, agg[kid, 2], agg[kid, 3]),
                ]))
            records.sort(key=lambda kv: kv[0])
        else:
            agg = compiled.read_slot(self._carry, slot)
            sums, counts = agg[:, 0], agg[:, 1]
            for kid in np.nonzero(counts > 0)[0]:
                records.append((self._label(int(kid)), self._aggregate_value(
                    emit.aggregation, sums[kid], counts[kid])))
            records.sort(key=lambda kv: kv[0])
        return records

    def _emit_window(self, window_index: int, slot: int,
                     report: StreamReport) -> None:
        window = self.assigner.window(window_index)
        records = self._window_records(slot)
        self._put_window(window_output_key(self.prog, window), records,
                         window.start, window.end, report)
        self._carry = self.prog.sides[0].compiled.clear_slot(self._carry,
                                                             slot)
        self.tracker.release(window_index)

    def _emit_session(self, session, report: StreamReport) -> None:
        compiled = self.prog.sides[0].compiled
        cell = compiled.read_cell(self._carry, session.slot, session.bucket)
        label = self._label(session.bucket)
        records: list[tuple[str, Any]] = []
        if cell[1] > 0:
            records.append((label, self._aggregate_value(
                self.prog.emit.aggregation, cell[0], cell[1])))
        out_key = session_output_key(self.prog, label, session.start,
                                     session.end)
        self._put_window(out_key, records, session.start, session.end,
                         report)
        self._carry = compiled.clear_cell(self._carry, session.slot,
                                          session.bucket)
        self.tracker.release(session)

    def _finalize_ripe(self, report: StreamReport) -> None:
        if self._is_session:
            for session in self.tracker.ripe():
                self._emit_session(session, report)
                report.windows_emitted += 1
        else:
            for window_index, slot in self.tracker.ripe():
                self._emit_window(window_index, slot, report)
                report.windows_emitted += 1

    # -- checkpoint / restore --------------------------------------------------
    def _save_state(self) -> None:
        """Persist the full streaming state at a batch boundary: carry
        leaves to the object store, tracker + key dictionary + the consumed
        *record* offset to the metadata store.  Record addressing (not batch
        indices) keeps resume correct when the log grows past a
        previously-partial final batch.  A restarted coordinator re-folds at
        most the batches since the last checkpoint; window emissions are
        idempotent (same carry → same bytes) and replayed writes of
        already-persisted windows are skipped (``_put_window``), keeping
        restart effectively exactly-once."""
        leaves = [np.asarray(leaf)
                  for leaf in jax.tree_util.tree_leaves(self._carry)]
        buf = io.BytesIO()
        np.savez(buf, **{f"leaf{i}": leaf for i, leaf in enumerate(leaves)})
        self.store.put(_carry_key(self.prog.job_id), buf.getvalue())
        self.meta.set(_state_key(self.prog.job_id), {
            "offset": self._records_consumed,
            "carry_shapes": [list(leaf.shape) for leaf in leaves],
            "tracker": self.tracker.state_dict(),
            "keys": list(self._id_keys),
            "bucket_keys": [[kid, keys]
                            for kid, keys in self._bucket_keys.items()],
            "hash_collisions": self._hash_collisions,
        })

    def _restore_state(self) -> int:
        """Load a prior run's checkpoint; returns the record offset to
        resume from (0 when starting fresh).  Also consults the output
        prefix for windows the prior run already persisted, so the replay
        of the uncheckpointed tail does not re-write them — including a
        crash before the *first* checkpoint, where the whole log replays."""
        out_prefix = (f"{self.prog.output_prefix.rstrip('/')}/"
                      f"{self.prog.job_id}/")
        self._persisted = {m.key for m in self.store.list_objects(out_prefix)}
        state = self.meta.get(_state_key(self.prog.job_id))
        if state is None:
            self._records_consumed = 0
            return 0
        if "carry_shapes" not in state:
            raise ValueError(
                f"checkpoint for job {self.prog.job_id} predates the "
                f"execution-plan carry format (PR 2); restart the stream "
                f"under a fresh job_id or replay it from the log")
        leaves, treedef = jax.tree_util.tree_flatten(self._carry)
        shapes = [tuple(s) for s in state["carry_shapes"]]
        if shapes != [leaf.shape for leaf in leaves]:
            raise ValueError(
                f"checkpointed carry shapes {shapes} do not match this "
                f"coordinator's {[leaf.shape for leaf in leaves]}; the "
                f"streaming config changed under job {self.prog.job_id}")
        blob = self.store.get(_carry_key(self.prog.job_id))
        with np.load(io.BytesIO(blob)) as loaded:
            restored = [jnp.asarray(loaded[f"leaf{i}"])
                        for i in range(len(leaves))]
        self._carry = jax.tree_util.tree_unflatten(treedef, restored)
        self.tracker.load_state_dict(state["tracker"])
        self._id_keys = list(state["keys"])
        self._key_ids = {k: i for i, k in enumerate(self._id_keys)}
        self._bucket_keys = {int(kid): list(keys)
                             for kid, keys in state.get("bucket_keys", [])}
        self._raw_ids = {k: fold_key24(k)
                         for keys in self._bucket_keys.values() for k in keys}
        self._hash_collisions = int(state.get("hash_collisions", 0))
        self._records_consumed = int(state["offset"])
        return self._records_consumed

    # -- backpressure ----------------------------------------------------------
    def _autoscale(self, report: StreamReport) -> None:
        lag = self.bus.lag(self.CONSUMER_GROUP, TOPIC_STREAM_BATCH)
        report.max_lag = max(report.max_lag, lag)
        want = self.pool.desired_scale_from_backlog(lag)
        if want > self.pool.replicas():
            self.pool.ensure_scale(want)
            report.scale_events += 1
        elif want < self.pool.replicas():
            if self.pool.reap_idle():
                report.scale_events += 1

    # -- the streaming loop -----------------------------------------------------
    def announce(self, source, start_record: int = 0) -> int:
        """Publish one trigger CloudEvent per available micro-batch — the
        stand-in for a Kafka producer filling the topic ahead of the
        consumer.  The resulting consumer lag drives autoscaling.
        ``start_record`` skips already-processed records on resume so the
        lag signal reflects real backlog, not replayed history.  Uses
        record counts only (``batch_sizes``), so the log's payloads are
        parsed once — by the processing loop, not here."""
        n = 0
        for index, size in enumerate(source.batch_sizes(start_record)):
            self.bus.produce(
                TOPIC_STREAM_BATCH,
                batch_event(self.prog.job_id, index, size),
                key=f"{self.prog.job_id}/{index}")
            n += 1
        return n

    def _ingest_device(self, batch: MicroBatch,
                       report: StreamReport) -> None:
        """Device fan-out ingestion: one 5-column row per record; window
        *indices* are assigned host-side in float64 (bit-identical to the
        host-fan-out assigner) but the event × window expansion happens
        on-chip.  A batch that spans more windows than the ring holds folds
        and finalizes mid-batch instead of aborting — splitting the
        triggering record's coverage so pairs admitted before the mid-batch
        watermark advance still land, exactly like the host path.  Each
        record folds through its side's plan; a join's two sides share the
        carry, so one pass interleaves them safely."""
        prog = self.prog
        recs = self._transformed(batch, report)
        if not recs:
            self.tracker.observe(batch.max_event_time)
            self._finalize_ripe(report)
            return
        w0 = self.assigner.window(0)
        step = self.assigner.window(1).start - w0.start
        ts = np.array([r[0] for r in recs], np.float64)
        rel = ts - w0.start
        last = np.floor(rel / step).astype(np.int64)
        if prog.window.slide is None:
            first = last
        else:
            first = np.floor((rel - w0.size) / step).astype(np.int64) + 1
        # rebase wire indices so they stay exact in float32 at any absolute
        # event time; a multiple of n_slots keeps w % n_slots unchanged
        base = (int(first.min()) // prog.n_slots) * prog.n_slots
        if int(last.max()) - base >= _MAX_WIRE_INT:
            raise ValueError(
                f"micro-batch {batch.index} spans "
                f"{int(last.max()) - base} windows, beyond the float32 "
                f"wire's exact-integer range; reduce batch_records or "
                f"raise the window slide")
        self._window_base = base
        n_sides = len(prog.sides)
        shape = (prog.n_workers * self._per_worker, 5)
        rows = [np.zeros(shape, np.float32) for _ in range(n_sides)]
        n = [0] * n_sides

        def fold_staged() -> None:
            # the dispatched fold may zero-copy-alias the numpy buffer; a
            # fresh buffer avoids racing the in-flight computation with our
            # next writes
            for s in range(n_sides):
                if n[s]:
                    self._fold_device(rows[s], report, s)
                    rows[s] = np.zeros(shape, np.float32)
                    n[s] = 0

        seen = float("-inf")        # stream position within this batch
        for i, (tsi, key, value, side) in enumerate(recs):
            seen = tsi if tsi > seen else seen
            kid = self._key_id(key)
            lo, hi = int(first[i]), int(last[i])
            start = lo
            for widx in range(lo, hi + 1):
                if widx in self.tracker.active or self.tracker.is_late(widx):
                    continue        # device masks + counts the late pairs
                try:
                    self.tracker.slot_for(widx)
                except LateEventError:
                    # ring full mid-batch: ship this record's already-safe
                    # window span, fold what we have, advance the watermark
                    # to the position reached, finalize ripe windows, then
                    # retry (a second failure is a genuine capacity error
                    # and propagates)
                    if widx > start:
                        rows[side][n[side]] = (widx - 1 - base, widx - start,
                                               kid, value, 1.0)
                        n[side] += 1
                        start = widx
                    fold_staged()
                    self.tracker.observe(seen)
                    self._finalize_ripe(report)
                    if not self.tracker.is_late(widx):
                        self.tracker.slot_for(widx)
                    # else: the watermark advance closed widx; the device
                    # masks + counts the pair (slot_for would double-count)
            if hi >= start:
                rows[side][n[side]] = (hi - base, hi - start + 1, kid, value,
                                       1.0)
                n[side] += 1
        for s in range(n_sides):
            self._fold_device(rows[s], report, s)
        self.tracker.observe(batch.max_event_time)
        self._finalize_ripe(report)

    def _ingest_host(self, batch: MicroBatch, report: StreamReport) -> None:
        """Legacy host fan-out: expand every record into one row per
        containing window on the host (numpy), the PR 1 baseline the
        device path is benchmarked against."""
        prog = self.prog
        recs = self._transformed(batch, report)
        rows = np.zeros((prog.n_workers * self._per_worker, 4), np.float32)
        n = 0
        seen = float("-inf")
        for ts, key, value, _side in recs:
            seen = ts if ts > seen else seen
            for widx in self.assigner.assign(ts):
                try:
                    slot = self.tracker.slot_for(widx)
                except LateEventError:
                    if n:
                        self._fold_host(rows)
                        report.records_expanded += n
                        rows = np.zeros_like(rows)
                        n = 0
                    self.tracker.observe(seen)
                    self._finalize_ripe(report)
                    slot = self.tracker.slot_for(widx)
                if slot is None:        # late: window already emitted
                    continue
                rows[n] = (slot, self._key_id(key), value, 1.0)
                n += 1
        report.records_expanded += n
        self._fold_host(rows)
        self.tracker.observe(batch.max_event_time)
        self._finalize_ripe(report)

    def _ingest_session(self, batch: MicroBatch,
                        report: StreamReport) -> None:
        """Session ingestion: the tracker assigns each admitted event a
        carry cell (slot, bucket), merging bridged sessions; rows ship on
        the host wire with fan-out 1.  Cell merges apply *after* folding
        the rows already staged for the source cells, so the carry and the
        tracker never disagree about where a session lives."""
        compiled = self.prog.sides[0].compiled
        recs = self._transformed(batch, report)
        shape = (self.prog.n_workers * self._per_worker, 4)
        rows = np.zeros(shape, np.float32)
        n = 0
        seen = float("-inf")

        def fold_staged() -> None:
            nonlocal rows, n
            if n:
                report.records_expanded += n
                self._fold_host(rows)
                rows = np.zeros(shape, np.float32)
                n = 0

        for tsi, key, value, _side in recs:
            seen = tsi if tsi > seen else seen
            kid = self._key_id(key)
            bucket = self._bucket_of(kid)
            try:
                admitted = self.tracker.admit(bucket, tsi)
            except LateEventError:
                # every slot holds an open session for this bucket: fold,
                # advance the watermark to the position reached, finalize,
                # retry (a second failure is a genuine capacity error)
                fold_staged()
                self.tracker.observe(seen)
                self._finalize_ripe(report)
                admitted = self.tracker.admit(bucket, tsi)
            if admitted is None:
                continue                # late: session already emitted
            slot, merges = admitted
            if merges:
                fold_staged()
                for src, dst in merges:
                    self._carry = compiled.merge_cell(self._carry, src, dst,
                                                      bucket)
            rows[n] = (slot, kid, value, 1.0)
            n += 1
        fold_staged()
        self.tracker.observe(batch.max_event_time)
        self._finalize_ripe(report)

    def process_batch(self, batch: MicroBatch,
                      report: StreamReport) -> None:
        """One micro-batch round: admit → fold (device) → watermark →
        finalize.  Normally one fused collective per batch per side; a
        batch that spans more windows than the ring holds (low event rate
        relative to batch size) folds and finalizes mid-batch instead of
        aborting."""
        prog = self.prog
        if len(batch.records) > prog.batch_records:
            raise ValueError(
                f"micro-batch {batch.index} carries {len(batch.records)} "
                f"records but the coordinator was sized for batch_records="
                f"{prog.batch_records}; create the StreamSource with "
                f"batch_records <= the coordinator's")
        t0 = time.perf_counter()
        self.bus.poll(self.CONSUMER_GROUP, TOPIC_STREAM_BATCH,
                      timeout=0.01, max_records=1)
        self._autoscale(report)
        late_before = self.tracker.late_dropped
        if self._is_session:
            self._ingest_session(batch, report)
        elif prog.fanout == "device":
            self._ingest_device(batch, report)
        else:
            self._ingest_host(batch, report)
        report.late_dropped += self.tracker.late_dropped - late_before
        report.hash_collisions = self._hash_collisions
        report.batches += 1
        self._records_consumed += len(batch.records)
        # sparser checkpoints trade restart replay (the log is replayable
        # from the last checkpoint) for hot-path device syncs; interval 0
        # disables checkpointing entirely (the batch-mode drive)
        if prog.checkpoint_interval and \
                (batch.index + 1) % prog.checkpoint_interval == 0:
            self._save_state()
        report.batch_latencies.append(time.perf_counter() - t0)

    def run_stream(self, source, *, announce: bool = True,
                   flush: bool = True) -> StreamReport:
        """Consume the whole currently-available log; with ``flush`` also
        finalize the still-open windows at the end (end-of-stream watermark
        → +inf), which a truly continuous deployment would never do."""
        report = StreamReport(self.prog.job_id)
        t_start = time.perf_counter()
        start = self._restore_state()
        try:
            if announce:
                self.announce(source, start_record=start)
            for batch in source.batches(start_record=start):
                self.process_batch(batch, report)
            if flush:
                # checkpoint BEFORE the artificial end-of-stream watermark:
                # a later run over a grown log must resume with the real
                # watermark, not +inf (which would drop every new event as
                # late); flushed windows then re-finalize idempotently
                if report.batches and self.prog.checkpoint_interval:
                    self._save_state()
                self.tracker.observe(float("inf"))
                self._finalize_ripe(report)
        except Exception as exc:
            report.error = str(exc)
            raise
        finally:
            report.wall_time = time.perf_counter() - t_start
        return report

    # -- introspection ---------------------------------------------------------
    def checkpointed_offset(self) -> int:
        state = self.meta.get(_state_key(self.prog.job_id))
        return int(state["offset"]) if state else 0

    def pool_stats(self) -> dict[str, Any]:
        return self.pool.stats()


def _fnv24(key: Any) -> int:
    """Deprecated alias — the helper moved to ``engine.stages.fold_key24``
    so host and device key folding share one source of truth."""
    warnings.warn("_fnv24 moved to repro.engine.stages.fold_key24",
                  DeprecationWarning, stacklevel=2)
    return fold_key24(key)


def _murmur_bucket(raw: int, num_buckets: int) -> int:
    """Deprecated alias — see ``engine.stages.host_bucket``."""
    warnings.warn("_murmur_bucket moved to repro.engine.stages.host_bucket",
                  DeprecationWarning, stacklevel=2)
    return host_bucket(raw, num_buckets)
