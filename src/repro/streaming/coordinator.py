"""StreamingCoordinator — continuous MapReduce, one round per micro-batch.

Where ``core.coordinator.Coordinator`` drives a one-shot job to DONE and
terminates, this coordinator runs a long-lived loop: consume the next
micro-batch trigger, fold the batch through a **compiled pipeline program**
(``repro.pipeline.BuiltPipeline`` — the lowered form of the declarative
``Pipeline`` dataflow graph), advance the watermark, and finalize + emit
every window the watermark has passed.  The full streaming state —
consumed record offset, carried window aggregates (all stages' carries as
one pytree), watermark/ring (or session) trackers, key dictionaries —
checkpoints at batch boundaries (metadata + object store), so a restarted
coordinator resumes exactly where it stopped, even over a log that has
grown since — the streaming analogue of ``Coordinator.resume_job``.

The program is a **stage DAG** (``BuiltPipeline.stages`` in topological
order, wired by ``BuiltPipeline.edges``).  A plain chain has one stage; a
windowed join has one stage with two sides, compiled over disjoint channel
pairs of **one shared carry** — left records fold into channels [0, 2),
right into [2, 4), and finalization inner-joins keys populated on both
sides (by label for dense joins, whose sides may size their key spaces
independently; by bucket for hashed joins).  A multi-stage graph —
``reduce → map → window → reduce`` — runs as a *plan cascade*: when stage
N's watermark finalizes a window, the window's aggregates become each
successor's input batch through a **carry handoff**, one delivery per
out-*edge* — a ``tee``'d stage fans a single finalized window out to every
branch, each edge with its own transport and its own bucket →
next-key-id relabel table.  Edges with no host transform re-key/re-window
entirely on device (``CompiledStreamAggregate.handoff_rows``: the
finalized slot is gathered, relabeled through the edge's host-maintained
table, stamped with the re-windowed span, and folded by the destination
plan's step — the aggregates never visit the host); edges with an
inter-stage map or custom ``key_by`` materialize the same records
host-side and feed them through the ordinary ingestion path.  Fixed
windows finalize in start order, so every successor sees a monotone
event-time feed — batch and streaming replays fold in the same order and
stay bit-identical.  Finalization runs as one forward sweep over the
topologically ordered stages, and a stage with several inputs (a join
over multi-stage sides) advances its watermark to the *minimum* over its
input channels — a window never closes while a lagging input can still
feed it.

Session windows (``Windowing.session(gap)``) drive the host-wire fold with
a ``SessionTracker`` mapping each open session to a carry *cell*
(slot, bucket), merging bridged sessions on-device; they run in the final
position of single-stage pipelines (sessions finalize out of start order,
which would break the deterministic multi-stage replay).  Fixed windows
keep the PR 2 machinery: on-device fan-out (one row per record, replicated
on-chip), host fan-out as the measured legacy baseline, aggregate or
group-mode reduction, dense or hashed key spaces.

Late-drop accounting has exactly one writer: ``tracker.note_late``.  The
admission methods (``slot_for`` / ``admit``) return ``None`` for a late
pair without counting; the coordinator counts each host-dropped pair once,
and the device fan-out's masked-pair count (for pairs that ride the wire
inside a record's window span) flows back through the same method.  A pair
is dropped on one path or the other, never both.

The coordinator drives exactly one program shape: a ``BuiltPipeline``.
(The flat ``StreamingConfig`` shim that lowered itself onto the Pipeline
API was removed in PR 8, as its deprecation message scheduled — author a
``repro.pipeline.Pipeline`` and drive it with ``BuiltPipeline.run(...)``.)

Restart tightening: on ``_restore_state`` the coordinator lists the
windows already persisted under the job's output prefix; a replayed window
whose bytes match the persisted object is **not** re-written (and not
re-announced), so a crash after an emission no longer causes a duplicate
write — at-least-once becomes effectively exactly-once for unchanged
windows, while a window whose content legitimately changed (a flushed
partial window over a log that since grew) still overwrites.  The same
holds across stages: replayed handoffs re-fold into restored carries that
predate them, so second-stage windows are neither lost nor duplicated.

Scaling is backpressure-driven: the source announces each batch on
``TOPIC_STREAM_BATCH``; the coordinator is a consumer group on that topic
and sizes its mapper pool from the consumer lag (queue depth) instead of a
fixed split count — KEDA's Kafka-lag signal where the batch engine uses
KPA concurrency.

The drive loop is a **pipelined scheduler** (``RunOptions``) with three
lanes.  *Prepare*: a background thread reads and host-prepares micro-batch
N+1 (source read, record wiring through the fused map chains) while the
device folds batch N — key-table lookups and ring admission stay on the
main thread, strictly in batch order, so key-id assignment (and with it
every output byte) is identical with overlap on or off.  *Fold*: device
steps dispatch asynchronously (JAX async dispatch) and donate the carry
buffer (``donate_argnums``), so sibling tee branches' handoff folds queue
back-to-back on the device with no host round trip between them.  *Drain*:
the per-fold device→host stats reads (late/expanded/dropped counters) are
deferred to the micro-batch boundary and drained in one pass, and window
emissions within one finalization sweep stage into a single
``ObjectStore.put_many`` round trip.  Checkpoints snapshot at micro-batch
barriers only, after the drain and the sink flush — a crash mid-prefetch
(batch N+1 prepared but unconsumed) replays from the barrier exactly like
a crash in the synchronous loop.
"""

from __future__ import annotations

import io
import math
import queue
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.lanes import lane
from ..core.autoscaler import AutoscalerConfig, ServerlessPool
from ..core.events import (EventBus, TOPIC_STREAM_BATCH, TOPIC_STREAM_WINDOW,
                           batch_event, window_event)
from ..core.metadata import MetadataStore
from ..core.storage import ObjectStore
from ..core.workers import _encode_records
from ..engine.stages import RAW_KEY_BITS, fold_key24, host_bucket
from .source import MicroBatch
from .state import LateEventError
from .windows import Window

#: the three-lane scheduler's shared-state contract, machine-readable:
#: coordinator attributes that more than one piece of the drive loop
#: touches, mapped to the lanes allowed to mutate them (or call methods
#: through them).  ``repro.analysis.reprolint`` reads this table — a
#: mutation from an ``@lane``-annotated function outside the declared set
#: is an RL103 error, the static form of the byte-identity invariant the
#: PR 6 docstrings could only state.  Keep entries literal.
LANE_SHARED = {
    "_pending_stats": ("driver", "barrier"),   # deferred fold counters
    "_pending_puts": ("driver", "barrier"),    # staged sink writes
    "tables": ("driver",),                     # key-id dictionaries
    "tracker": ("driver", "barrier"),          # ring + watermark state
    "carry": ("driver", "barrier"),            # device fold state
}

#: names that hold device arrays on the hot path: ``int()``/``float()``
#: over these inside a driver/prefetch lane forces a device->host sync
#: mid-batch (RL102)
LANE_DEVICE_STATE = {"carry", "stats"}

_RAW_KEY_BITS = RAW_KEY_BITS    # raw ids must survive the float32 wire
_MAX_WIRE_INT = 1 << 24  # largest int the float32 wire carries exactly
_NEG_INF = float("-inf")


@dataclass(frozen=True)
class RunOptions:
    """Scheduler knobs for one drive of a built pipeline — the single
    options surface behind ``BuiltPipeline.run(...)``.

    Each knob maps onto one lane of the pipelined runtime:

    * ``overlap`` — the *prepare* and *drain* lanes: prefetch + host-prepare
      micro-batch N+1 on a background thread while batch N folds, and defer
      the per-fold device→host stats reads to the micro-batch boundary.
      ``False`` restores the fully synchronous PR 4/5 loop; output bytes
      are identical either way.
    * ``prefetch_batches`` — prepare-lane queue depth (how many prepared
      batches may sit ahead of the fold lane).
    * ``sink_batching`` — drain lane: stage every window emitted during one
      finalization sweep and write them through a single
      ``ObjectStore.put_many`` round trip instead of one PUT per window.
    * ``donate_carry`` — fold lane: donate the carry buffer to each step
      (``jax.jit(..., donate_argnums=...)``) so the long-lived fold reuses
      one buffer instead of copying the carry every micro-batch.
    * ``checkpoint_interval`` — overrides the built program's barrier
      spacing (``None`` keeps the build-time value); checkpoints only ever
      land at micro-batch barriers, after the drain and sink flush.
    * ``shard`` — ``(index, count)``: drive only the keys this coordinator
      owns (``fold_key24(key) % count == index``) under a per-shard job id,
      so ``count`` coordinators split one program's key space cleanly
      (aggregation is per-key, so shard outputs union to the unsharded
      run's).  Single-input pipelines only.
    """

    overlap: bool = True
    prefetch_batches: int = 2
    sink_batching: bool = True
    donate_carry: bool = True
    checkpoint_interval: int | None = None
    shard: tuple[int, int] | None = None

    def validate(self) -> None:
        if self.prefetch_batches < 1:
            raise ValueError("prefetch_batches must be >= 1")
        if self.checkpoint_interval is not None \
                and self.checkpoint_interval < 0:
            raise ValueError("checkpoint_interval must be >= 0 "
                             "(0 disables checkpointing)")
        if self.shard is not None:
            index, count = self.shard
            if count < 1 or not 0 <= index < count:
                raise ValueError(f"shard must be (index, count) with "
                                 f"0 <= index < count, got {self.shard}")


@dataclass
class _PreparedBatch:
    """One micro-batch after prepare-lane work: records routed to their
    root stages and pushed through the fused map chains.  Key-table
    lookups, admission, and folding stay on the main thread."""

    index: int
    n_records: int
    max_event_time: float
    groups: dict[int, list]         # root stage → transformed records


class _Prefetcher:
    """Bounded-depth background prefetcher — the prepare lane.

    Reads micro-batches from the source iterator and host-prepares them on
    a worker thread while the main loop folds the batch in flight; at most
    ``depth`` prepared batches queue ahead.  A source or prepare error is
    forwarded and re-raised on the main thread at the position the
    synchronous loop would have raised it.  ``close`` stops the thread
    promptly even when the main loop exits early (crash injection, ring
    capacity errors), leaving any prepared-but-unconsumed batches to the
    next run's replay from the checkpoint barrier."""

    def __init__(self, batches: Iterator[MicroBatch],
                 prepare: Callable[[MicroBatch], _PreparedBatch],
                 depth: int) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=max(depth, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._fill, args=(batches, prepare),
            name="stream-prefetch", daemon=True)
        self._thread.start()

    def _fill(self, batches: Iterator[MicroBatch], prepare) -> None:
        try:
            for batch in batches:
                item = ("batch", prepare(batch))
                if not self._offer(item):
                    return
            self._offer(("end", None))
        except BaseException as exc:  # forwarded, re-raised by the consumer
            self._offer(("error", exc))

    def _offer(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self) -> Iterator[_PreparedBatch]:
        while True:
            kind, payload = self._q.get()
            if kind == "batch":
                yield payload
            elif kind == "end":
                return
            else:
                raise payload

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)


@dataclass
class StreamReport:
    """Rolling accounting for a streaming run — the Fig. 6/7 quantities
    reinterpreted for sustained throughput."""

    job_id: str
    batches: int = 0
    records_in: int = 0             # raw events consumed
    records_expanded: int = 0       # after window fan-out (sliding > 1×)
    late_dropped: int = 0
    windows_emitted: int = 0        # final-stage windows written to the store
    handoffs: int = 0               # intermediate windows handed to the next stage
    wall_time: float = 0.0
    batch_latencies: list[float] = field(default_factory=list)
    max_lag: int = 0                # worst backpressure observed
    scale_events: int = 0           # pool resizes driven by lag
    hash_collisions: int = 0        # hashed key space: keys sharing a bucket
    capacity_dropped: int = 0       # group mode: window-buffer overflow
    writes_skipped: int = 0         # restart: windows already persisted
    emit_latencies: list[float] = field(default_factory=list)
    # ^ per emitted window: wall-clock seconds from the watermark passing
    #   its end (close) to its bytes landing in the store (emit)
    error: str | None = None

    @property
    def records_per_sec(self) -> float:
        return self.records_in / self.wall_time if self.wall_time else 0.0

    @property
    def mean_batch_latency(self) -> float:
        ls = self.batch_latencies
        return sum(ls) / len(ls) if ls else 0.0

    def emit_latency_quantile(self, q: float) -> float:
        """Close-to-emit latency at quantile ``q`` (nearest-rank), in
        seconds; 0.0 when no window was emitted."""
        ls = sorted(self.emit_latencies)
        if not ls:
            return 0.0
        return ls[min(int(q * len(ls)), len(ls) - 1)]

    @property
    def p50_emit_latency(self) -> float:
        return self.emit_latency_quantile(0.50)

    @property
    def p99_emit_latency(self) -> float:
        return self.emit_latency_quantile(0.99)


def window_output_key(cfg, window: Window, prefix: str | None = None) -> str:
    """Object key for a fixed window's emission.  ``cfg`` is anything with
    ``output_prefix`` and ``job_id`` — typically a ``BuiltPipeline``.
    ``prefix`` overrides the program's prefix for a terminal fan-out
    branch that sinks to its own stream."""
    return (f"{(prefix or cfg.output_prefix).rstrip('/')}/{cfg.job_id}/"
            f"window-{window.start:.3f}-{window.end:.3f}")


def session_output_key(cfg, label: str, start: float, end: float) -> str:
    """Object key for a finalized session — the key's label is part of the
    address because two keys' sessions may share identical bounds."""
    return (f"{cfg.output_prefix.rstrip('/')}/{cfg.job_id}/"
            f"session-{label}-{start:.3f}-{end:.3f}")


def _state_key(job_id: str) -> str:
    return f"stream/{job_id}/state"


def _carry_key(job_id: str) -> str:
    return f"jobs/{job_id}/stream/carry"


class _KeyTable:
    """One side's key dictionary (the data layer's vocab analogue).

    Dense mode: a bounded key → bucket-id map, ids assigned in first-seen
    order.  Hashed mode: raw wire ids (``fold_key24``) plus bucket →
    first-seen labels, so emissions stay labeled and collisions are
    counted exactly instead of raising.  ``on_new`` (dense only) fires
    when a key is first registered — the device-handoff path uses it to
    keep the bucket → next-stage relabel table eager, so checkpoints
    always hold a closed mapping.
    """

    def __init__(self, mode: str, num_buckets: int, name: str = "") -> None:
        self.mode = mode
        self.num_buckets = num_buckets
        self.name = name
        self.on_new: Callable[[int, str], None] | None = None
        self._key_ids: dict[Any, int] = {}
        self._id_keys: list[Any] = []
        self._raw_ids: dict[Any, int] = {}
        self._bucket_keys: dict[int, list] = {}
        self.collisions = 0

    def key_id(self, key: Any) -> int:
        """The wire key id: a dense bucket id, or the 24-bit raw id the
        device hashes into buckets."""
        if self.mode == "hashed":
            return self._raw_key_id(key)
        kid = self._key_ids.get(key)
        if kid is None:
            kid = len(self._id_keys)
            if kid >= self.num_buckets:
                side = f" on the {self.name} side" if self.name else ""
                raise ValueError(
                    f"distinct key count exceeded num_buckets="
                    f"{self.num_buckets}{side}; raise it (keys seen: {kid}) "
                    f"or open the domain with key_space='hashed'")
            self._key_ids[key] = kid
            self._id_keys.append(key)
            if self.on_new is not None:
                self.on_new(kid, str(key))
        return kid

    def _raw_key_id(self, key: Any) -> int:
        """Open domain: fold the key to its raw wire id (the engine's
        ``fold_key24``), remember which keys landed in which bucket so
        emissions stay labeled and collisions are counted instead of
        raising."""
        raw = self._raw_ids.get(key)
        if raw is None:
            raw = fold_key24(key)
            self._raw_ids[key] = raw
            seen = self._bucket_keys.setdefault(
                host_bucket(raw, self.num_buckets), [])
            if seen and key not in seen:
                self.collisions += 1
            if key not in seen:
                seen.append(key)
        return raw

    def bucket_of(self, kid: int) -> int:
        """Host-side bucket for a wire key id — the device folds the same
        id through ``device_hash``, and ``host_bucket`` mirrors it exactly
        (they share the murmur finalizer), so labels cannot drift."""
        if self.mode == "dense":
            return kid
        return host_bucket(kid, self.num_buckets)

    def label(self, bucket: int) -> str:
        """Output label for a bucket id."""
        if self.mode == "dense":
            return str(self._id_keys[bucket])
        seen = self._bucket_keys.get(bucket)
        if not seen:
            return f"bucket-{bucket}"
        if len(seen) == 1:
            return str(seen[0])
        return f"bucket-{bucket}[{'|'.join(sorted(str(k) for k in seen))}]"

    @property
    def dense_keys(self) -> list:
        return self._id_keys

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self) -> dict:
        return {"keys": list(self._id_keys),
                "bucket_keys": [[kid, keys]
                                for kid, keys in self._bucket_keys.items()],
                "collisions": self.collisions}

    def load_state_dict(self, d: dict) -> None:
        """Restore without firing ``on_new`` — relabel tables are rebuilt
        explicitly after every table has loaded."""
        self._id_keys = list(d["keys"])
        self._key_ids = {k: i for i, k in enumerate(self._id_keys)}
        self._bucket_keys = {int(kid): list(keys)
                             for kid, keys in d.get("bucket_keys", [])}
        self._raw_ids = {k: fold_key24(k)
                         for keys in self._bucket_keys.values() for k in keys}
        self.collisions = int(d.get("collisions", 0))


class _StageState:
    """One stage's runtime state: the compiled plan handle(s), carry,
    window tracker, per-side key tables, and wire sizing."""

    def __init__(self, plan, per_worker: int) -> None:
        self.plan = plan
        self.compiled = plan.sides[0].compiled
        self.assigner = plan.assigner()         # None for session windows
        self.tracker = plan.make_tracker()
        self.carry = self.compiled.init_carry()
        self.tables: list[_KeyTable] = []
        self.per_worker = per_worker
        self.window_base = 0                    # per-fold wire-index rebase


class _EdgeState:
    """One DAG edge's runtime state: the lowered transport flags
    (``spec`` is a ``pipeline.lower.StageEdge``), the bucket →
    next-stage-key relabel table (device transports own one *per edge* —
    a teed stage relabels independently toward each successor), and the
    feed watermark driving the destination's min-over-inputs
    observation."""

    def __init__(self, spec) -> None:
        self.spec = spec
        self.relabel: np.ndarray | None = None  # src bucket → dst key id
        self.relabel_dev: jax.Array | None = None
        self.fed: float = _NEG_INF              # max window start handed off


class StreamingCoordinator:
    """Long-lived coordinator: micro-batch rounds over a continuous stream,
    driving one compiled pipeline program — a sequence of execution-plan
    stages chained by carry handoffs."""

    CONSUMER_GROUP = "streaming-coordinator"

    def __init__(self, store: ObjectStore, meta: MetadataStore,
                 bus: EventBus | None = None,
                 autoscaler: AutoscalerConfig | None = None, *,
                 program, options: RunOptions | None = None,
                 pool: ServerlessPool | None = None) -> None:
        if program is None:
            raise ValueError("pass program= (a BuiltPipeline); the flat "
                             "StreamingConfig shim was removed in PR 8")
        if pool is not None and autoscaler is not None:
            raise ValueError("pass pool= (a shared ServerlessPool) or "
                             "autoscaler= (a config for a private pool), "
                             "not both")
        self.opts = options or RunOptions()
        self.opts.validate()
        self.store = store
        self.meta = meta
        self.prog = program
        self._ckpt_interval = (program.checkpoint_interval
                               if self.opts.checkpoint_interval is None
                               else self.opts.checkpoint_interval)
        self.bus = bus or EventBus()
        # pool= shares one physical worker pool across coordinators — the
        # job-server mode where many tenants' programs run on one engine
        # pool; by default each coordinator owns a private pool sized to
        # its program
        self.pool = pool if pool is not None else ServerlessPool(
            "stream-mapper", autoscaler or AutoscalerConfig(
                max_scale=program.n_workers))
        self.owns_pool = pool is None
        # per-job consumer group: coordinators sharing one bus (the job
        # server) must not advance each other's trigger offsets
        self.consumer_group = f"{self.CONSUMER_GROUP}:{program.job_id}"
        # the stage DAG: adjacency first (wire sizing needs the in-edges),
        # then per-stage state.  Fixed per-batch array capacity so XLA
        # compiles a single program: device fan-out ships one row per
        # record, host fan-out pre-expands, sessions ship host-wire rows
        # with fan-out 1; carry-fed stages are sized by their sources'
        # worst-case window output
        self.edges = [_EdgeState(e) for e in program.edges]
        self._out: dict[int, list[_EdgeState]] = {}
        self._in: dict[int, list[_EdgeState]] = {}
        for e in self.edges:
            self._out.setdefault(e.spec.src, []).append(e)
            self._in.setdefault(e.spec.dst, []).append(e)
        self._roots = sorted({si for si, _side in program.inputs})
        self._ext_wm: dict[int, float] = {}  # per-root external watermark
        self.stages = [
            _StageState(sp, self._wire_rows(si))
            for si, sp in enumerate(program.stages)]
        self._build_tables()
        self._records_consumed = 0      # checkpointed resume point (records)
        self._persisted: set[str] = set()   # restart: already-written windows
        # drain-lane staging: per-fold device stats awaiting their batch-
        # boundary host read, and per-sweep window emissions awaiting their
        # batched store write
        self._pending_stats: list[tuple[int, Any]] = []
        self._pending_puts: list[tuple[str, bytes, float, float, int,
                                       float]] = []

    # -- construction ----------------------------------------------------------
    def _wire_rows(self, si: int) -> int:
        """Per-worker wire capacity for stage ``si``: the micro-batch bound
        where an external input lands, each in-edge source's worst-case
        window output where the carry feeds it — a stage fed both ways (a
        join with one single-stage side) takes the max (grown on demand if
        flat-maps expand it)."""
        prog = self.prog
        sp = prog.stages[si]
        bounds = [prog.batch_records] if any(
            s == si for s, _side in prog.inputs) else []
        for e in self._in.get(si, ()):
            prev = prog.stages[e.spec.src]
            if prev.emit.kind == "top_k":
                bounds.append(max(prev.emit.k, 1))
            elif prev.emit.kind == "group":
                bounds.append(prog.n_workers * max(prev.capacity, 1))
            else:
                bounds.append(prev.num_buckets)
        bound = max(bounds)
        if not (sp.is_session or prog.fanout == "device"):
            bound *= sp.assigner().max_windows_per_event()
        return -(-bound // prog.n_workers)

    def _build_tables(self) -> None:
        prog = self.prog
        for st in self.stages:
            if st.plan.is_join and prog.key_space == "dense":
                # dense joins match by label at emission, so each side keeps
                # its own dictionary — per-side key-space sizes stay honest
                st.tables = [_KeyTable("dense", sp.num_buckets, name=sp.name)
                             for sp in st.plan.sides]
            else:
                # hashed joins match by bucket id: one shared table keeps
                # cross-side collision accounting and labels identical
                table = _KeyTable(prog.key_space,
                                  st.plan.sides[0].num_buckets)
                st.tables = [table] * len(st.plan.sides)
        for si, st in enumerate(self.stages):
            eager = [e for e in self._out.get(si, ()) if e.spec.eager]
            if not eager:
                continue
            for e in eager:
                if e.spec.device:
                    e.relabel = np.full(st.plan.num_buckets, -1, np.int32)

            def on_new(kid: int, label: str, edges=tuple(eager)) -> None:
                # eager: every identity successor's dictionary (and, on
                # device edges, the edge's relabel table) grows the moment
                # this stage first sees a key — both handoff transports
                # assign the same downstream id order, and every checkpoint
                # snapshots a closed mapping on every edge
                for e in edges:
                    dst = self.stages[e.spec.dst]
                    next_id = dst.tables[e.spec.dst_side].key_id(label)
                    if e.relabel is not None:
                        e.relabel[kid] = next_id
                        e.relabel_dev = None

            st.tables[0].on_new = on_new

    # -- record transforms -----------------------------------------------------
    @lane("prefetch")
    def _transform_recs(self, si: int,
                        raw) -> list[tuple[float, Any, float, int]]:
        """Apply stage ``si``'s fused map chain and key/value extractors;
        returns side-tagged ``(ts, key, value, side)`` records.  Touches
        only the immutable program (transforms are pure by the Pipeline
        contract), so the prepare lane may run it off-thread while the
        main loop folds the batch in flight."""
        stage = self.stages[si]
        recs: list[tuple[float, Any, float, int]] = []
        for rec in raw:
            side = int(rec[3]) if len(rec) > 3 else 0
            sp = stage.plan.sides[side]
            if sp.transform is None:
                out = (tuple(rec[:3]),)
            else:
                o = sp.transform(tuple(rec[:3]))
                out = () if o is None else \
                    ((o,) if isinstance(o, tuple) else tuple(o))
            for r in out:
                recs.append((float(r[0]), sp.key_fn(r),
                             float(sp.value_fn(r)), side))
        return recs

    @lane("driver")
    def _grow_wire(self, si: int, recs: list) -> None:
        """Flat-maps may expand past the stage's wire capacity: grow the
        buffer (and retrace the step once per growth) instead of failing,
        so the same graph runs in batch mode, where one "micro-batch" is
        the whole input.  Mutates stage state — main thread only."""
        stage = self.stages[si]
        if stage.plan.is_session or self.prog.fanout == "device":
            needed = len(recs)
        else:
            needed = len(recs) * stage.assigner.max_windows_per_event()
        per = -(-needed // self.prog.n_workers)
        if per > stage.per_worker:
            stage.per_worker = per

    @lane("driver")
    def _stage_recs(self, si: int, raw, report: StreamReport,
                    count_in: bool) -> list[tuple[float, Any, float, int]]:
        """Transform + wire growth in one synchronous call — the host-edge
        feed path and the prepare lane's building block."""
        if count_in:
            report.records_in += len(raw)
        recs = self._transform_recs(si, raw)
        self._grow_wire(si, recs)
        return recs

    # -- batch ingestion -------------------------------------------------------
    def _wire(self, stage: _StageState, rows: np.ndarray,
              width: int) -> np.ndarray:
        """Rows in the backend's wire layout: vmap batches the worker axis,
        shard_map shards the flat global array over the mesh axis."""
        if self.prog.backend == "vmap":
            return rows.reshape(self.prog.n_workers, stage.per_worker, width)
        return rows

    @lane("driver")
    def _fold_device(self, si: int, rows: np.ndarray, report: StreamReport,
                     side: int = 0) -> None:
        """Fold one-row-per-record [last_window, n_windows, key, value,
        valid] rows through one side's compiled step; the device fans out,
        masks late pairs against the watermark bound, and returns the
        accounting.  Window indices on the wire are rebased by the stage's
        ``window_base`` (a multiple of ``n_slots``, so modular slots are
        unchanged) to stay exact in float32 at any absolute event time."""
        stage = self.stages[si]
        data = self._wire(stage, rows, 5)
        bound = stage.tracker.min_admissible() - stage.window_base
        bound = max(min(bound, 2 ** 31 - 1), -(2 ** 31))
        stage.carry, stats = self.pool.submit(
            stage.plan.sides[side].compiled.step, data, stage.carry, bound,
            donate=self.opts.donate_carry)
        self._account_stats(si, stats, report)

    @lane("driver")
    def _account_stats(self, si: int, stats, report: StreamReport) -> None:
        """Apply one fold's [late, expanded, dropped] counters.  With
        overlap on, the device→host read is deferred — the stats array
        queues on the drain lane and ``_drain_stats`` reads the whole
        batch's worth at the micro-batch barrier, so no fold forces a
        host sync on the hot path (and sibling tee-branch folds dispatch
        back-to-back on the device).  The counters feed accounting only
        (never admission), so deferral cannot change any output byte."""
        if self.opts.overlap:
            self._pending_stats.append((si, stats))
            return
        late, expanded, dropped = (
            # the synchronous (overlap-off) path reads per fold by design
            int(x) for x in np.asarray(stats))  # reprolint: disable=RL102
        self.stages[si].tracker.note_late(late)
        report.records_expanded += expanded
        report.capacity_dropped += dropped

    @lane("barrier")
    def _drain_stats(self, report: StreamReport) -> None:
        """Batch-boundary drain: read every deferred fold's counters in one
        pass (each ``np.asarray`` waits on its already-dispatched step)."""
        if not self._pending_stats:
            return
        pending, self._pending_stats = self._pending_stats, []
        for si, stats in pending:
            late, expanded, dropped = (int(x) for x in np.asarray(stats))
            self.stages[si].tracker.note_late(late)
            report.records_expanded += expanded
            report.capacity_dropped += dropped

    @lane("driver")
    def _fold_host(self, si: int, rows: np.ndarray) -> None:
        """Host-wire fold: [window_slot, key, value, valid] rows whose slot
        was assigned host-side (legacy host fan-out, or session cells)."""
        stage = self.stages[si]
        data = self._wire(stage, rows, 4)
        stage.carry, _ = self.pool.submit(stage.compiled.step, data,
                                          stage.carry,
                                          donate=self.opts.donate_carry)

    # -- window finalization --------------------------------------------------
    @lane("driver")
    def _put_window(self, out_key: str, records: list, start: float,
                    end: float, report: StreamReport,
                    t_close: float | None = None) -> None:
        """Persist one finalized window, idempotently across restarts: a
        window already in the store with identical bytes (a replayed
        emission from before the crash) is skipped, not re-written; changed
        bytes (a flushed partial window over a since-grown log) overwrite.

        With sink batching on, the write stages on the drain lane instead
        of PUTting immediately; ``_flush_sinks`` writes the whole
        finalization sweep's windows through one ``ObjectStore.put_many``
        round trip (the idempotence check already ran here, so a staged
        window is always a real write).  ``t_close`` is when the watermark
        passed the window's end, for the close-to-emit latency histogram."""
        blob = _encode_records(records)
        if t_close is None:
            t_close = time.perf_counter()
        if out_key in self._persisted and self.store.get(out_key) == blob:
            report.writes_skipped += 1
            return
        if self.opts.sink_batching:
            self._pending_puts.append((out_key, blob, start, end,
                                       len(records), t_close))
            return
        self.store.put(out_key, blob)
        report.emit_latencies.append(time.perf_counter() - t_close)
        self.bus.produce(TOPIC_STREAM_WINDOW,
                         window_event(self.prog.job_id, start, end,
                                      len(records), out_key),
                         key=f"{self.prog.job_id}/{start}")

    @lane("barrier")
    def _flush_sinks(self, report: StreamReport) -> None:
        """Drain-lane sink flush: one batched store write for every window
        the sweep emitted, then the per-window bus events in emission
        order.  Runs at the end of each finalization sweep — always before
        a checkpoint barrier, so a crash can lose only writes the replay
        will re-emit (bytes are deterministic, so re-writes are
        idempotent)."""
        if not self._pending_puts:
            return
        pending, self._pending_puts = self._pending_puts, []
        self.store.put_many([(key, blob) for key, blob, *_ in pending])
        t_emit = time.perf_counter()
        for key, blob, start, end, n_records, t_close in pending:
            report.emit_latencies.append(t_emit - t_close)
            self.bus.produce(TOPIC_STREAM_WINDOW,
                             window_event(self.prog.job_id, start, end,
                                          n_records, key),
                             key=f"{self.prog.job_id}/{start}")

    def _aggregate_value(self, kind: str, total: float, count: float) -> Any:
        if kind == "count":
            return int(count)
        if kind == "sum":
            return float(total)
        return float(total / count)

    @lane("driver")
    def _window_records(self, si: int, slot: int) -> list[tuple[str, Any]]:
        """One finalized fixed window's output records, per the stage's
        emission spec — written to the store by the final stage, fed to
        the next stage's ingestion by an intermediate one."""
        stage = self.stages[si]
        emit = stage.plan.emit
        compiled = stage.compiled
        table = stage.tables[0]
        records: list[tuple[str, Any]] = []
        if emit.kind == "group":
            gk, gv, gvalid = compiled.finalize_slot(stage.carry, slot)
            records = [(table.label(int(k)), float(v))
                       for k, v, ok in zip(gk, gv, gvalid) if ok]
            records.sort(key=lambda kv: kv[0])
        elif emit.kind == "top_k":
            ids, _vals, valid = compiled.top_k_slot(stage.carry, slot,
                                                    emit.rank_by)
            agg = compiled.read_slot(stage.carry, slot)
            for kid in ids[valid]:
                records.append((table.label(int(kid)), self._aggregate_value(
                    emit.aggregation, agg[kid, 0], agg[kid, 1])))
            # rank order, not label order: the k heaviest keys, heaviest
            # first — deterministic (top_k ties break on bucket id)
        elif emit.kind == "join":
            agg = compiled.read_slot(stage.carry, slot)
            lkind, rkind = emit.join_aggs
            lt, rt = stage.tables
            if lt is rt:
                # hashed join: both sides share one bucket space — match by
                # bucket id, label from the shared table
                both = np.nonzero((agg[:, 1] > 0) & (agg[:, 3] > 0))[0]
                for kid in both:
                    records.append((lt.label(int(kid)), [
                        self._aggregate_value(lkind, agg[kid, 0],
                                              agg[kid, 1]),
                        self._aggregate_value(rkind, agg[kid, 2],
                                              agg[kid, 3]),
                    ]))
            else:
                # dense join (possibly asymmetric key spaces): each side
                # owns its dictionary, so equality is by label
                left = {lt.label(int(k)): int(k)
                        for k in np.nonzero(agg[:lt.num_buckets, 1] > 0)[0]}
                for rk in np.nonzero(agg[:rt.num_buckets, 3] > 0)[0]:
                    lab = rt.label(int(rk))
                    lk = left.get(lab)
                    if lk is None:
                        continue
                    records.append((lab, [
                        self._aggregate_value(lkind, agg[lk, 0], agg[lk, 1]),
                        self._aggregate_value(rkind, agg[rk, 2], agg[rk, 3]),
                    ]))
            records.sort(key=lambda kv: kv[0])
        else:
            agg = compiled.read_slot(stage.carry, slot)
            sums, counts = agg[:, 0], agg[:, 1]
            for kid in np.nonzero(counts > 0)[0]:
                records.append((table.label(int(kid)), self._aggregate_value(
                    emit.aggregation, sums[kid], counts[kid])))
            records.sort(key=lambda kv: kv[0])
        return records

    @lane("driver")
    def _emit_window(self, si: int, window_index: int, slot: int,
                     report: StreamReport) -> None:
        stage = self.stages[si]
        window = stage.assigner.window(window_index)
        records = self._window_records(si, slot)
        out_key = window_output_key(self.prog, window,
                                    prefix=self.prog.stage_prefix(si))
        t_close = getattr(stage.tracker, "closed_at", {}).get(window_index)
        self._put_window(out_key, records, window.start, window.end, report,
                         t_close=t_close)
        stage.carry = stage.compiled.clear_slot(stage.carry, slot)
        stage.tracker.release(window_index)

    @lane("driver")
    def _emit_session(self, si: int, session, report: StreamReport) -> None:
        stage = self.stages[si]
        compiled = stage.compiled
        cell = compiled.read_cell(stage.carry, session.slot, session.bucket)
        label = stage.tables[0].label(session.bucket)
        records: list[tuple[str, Any]] = []
        if cell[1] > 0:
            records.append((label, self._aggregate_value(
                stage.plan.emit.aggregation, cell[0], cell[1])))
        out_key = session_output_key(self.prog, label, session.start,
                                     session.end)
        self._put_window(out_key, records, session.start, session.end,
                         report)
        stage.carry = compiled.clear_cell(stage.carry, session.slot,
                                          session.bucket)
        stage.tracker.release(session)

    # -- span admission (shared by record ingestion and the carry handoff) -----
    @lane("driver")
    def _admit_span(self, si: int, lo: int, hi: int, seen: float,
                    ship, flush, report: StreamReport, *ship_args,
                    via: "_EdgeState | None" = None) -> None:
        """Admit windows ``[lo, hi]`` on stage ``si``'s ring and ship the
        span in contiguous segments — THE ring/watermark protocol, in one
        place for both transports.

        ``ship(last, n, *ship_args)`` emits one segment covering
        ``[last - n + 1, last]`` (absolute indices; late windows inside it
        are masked + counted on device) — the extra args pass per-record
        context through without a per-record closure on the hot path.  On
        a mid-span ring-full, the already-safe prefix ships, ``flush()``
        folds whatever the caller has staged, the watermark advances to
        ``seen``, ripe windows finalize, and the blocked window retries
        once — a second failure is a genuine capacity error and
        propagates.  A window the watermark closed during the retry stays
        in the span for the device mask (re-admitting it would
        double-count the pair)."""
        stage = self.stages[si]
        start = lo
        for widx in range(lo, hi + 1):
            if widx in stage.tracker.active or stage.tracker.is_late(widx):
                continue        # device masks + counts the late pairs
            try:
                stage.tracker.slot_for(widx)
            except LateEventError:
                if widx > start:
                    ship(widx - 1, widx - start, *ship_args)
                    start = widx
                flush()
                self._observe_floor(si, seen, via)
                self._finalize_ripe(report, si)
                if not stage.tracker.is_late(widx):
                    stage.tracker.slot_for(widx)
        if hi >= start:
            ship(hi, hi - start + 1, *ship_args)

    # -- the carry handoff (stage N windows → successor batches) ---------------
    @lane("driver")
    def _handoff_device(self, edge: _EdgeState, slot: int, wstart: float,
                        report: StreamReport) -> None:
        """On-device edge: re-key/re-window one finalized window of the
        edge's source and fold it into the destination's carry without the
        aggregates visiting the host.  Admission control (which target
        windows are open) stays host-side — it is pure scalar math on the
        window's timestamp — through the same ``_admit_span`` protocol as
        record ingestion."""
        dst = self.stages[edge.spec.dst]
        asg = dst.assigner
        w0 = asg.window(0)
        step = asg.window(1).start - w0.start
        rel = wstart - w0.start
        last = int(math.floor(rel / step))
        if dst.plan.window.slide is None:
            first = last
        else:
            first = int(math.floor((rel - w0.size) / step)) + 1
        dst.window_base = (first // dst.plan.n_slots) * dst.plan.n_slots
        self._admit_span(
            edge.spec.dst, first, last, wstart,
            lambda seg_last, n: self._handoff_step(edge, slot, seg_last, n,
                                                   report),
            lambda: None, report, via=edge)

    @lane("driver")
    def _handoff_step(self, edge: _EdgeState, slot: int, last: int,
                      n_windows: int, report: StreamReport) -> None:
        """One fused handoff: gather the source's finalized slot, relabel
        through the *edge's* table + re-window + fold through the
        destination side's step, all on device."""
        src = self.stages[edge.spec.src]
        dst = self.stages[edge.spec.dst]
        if edge.relabel_dev is None:
            edge.relabel_dev = jnp.asarray(edge.relabel)
        base = dst.window_base
        rows = src.compiled.handoff_rows(
            src.carry, slot, edge.relabel_dev, last - base, n_windows,
            src.plan.emit.aggregation,
            dst.per_worker * self.prog.n_workers)
        bound = dst.tracker.min_admissible() - base
        bound = max(min(bound, 2 ** 31 - 1), -(2 ** 31))
        step_fn = dst.plan.sides[edge.spec.dst_side].compiled.step
        dst.carry, stats = self.pool.submit(step_fn, rows, dst.carry, bound,
                                            donate=self.opts.donate_carry)
        self._account_stats(edge.spec.dst, stats, report)

    @lane("driver")
    def _feed(self, edge: _EdgeState, records: list,
              report: StreamReport) -> None:
        """Host edge: one finalized window's records, materialized and fed
        through the destination's ordinary ingestion (its inter-stage maps
        and ``key_by`` apply here), side-tagged for a join destination."""
        si, side = edge.spec.dst, edge.spec.dst_side
        recs = self._stage_recs(si, [(r[0], r[1], r[2], side)
                                     for r in records],
                                report, count_in=False)
        if not recs:
            return
        if self.prog.fanout == "device":
            self._ingest_device(si, recs, report, via=edge)
        else:
            self._ingest_host(si, recs, report, via=edge)

    @lane("driver")
    def _observe(self, si: int) -> None:
        """Advance stage ``si``'s watermark to the minimum over its input
        channels — the external stream's observed event time (roots) and
        each in-edge's feed watermark.  A join over a lagging input holds
        its windows open until *every* channel has passed them; a root's
        external channel counts from the start (at -inf until its first
        batch lands), so a carry feed racing ahead of a not-yet-ingested
        external side cannot close its windows early."""
        cands = [e.fed for e in self._in.get(si, ())]
        if si in self._roots:
            cands.append(self._ext_wm.get(si, _NEG_INF))
        if cands:
            self.stages[si].tracker.observe(min(cands))

    @lane("driver")
    def _observe_floor(self, si: int, seen: float,
                       via: "_EdgeState | None") -> None:
        """The mid-batch ring-full recovery's watermark advance: the
        *active* input channel (the external stream, or the in-edge
        ``via`` currently feeding) stands at ``seen``, but every OTHER
        input channel still caps the watermark at its feed position — a
        multi-input stage (a join over a lagging side) frees slots only
        past windows every input has passed, so the recovery can never
        close a window a lagging channel could still feed.  If nothing
        frees, the retry's second failure raises the genuine capacity
        error instead of silently dropping a side."""
        cands = [seen]
        for e in self._in.get(si, ()):
            if e is not via:
                cands.append(e.fed)
        if via is not None and si in self._roots:
            cands.append(self._ext_wm.get(si, _NEG_INF))
        self.stages[si].tracker.observe(min(cands))

    @lane("driver")
    def _finalize_stage(self, si: int, report: StreamReport) -> set[int]:
        """Emit (terminal stage) or hand off (one delivery per out-edge)
        every window stage ``si``'s watermark has passed; returns the
        destination stages fed."""
        stage = self.stages[si]
        out = self._out.get(si, ())
        if stage.plan.is_session:
            for session in stage.tracker.ripe():
                self._emit_session(si, session, report)
                report.windows_emitted += 1
            return set()    # sessions run in single-stage pipelines only
        fed: set[int] = set()
        for window_index, slot in stage.tracker.ripe():
            if not out:
                self._emit_window(si, window_index, slot, report)
                report.windows_emitted += 1
                continue
            window = stage.assigner.window(window_index)
            host_records = None
            for edge in out:
                if edge.spec.device:
                    self._handoff_device(edge, slot, window.start, report)
                else:
                    if host_records is None:    # materialize at most once
                        host_records = self._window_records(si, slot)
                    self._feed(edge, [(window.start, key, value)
                                      for key, value in host_records],
                               report)
                edge.fed = max(edge.fed, window.start)
                fed.add(edge.spec.dst)
                report.handoffs += 1
            stage.carry = stage.compiled.clear_slot(stage.carry, slot)
            stage.tracker.release(window_index)
        if out and stage.tracker.watermark == float("inf"):
            # end-of-stream: no further window can ever be fed over these
            # edges, so successors may close everything they hold
            for edge in out:
                edge.fed = float("inf")
                fed.add(edge.spec.dst)
        return fed

    def _finalize_ripe(self, report: StreamReport, si: int = 0) -> None:
        """Finalize every ripe window of stage ``si`` and cascade the
        handoffs through the DAG in one forward sweep: stages are stored
        in topological order and every edge points forward, so by the time
        the sweep reaches a stage, *all* of this round's feeds into it —
        including both sides of a downstream join — have landed."""
        self._finalize_sweep(report, {si})

    def _finalize_sweep(self, report: StreamReport,
                        touched: set[int]) -> None:
        """One forward topological sweep, then one batched sink flush for
        everything it emitted.  With overlap on, a tee'd stage's sibling
        out-edges dispatch their handoff folds with no host sync between
        them (each fold's stats read is deferred to the drain lane), so
        independent branches of the DAG execute concurrently under JAX
        async dispatch instead of serializing on per-branch host reads."""
        for si in range(len(self.stages)):
            if si not in touched:
                continue
            for dst in self._finalize_stage(si, report):
                self._observe(dst)
                touched.add(dst)
        self._flush_sinks(report)

    # -- checkpoint / restore --------------------------------------------------
    @lane("barrier")
    def save_state(self) -> None:
        """Persist the full streaming state at a batch boundary: every
        stage's carry — branches included, one pytree — to the object
        store, trackers + key dictionaries + per-edge feed watermarks +
        the consumed *record* offset to the metadata store.  Record
        addressing (not batch indices) keeps resume correct when the log
        grows past a previously-partial final batch.  A restarted
        coordinator re-folds at most the batches since the last
        checkpoint; window emissions are idempotent (same carries → same
        bytes), replayed handoffs re-fold into carries that predate them,
        and replayed writes of already-persisted windows are skipped
        (``_put_window``), keeping restart effectively exactly-once on
        every branch.

        Checkpoints land at micro-batch barriers only, strictly after the
        drain lane has emptied: staged sink writes must be durable before
        the offset advances (a checkpoint past an unwritten window would
        replay nothing that re-emits it), and deferred stats must be
        applied so the snapshot's late-drop counters match the synchronous
        loop's bit-for-bit."""
        if self._pending_puts or self._pending_stats:
            raise RuntimeError(
                "internal: checkpoint requested with an undrained lane "
                f"({len(self._pending_puts)} staged sink writes, "
                f"{len(self._pending_stats)} deferred stats reads); "
                "checkpoints must follow the batch-boundary drain")
        carries = tuple(st.carry for st in self.stages)
        leaves = [np.asarray(leaf)
                  for leaf in jax.tree_util.tree_leaves(carries)]
        buf = io.BytesIO()
        np.savez(buf, **{f"leaf{i}": leaf for i, leaf in enumerate(leaves)})
        self.store.put(_carry_key(self.prog.job_id), buf.getvalue())
        self.meta.set(_state_key(self.prog.job_id), {
            "offset": self._records_consumed,
            "carry_shapes": [list(leaf.shape) for leaf in leaves],
            "edge_fed": [e.fed for e in self.edges],
            "stages": [{
                "tracker": st.tracker.state_dict(),
                "tables": [t.state_dict()
                           for t in self._unique_tables(st)],
            } for st in self.stages],
        })

    # back-compat private name (pre-PR 8 callers)
    _save_state = save_state

    def restore_state(self) -> int:
        """Load a prior run's checkpoint; returns the record offset to
        resume from (0 when starting fresh).  Also consults every terminal
        stage's output prefix for windows the prior run already persisted,
        so the replay of the uncheckpointed tail does not re-write them —
        including a crash before the *first* checkpoint, where the whole
        log replays."""
        self._persisted = {
            m.key for out_prefix in self.prog.output_prefixes()
            for m in self.store.list_objects(out_prefix)}
        state = self.meta.get(_state_key(self.prog.job_id))
        if state is None:
            self._records_consumed = 0
            return 0
        if "carry_shapes" not in state or "stages" not in state:
            raise ValueError(
                f"checkpoint for job {self.prog.job_id} predates the "
                f"multi-stage carry format (PR 4); restart the stream "
                f"under a fresh job_id or replay it from the log")
        if len(state["stages"]) != len(self.stages):
            raise ValueError(
                f"checkpoint for job {self.prog.job_id} holds "
                f"{len(state['stages'])} stages but this program has "
                f"{len(self.stages)}; the pipeline changed under the job")
        carries = tuple(st.carry for st in self.stages)
        leaves, treedef = jax.tree_util.tree_flatten(carries)
        shapes = [tuple(s) for s in state["carry_shapes"]]
        if shapes != [leaf.shape for leaf in leaves]:
            raise ValueError(
                f"checkpointed carry shapes {shapes} do not match this "
                f"coordinator's {[leaf.shape for leaf in leaves]}; the "
                f"streaming config changed under job {self.prog.job_id}")
        blob = self.store.get(_carry_key(self.prog.job_id))
        with np.load(io.BytesIO(blob)) as loaded:
            restored = [jnp.asarray(loaded[f"leaf{i}"])
                        for i in range(len(leaves))]
        for st, carry in zip(self.stages,
                             jax.tree_util.tree_unflatten(treedef, restored)):
            st.carry = carry
        for st, sdict in zip(self.stages, state["stages"]):
            st.tracker.load_state_dict(sdict["tracker"])
            for table, tdict in zip(self._unique_tables(st),
                                    sdict["tables"]):
                table.load_state_dict(tdict)
        # rebuild every edge's relabel table from the restored
        # dictionaries (eager registration means every label already has a
        # destination id — nothing is created here) and restore the feed
        # watermarks driving min-over-inputs observation
        for e, fed in zip(self.edges,
                          state.get("edge_fed", [_NEG_INF] * len(self.edges))):
            e.fed = float(fed)
            if e.relabel is None:
                continue
            src_table = self.stages[e.spec.src].tables[0]
            dst_table = self.stages[e.spec.dst].tables[e.spec.dst_side]
            for kid, key in enumerate(src_table.dense_keys):
                e.relabel[kid] = dst_table.key_id(str(key))
            e.relabel_dev = None
        self._records_consumed = int(state["offset"])
        return self._records_consumed

    # -- backpressure ----------------------------------------------------------
    def _autoscale(self, report: StreamReport) -> None:
        lag = self.bus.lag(self.consumer_group, TOPIC_STREAM_BATCH)
        report.max_lag = max(report.max_lag, lag)
        want = self.pool.desired_scale_from_backlog(lag)
        if want > self.pool.replicas():
            self.pool.ensure_scale(want)
            report.scale_events += 1
        elif want < self.pool.replicas():
            if self.pool.reap_idle():
                report.scale_events += 1

    # -- the streaming loop -----------------------------------------------------
    def announce(self, source, start_record: int = 0) -> int:
        """Publish one trigger CloudEvent per available micro-batch — the
        stand-in for a Kafka producer filling the topic ahead of the
        consumer.  The resulting consumer lag drives autoscaling.
        ``start_record`` skips already-processed records on resume so the
        lag signal reflects real backlog, not replayed history.  Uses
        record counts only (``batch_sizes``), so the log's payloads are
        parsed once — by the processing loop, not here."""
        n = 0
        for index, size in enumerate(source.batch_sizes(start_record)):
            self.bus.produce(
                TOPIC_STREAM_BATCH,
                batch_event(self.prog.job_id, index, size),
                key=f"{self.prog.job_id}/{index}")
            n += 1
        return n

    @lane("driver")
    def _ingest_device(self, si: int, recs, report: StreamReport,
                       via: "_EdgeState | None" = None) -> None:
        """Device fan-out ingestion: one 5-column row per record; window
        *indices* are assigned host-side in float64 (bit-identical to the
        host-fan-out assigner) but the event × window expansion happens
        on-chip.  A batch that spans more windows than the ring holds folds
        and finalizes mid-batch instead of aborting — splitting the
        triggering record's coverage so pairs admitted before the mid-batch
        watermark advance still land, exactly like the host path.  Each
        record folds through its side's plan; a join's two sides share the
        carry, so one pass interleaves them safely."""
        stage = self.stages[si]
        prog = self.prog
        w0 = stage.assigner.window(0)
        step = stage.assigner.window(1).start - w0.start
        ts = np.array([r[0] for r in recs], np.float64)
        rel = ts - w0.start
        last = np.floor(rel / step).astype(np.int64)
        if stage.plan.window.slide is None:
            first = last
        else:
            first = np.floor((rel - w0.size) / step).astype(np.int64) + 1
        # rebase wire indices so they stay exact in float32 at any absolute
        # event time; a multiple of n_slots keeps w % n_slots unchanged
        n_slots = stage.plan.n_slots
        base = (int(first.min()) // n_slots) * n_slots
        if int(last.max()) - base >= _MAX_WIRE_INT:
            raise ValueError(
                f"one ingestion round spans {int(last.max()) - base} "
                f"windows, beyond the float32 wire's exact-integer range; "
                f"reduce batch_records or raise the window slide")
        stage.window_base = base
        n_sides = len(stage.plan.sides)
        shape = (prog.n_workers * stage.per_worker, 5)
        rows = [np.zeros(shape, np.float32) for _ in range(n_sides)]
        n = [0] * n_sides

        def fold_staged() -> None:
            # the dispatched fold may zero-copy-alias the numpy buffer; a
            # fresh buffer avoids racing the in-flight computation with our
            # next writes
            for s in range(n_sides):
                if n[s]:
                    self._fold_device(si, rows[s], report, s)
                    rows[s] = np.zeros(shape, np.float32)
                    n[s] = 0

        seen = _NEG_INF             # stream position within this round

        def ship(seg_last: int, nw: int, side: int, kid: int,
                 value: float) -> None:
            rows[side][n[side]] = (seg_last - base, nw, kid, value, 1.0)
            n[side] += 1

        for i, (tsi, key, value, side) in enumerate(recs):
            seen = tsi if tsi > seen else seen
            kid = stage.tables[side].key_id(key)
            # a mid-span ring-full ships the record's already-safe prefix,
            # folds the staged rows, and finalizes before retrying — see
            # _admit_span for the protocol
            self._admit_span(si, int(first[i]), int(last[i]), seen, ship,
                             fold_staged, report, side, kid, value, via=via)
        for s in range(n_sides):
            self._fold_device(si, rows[s], report, s)

    @lane("driver")
    def _ingest_host(self, si: int, recs, report: StreamReport,
                     via: "_EdgeState | None" = None) -> None:
        """Legacy host fan-out: expand every record into one row per
        containing window on the host (numpy), the PR 1 baseline the
        device path is benchmarked against.  Host-dropped pairs are
        counted here through the tracker's single accounting entry point
        (``note_late``)."""
        stage = self.stages[si]
        rows = np.zeros((self.prog.n_workers * stage.per_worker, 4),
                        np.float32)
        n = 0
        seen = _NEG_INF
        for ts, key, value, _side in recs:
            seen = ts if ts > seen else seen
            for widx in stage.assigner.assign(ts):
                try:
                    slot = stage.tracker.slot_for(widx)
                except LateEventError:
                    if n:
                        self._fold_host(si, rows)
                        report.records_expanded += n
                        rows = np.zeros_like(rows)
                        n = 0
                    self._observe_floor(si, seen, via)
                    self._finalize_ripe(report, si)
                    slot = stage.tracker.slot_for(widx)
                if slot is None:        # late: window already emitted
                    stage.tracker.note_late(1)
                    continue
                rows[n] = (slot, stage.tables[0].key_id(key), value, 1.0)
                n += 1
        report.records_expanded += n
        self._fold_host(si, rows)

    @lane("driver")
    def _ingest_session(self, si: int, recs, report: StreamReport) -> None:
        """Session ingestion: the tracker assigns each admitted event a
        carry cell (slot, bucket), merging bridged sessions; rows ship on
        the host wire with fan-out 1.  Cell merges apply *after* folding
        the rows already staged for the source cells, so the carry and the
        tracker never disagree about where a session lives."""
        stage = self.stages[si]
        compiled = stage.compiled
        table = stage.tables[0]
        shape = (self.prog.n_workers * stage.per_worker, 4)
        rows = np.zeros(shape, np.float32)
        n = 0
        seen = _NEG_INF

        def fold_staged() -> None:
            nonlocal rows, n
            if n:
                report.records_expanded += n
                self._fold_host(si, rows)
                rows = np.zeros(shape, np.float32)
                n = 0

        for tsi, key, value, _side in recs:
            seen = tsi if tsi > seen else seen
            kid = table.key_id(key)
            bucket = table.bucket_of(kid)
            try:
                admitted = stage.tracker.admit(bucket, tsi)
            except LateEventError:
                # every slot holds an open session for this bucket: fold,
                # advance the watermark to the position reached, finalize,
                # retry (a second failure is a genuine capacity error)
                fold_staged()
                stage.tracker.observe(seen)
                self._finalize_ripe(report, si)
                admitted = stage.tracker.admit(bucket, tsi)
            if admitted is None:        # late: session already emitted
                stage.tracker.note_late(1)
                continue
            slot, merges = admitted
            if merges:
                fold_staged()
                for src, dst in merges:
                    stage.carry = compiled.merge_cell(stage.carry, src, dst,
                                                      bucket)
            rows[n] = (slot, kid, value, 1.0)
            n += 1
        fold_staged()

    @staticmethod
    def _unique_tables(st: _StageState) -> list[_KeyTable]:
        """A stage's tables deduped by identity — a hashed join aliases
        one shared table in both side slots."""
        seen: list[_KeyTable] = []
        for table in st.tables:
            if not any(table is u for u in seen):
                seen.append(table)
        return seen

    def _late_dropped(self) -> int:
        return sum(st.tracker.late_dropped for st in self.stages)

    def _total_collisions(self) -> int:
        return sum(table.collisions for st in self.stages
                   for table in self._unique_tables(st))

    @lane("prefetch")
    def _prepare_batch(self, batch: MicroBatch) -> _PreparedBatch:
        """Prepare-lane work for one micro-batch: size check, routing each
        record to its external input's root stage, and the fused map
        chains.  Reads only the immutable program, so the prefetch thread
        runs it for batch N+1 while the main thread folds batch N; the
        synchronous path calls it inline."""
        prog = self.prog
        if len(batch.records) > prog.batch_records:
            raise ValueError(
                f"micro-batch {batch.index} carries {len(batch.records)} "
                f"records but the coordinator was sized for batch_records="
                f"{prog.batch_records}; create the StreamSource with "
                f"batch_records <= the coordinator's")
        if len(prog.inputs) == 1:
            # single-input fast path: no per-record re-tagging on the hot
            # path (the input necessarily lands at stage 0, side 0)
            groups: dict[int, list] = {0: batch.records}
        else:
            groups = {}
            for rec in batch.records:
                tag = int(rec[3]) if len(rec) > 3 else 0
                si, side = prog.inputs[tag]
                groups.setdefault(si, []).append(
                    (rec[0], rec[1], rec[2], side))
        return _PreparedBatch(
            index=batch.index, n_records=len(batch.records),
            max_event_time=batch.max_event_time,
            groups={si: self._transform_recs(si, raw)
                    for si, raw in groups.items()})

    def _process_prepared(self, prep: _PreparedBatch,
                          report: StreamReport) -> None:
        """Fold + drain lanes for one prepared micro-batch: admit → fold
        (device) → watermark → finalize, cascading finalized windows
        through the DAG in one topological sweep, then drain the deferred
        stats at the barrier and checkpoint if due.  Normally one fused
        collective per batch per side; a batch that spans more windows
        than the ring holds (low event rate relative to batch size) folds
        and finalizes mid-batch instead of aborting."""
        prog = self.prog
        t0 = time.perf_counter()
        self.bus.poll(self.consumer_group, TOPIC_STREAM_BATCH,
                      timeout=0.01, max_records=1)
        self._autoscale(report)
        late_before = self._late_dropped()
        report.records_in += prep.n_records
        for si in sorted(prep.groups):
            recs = prep.groups[si]
            if not recs:
                continue
            self._grow_wire(si, recs)
            stage = self.stages[si]
            if stage.plan.is_session:
                self._ingest_session(si, recs, report)
            elif prog.fanout == "device":
                self._ingest_device(si, recs, report)
            else:
                self._ingest_host(si, recs, report)
        # every root shares the merged stream's event-time watermark (a
        # multi-root join consumes one merged, side-tagged source)
        for si in self._roots:
            self._ext_wm[si] = max(self._ext_wm.get(si, _NEG_INF),
                                   prep.max_event_time)
            self._observe(si)
        self._finalize_sweep(report, set(self._roots))
        self._drain_stats(report)       # micro-batch barrier: lanes empty
        report.late_dropped += self._late_dropped() - late_before
        report.hash_collisions = self._total_collisions()
        report.batches += 1
        self._records_consumed += prep.n_records
        # sparser checkpoints trade restart replay (the log is replayable
        # from the last checkpoint) for hot-path device syncs; interval 0
        # disables checkpointing entirely (the batch-mode drive)
        if self._ckpt_interval and \
                (prep.index + 1) % self._ckpt_interval == 0:
            self.save_state()
        report.batch_latencies.append(time.perf_counter() - t0)

    def process_batch(self, batch: MicroBatch,
                      report: StreamReport) -> None:
        """One micro-batch round, prepared and processed inline — the
        synchronous entry point (``run_stream`` overlaps the two halves
        when ``RunOptions.overlap`` is on)."""
        self._process_prepared(self._prepare_batch(batch), report)

    def flush_end_of_stream(self, report: StreamReport) -> None:
        """Finalize every still-open window as if the stream had ended:
        checkpoint first, then ripple an end-of-stream watermark (+inf)
        through every stage in topological order and drain the lanes.

        Checkpointing happens BEFORE the artificial watermark: a later run
        over a grown log must resume with the real watermark, not +inf
        (which would drop every new event as late); flushed windows then
        re-finalize idempotently.  The job server calls this when parking
        or finishing a job, so a parked job's sink bytes match a
        standalone flushed run's exactly."""
        if report.batches and self._ckpt_interval:
            self.save_state()
        for si in range(len(self.stages)):
            if si in self._roots:
                self._ext_wm[si] = float("inf")
            self.stages[si].tracker.observe(float("inf"))
            self._finalize_ripe(report, si)
        self._drain_stats(report)
        self._flush_sinks(report)

    def run_stream(self, source, *, announce: bool = True,
                   flush: bool = True) -> StreamReport:
        """Consume the whole currently-available log; with ``flush`` also
        finalize the still-open windows at the end (end-of-stream watermark
        → +inf, rippled through every stage), which a truly continuous
        deployment would never do.

        With ``RunOptions.overlap`` on, a background prefetcher reads and
        host-prepares batch N+1 while batch N folds; a crash leaves the
        prepared-but-unconsumed batches unconsumed (the record offset only
        advances at the barrier), so restart replays them from the
        checkpoint exactly like the synchronous loop."""
        report = StreamReport(self.prog.job_id)
        t_start = time.perf_counter()
        start = self.restore_state()
        try:
            if announce:
                self.announce(source, start_record=start)
            if self.opts.overlap:
                prefetcher = _Prefetcher(source.batches(start_record=start),
                                         self._prepare_batch,
                                         self.opts.prefetch_batches)
                try:
                    for prep in prefetcher:
                        self._process_prepared(prep, report)
                finally:
                    prefetcher.close()
            else:
                for batch in source.batches(start_record=start):
                    self.process_batch(batch, report)
            if flush:
                self.flush_end_of_stream(report)
        except Exception as exc:
            report.error = str(exc)
            raise
        finally:
            report.wall_time = time.perf_counter() - t_start
        return report

    # -- introspection ---------------------------------------------------------
    def checkpointed_offset(self) -> int:
        return saved_offset(self.meta, self.prog.job_id)

    def pool_stats(self) -> dict[str, Any]:
        return self.pool.stats()

    # Public seam for external drive loops (the job server's overlapped
    # multi-tenant scheduler): the prepare-lane and fold/drain-lane halves
    # of process_batch, so a driver can run many jobs' prepare lanes on
    # threads while folding each job's batches in order on its own thread.
    def prepare_batch(self, batch: MicroBatch) -> _PreparedBatch:
        """Host-prepare one micro-batch (pure, prefetch-lane safe) — the
        first half of ``process_batch``, exposed for external drivers."""
        return self._prepare_batch(batch)

    def process_prepared(self, prep: _PreparedBatch,
                         report: StreamReport) -> None:
        """Fold/drain one prepared batch on the driver thread in batch
        order — the second half of ``process_batch``, exposed for
        external drivers."""
        return self._process_prepared(prep, report)


# Same seam: the bounded prepare-lane thread run_stream uses, exported so
# external drivers multiplex one per job instead of reinventing the
# ("batch" | "end" | "error") handoff protocol.
Prefetcher = _Prefetcher


def saved_offset(meta: MetadataStore, job_id: str) -> int:
    """Record offset of ``job_id``'s last barrier checkpoint in ``meta``
    (0 when none) — readable without constructing a coordinator.  The job
    server reports a parked/re-attached job's position from this instead
    of the pre-park live counters, which die with the coordinator."""
    state = meta.get(_state_key(job_id))
    return int(state["offset"]) if state else 0


def _fnv24(key: Any) -> int:
    """Deprecated alias — the helper moved to ``engine.stages.fold_key24``
    so host and device key folding share one source of truth."""
    warnings.warn("_fnv24 moved to repro.engine.stages.fold_key24",
                  DeprecationWarning, stacklevel=2)
    return fold_key24(key)


def _murmur_bucket(raw: int, num_buckets: int) -> int:
    """Deprecated alias — see ``engine.stages.host_bucket``."""
    warnings.warn("_murmur_bucket moved to repro.engine.stages.host_bucket",
                  DeprecationWarning, stacklevel=2)
    return host_bucket(raw, num_buckets)
