"""StreamingCoordinator — continuous MapReduce, one round per micro-batch.

Where ``core.coordinator.Coordinator`` drives a one-shot job to DONE and
terminates, this coordinator runs a long-lived loop: consume the next
micro-batch trigger, fold the batch through the execution-plan layer
(``repro.engine``), advance the watermark, and finalize + emit every window
the watermark has passed.  The full streaming state — consumed record
offset, carried window aggregates, watermark/ring tracker, key dictionary —
checkpoints at batch boundaries (metadata + object store), so a restarted
coordinator resumes exactly where it stopped, even over a log that has
grown since — the streaming analogue of ``Coordinator.resume_job``.

The plan space (``StreamingConfig`` → ``ExecutionPlan``):

  * ``fanout="device"`` (default) — a record crosses host→device **once**
    as a ``[last_window_index, n_windows, key, value, valid]`` row and the
    fan-out stage replicates it into its ``ceil(size/slide)`` overlapping
    windows on-chip (broadcast + iota); late (record, window) pairs are
    masked and counted against the watermark bound the host ships per fold.
    ``fanout="host"`` keeps the PR 1 event × window numpy expansion as a
    measured baseline (``benchmarks/bench_streaming.py`` compares the two).
  * ``mode="aggregate"`` — count/sum/mean folded by one fused
    ``reduce_scatter`` per batch into a dense scattered carry.
    ``mode="group"`` — arbitrary ``reduce_fn`` over each (window, key)'s
    full value list: records exchange over the flattened (slot, bucket) id
    space into fixed-capacity per-slot buffers and reduce at finalization.
  * ``key_space="dense"`` — keys get dense ids from a bounded dictionary
    (raises past ``num_buckets``).  ``key_space="hashed"`` — open domains:
    keys fold to a 24-bit raw id (exact in the float32 wire) and hash into
    buckets on-device; colliding keys share a bucket and are reported
    (``StreamReport.hash_collisions``) instead of raising.

Scaling is backpressure-driven: the source announces each batch on
``TOPIC_STREAM_BATCH``; the coordinator is a consumer group on that topic and
sizes its mapper pool from the consumer lag (queue depth) instead of a fixed
split count — KEDA's Kafka-lag signal where the batch engine uses KPA
concurrency.
"""

from __future__ import annotations

import io
import math
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.autoscaler import AutoscalerConfig, ServerlessPool
from ..core.events import (EventBus, TOPIC_STREAM_BATCH, TOPIC_STREAM_WINDOW,
                           batch_event, window_event)
from ..core.metadata import MetadataStore
from ..core.storage import ObjectStore
from ..core.workers import _encode_records
from ..engine.plan import ExecutionPlan, KeySpace, ReduceSpec, WindowSpec
from ..engine.stages import SEGMENT_REDUCE_KINDS as GROUP_KINDS
from .source import MicroBatch, StreamSource
from .state import LateEventError, WindowTracker
from .windows import SlidingWindows, TumblingWindows, Window, WindowAssigner

AGGREGATIONS = ("count", "sum", "mean")
_RAW_KEY_BITS = 24      # raw hashed-key ids must survive the float32 wire
_MAX_WIRE_INT = 1 << 24  # largest int the float32 wire carries exactly


@dataclass
class StreamingConfig:
    """Stream-job analogue of the batch ``JobConfig`` JSON document."""

    num_buckets: int = 128          # key-id space (dense bucket width)
    n_workers: int = 8              # device-engine mesh-axis size
    window_size: float = 60.0       # seconds of event time per window
    window_slide: float | None = None  # None → tumbling; else sliding
    allowed_lateness: float = 0.0   # watermark slack for out-of-order events
    n_slots: int = 8                # in-flight window ring capacity
    batch_records: int = 1024       # micro-batch size bound
    aggregation: str = "count"      # aggregate mode: count | sum | mean
    mode: str = "aggregate"         # aggregate | group (arbitrary reduce_fn)
    reduce_fn: str | Callable = "sum"   # group mode: kind name or callable
    capacity: int = 0               # group mode: per-(worker, slot) records
    key_space: str = "dense"        # dense | hashed (open key domains)
    fanout: str = "device"          # device | host (legacy baseline)
    checkpoint_interval: int = 1    # save restart state every N batches
    output_prefix: str = "stream-output/"
    backend: str = "vmap"
    job_id: str = field(default_factory=lambda: "s" + uuid.uuid4().hex[:11])

    def validate(self) -> None:
        if self.mode not in ("aggregate", "group"):
            raise ValueError("mode must be 'aggregate' or 'group'")
        if self.mode == "aggregate":
            if self.aggregation not in AGGREGATIONS:
                raise ValueError(f"aggregation must be one of {AGGREGATIONS}")
            if self.num_buckets % self.n_workers != 0:
                raise ValueError(
                    "num_buckets must divide by n_workers so window "
                    "slices stay aligned to the scattered carry")
        else:
            if self.capacity < 1:
                raise ValueError("group mode needs capacity >= 1 (records "
                                 "buffered per worker per window slot)")
            if self.fanout != "device":
                raise ValueError("group mode runs with fanout='device'")
            if isinstance(self.reduce_fn, str) \
                    and self.reduce_fn not in GROUP_KINDS:
                raise ValueError(f"reduce_fn must be a callable or one of "
                                 f"{GROUP_KINDS}")
        if self.key_space not in ("dense", "hashed"):
            raise ValueError("key_space must be 'dense' or 'hashed'")
        if self.fanout not in ("device", "host"):
            raise ValueError("fanout must be 'device' or 'host'")
        if self.n_slots < 2:
            raise ValueError("need >= 2 window slots (one closing, one open)")
        if self.checkpoint_interval < 1:
            raise ValueError("checkpoint_interval must be >= 1")
        if self.window_slide is not None and self.window_slide > self.window_size:
            raise ValueError("slide must not exceed window size")
        # the ring must hold every window that can be open at one instant:
        # those covering (watermark, watermark + size + lateness]
        step = self.window_slide or self.window_size
        need = math.ceil((self.window_size + self.allowed_lateness) / step) + 1
        if need > self.n_slots:
            raise ValueError(
                f"n_slots={self.n_slots} cannot hold the "
                f"window_size+allowed_lateness span; need >= {need} slots "
                f"for size={self.window_size}, slide={step}, "
                f"lateness={self.allowed_lateness}")

    def assigner(self) -> WindowAssigner:
        if self.window_slide is None:
            return TumblingWindows(self.window_size)
        return SlidingWindows(self.window_size, self.window_slide)

    def plan(self) -> ExecutionPlan:
        """The streaming job as a point in the execution-plan space."""
        if self.key_space == "hashed":
            keys = KeySpace.hashed(self.num_buckets, track_collisions=False)
        else:
            keys = KeySpace.dense(self.num_buckets)
        window = WindowSpec(size=self.window_size, slide=self.window_slide,
                            n_slots=self.n_slots,
                            fanout_on_device=self.fanout == "device")
        reduce = ReduceSpec(mode=self.mode, reduce_fn=self.reduce_fn,
                            capacity=self.capacity)
        return ExecutionPlan(key_space=keys, reduce=reduce,
                             n_workers=self.n_workers, window=window)


@dataclass
class StreamReport:
    """Rolling accounting for a streaming run — the Fig. 6/7 quantities
    reinterpreted for sustained throughput."""

    job_id: str
    batches: int = 0
    records_in: int = 0             # raw events consumed
    records_expanded: int = 0       # after window fan-out (sliding > 1×)
    late_dropped: int = 0
    windows_emitted: int = 0
    wall_time: float = 0.0
    batch_latencies: list[float] = field(default_factory=list)
    max_lag: int = 0                # worst backpressure observed
    scale_events: int = 0           # pool resizes driven by lag
    hash_collisions: int = 0        # hashed key space: keys sharing a bucket
    capacity_dropped: int = 0       # group mode: window-buffer overflow
    error: str | None = None

    @property
    def records_per_sec(self) -> float:
        return self.records_in / self.wall_time if self.wall_time else 0.0

    @property
    def mean_batch_latency(self) -> float:
        ls = self.batch_latencies
        return sum(ls) / len(ls) if ls else 0.0


def window_output_key(cfg: StreamingConfig, window: Window) -> str:
    return (f"{cfg.output_prefix.rstrip('/')}/{cfg.job_id}/"
            f"window-{window.start:.3f}-{window.end:.3f}")


def _state_key(job_id: str) -> str:
    return f"stream/{job_id}/state"


def _carry_key(job_id: str) -> str:
    return f"jobs/{job_id}/stream/carry"


def _fnv24(key: Any) -> int:
    """Stable key → 24-bit raw id (FNV-1a 64, xor-folded).  Small enough to
    ride the float32 wire exactly; the device hashes it into buckets."""
    h = 0xCBF29CE484222325
    for b in str(key).encode():
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return (h ^ (h >> 24) ^ (h >> 48)) & ((1 << _RAW_KEY_BITS) - 1)


def _murmur_bucket(raw: int, num_buckets: int) -> int:
    """Host mirror of ``engine.stages.device_hash`` % num_buckets, for
    labeling hashed buckets with the keys that landed in them."""
    h = raw & 0xFFFFFFFF
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & 0xFFFFFFFF
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & 0xFFFFFFFF
    h ^= h >> 16
    return h % num_buckets


class StreamingCoordinator:
    """Long-lived coordinator: micro-batch rounds over a continuous stream."""

    CONSUMER_GROUP = "streaming-coordinator"

    def __init__(self, store: ObjectStore, meta: MetadataStore,
                 cfg: StreamingConfig, bus: EventBus | None = None,
                 autoscaler: AutoscalerConfig | None = None) -> None:
        cfg.validate()
        self.store = store
        self.meta = meta
        self.cfg = cfg
        self.bus = bus or EventBus()
        self.assigner = cfg.assigner()
        self.pool = ServerlessPool(
            "stream-mapper", autoscaler or AutoscalerConfig(
                max_scale=cfg.n_workers))
        # compiled once per stream: the per-batch fold (fused reduce_scatter
        # for aggregates, fan-out + exchange + buffer-append for group mode)
        self._compiled = cfg.plan().compile(backend=cfg.backend)
        self._carry = self._compiled.init_carry()
        self.tracker = WindowTracker(self.assigner, cfg.n_slots,
                                     cfg.allowed_lateness)
        # bounded key→bucket-id dictionary (the data layer's vocab analogue)
        self._key_ids: dict[Any, int] = {}
        self._id_keys: list[Any] = []
        # hashed key space: raw-id cache + bucket → first-seen keys (labels)
        self._raw_ids: dict[Any, int] = {}
        self._bucket_keys: dict[int, list] = {}
        self._hash_collisions = 0
        self._window_base = 0           # per-batch wire-index rebase
        self._records_consumed = 0      # checkpointed resume point (records)
        # fixed per-batch array capacity so XLA compiles a single program:
        # device fan-out ships one row per record; host fan-out pre-expands
        if cfg.fanout == "device":
            cap, self._row_width = cfg.batch_records, 5
        else:
            fanout = self.assigner.max_windows_per_event()
            cap, self._row_width = cfg.batch_records * fanout, 4
        self._per_worker = -(-cap // cfg.n_workers)

    # -- key dictionary --------------------------------------------------------
    def _key_id(self, key: Any) -> int:
        if self.cfg.key_space == "hashed":
            return self._raw_key_id(key)
        kid = self._key_ids.get(key)
        if kid is None:
            kid = len(self._id_keys)
            if kid >= self.cfg.num_buckets:
                raise ValueError(
                    f"distinct key count exceeded num_buckets="
                    f"{self.cfg.num_buckets}; raise it (keys seen: {kid}) "
                    f"or open the domain with key_space='hashed'")
            self._key_ids[key] = kid
            self._id_keys.append(key)
        return kid

    def _raw_key_id(self, key: Any) -> int:
        """Open domain: fold the key to its raw wire id, remember which keys
        landed in which bucket so emissions stay labeled and collisions are
        counted instead of raising."""
        raw = self._raw_ids.get(key)
        if raw is None:
            raw = _fnv24(key)
            self._raw_ids[key] = raw
            seen = self._bucket_keys.setdefault(
                _murmur_bucket(raw, self.cfg.num_buckets), [])
            if seen and key not in seen:
                self._hash_collisions += 1
            if key not in seen:
                seen.append(key)
        return raw

    def _label(self, kid: int) -> str:
        """Output key for bucket/key id ``kid``."""
        if self.cfg.key_space == "dense":
            return str(self._id_keys[kid])
        seen = self._bucket_keys.get(kid)
        if not seen:
            return f"bucket-{kid}"
        if len(seen) == 1:
            return str(seen[0])
        return f"bucket-{kid}[{'|'.join(sorted(str(k) for k in seen))}]"

    # -- batch ingestion -------------------------------------------------------
    def _fold_device(self, rows: np.ndarray, report: StreamReport) -> None:
        """Fold one-row-per-record [last_window, n_windows, key, value,
        valid] rows through the plan's step; the device fans out, masks late
        pairs against the watermark bound, and returns the accounting.
        Window indices on the wire are rebased by the per-batch
        ``_window_base`` (a multiple of ``n_slots``, so modular slots are
        unchanged) to stay exact in float32 at any absolute event time."""
        data = rows.reshape(self.cfg.n_workers, self._per_worker, 5)
        bound = self.tracker.min_admissible() - self._window_base
        bound = max(min(bound, 2 ** 31 - 1), -(2 ** 31))
        self._carry, stats = self.pool.submit(
            self._compiled.step, data, self._carry, bound)
        late, expanded, dropped = (int(x) for x in np.asarray(stats))
        self.tracker.note_late(late)
        report.records_expanded += expanded
        report.capacity_dropped += dropped

    def _fold_host(self, rows: np.ndarray) -> None:
        """Legacy host-fan-out fold: [window_slot, key, value, valid] rows,
        already expanded event × window on the host."""
        data = rows.reshape(self.cfg.n_workers, self._per_worker, 4)
        self._carry, _ = self.pool.submit(self._compiled.step, data,
                                          self._carry)

    # -- window finalization --------------------------------------------------
    def _emit_window(self, window_index: int, slot: int) -> None:
        cfg = self.cfg
        window = self.assigner.window(window_index)
        records: list[tuple[str, Any]] = []
        if cfg.mode == "aggregate":
            agg = self._compiled.read_slot(self._carry, slot)
            sums, counts = agg[:, 0], agg[:, 1]
            for kid in np.nonzero(counts > 0)[0]:
                if cfg.aggregation == "count":
                    val: Any = int(counts[kid])
                elif cfg.aggregation == "sum":
                    val = float(sums[kid])
                else:
                    val = float(sums[kid] / counts[kid])
                records.append((self._label(int(kid)), val))
        else:
            gk, gv, gvalid = self._compiled.finalize_slot(self._carry, slot)
            records = [(self._label(int(k)), float(v))
                       for k, v, ok in zip(gk, gv, gvalid) if ok]
        records.sort(key=lambda kv: kv[0])
        out_key = window_output_key(cfg, window)
        self.store.put(out_key, _encode_records(records))
        self.bus.produce(TOPIC_STREAM_WINDOW,
                         window_event(cfg.job_id, window.start, window.end,
                                      len(records), out_key),
                         key=f"{cfg.job_id}/{window.start}")
        self._carry = self._compiled.clear_slot(self._carry, slot)
        self.tracker.release(window_index)

    def _finalize_ripe(self, report: StreamReport) -> None:
        for window_index, slot in self.tracker.ripe():
            self._emit_window(window_index, slot)
            report.windows_emitted += 1

    # -- checkpoint / restore --------------------------------------------------
    def _save_state(self) -> None:
        """Persist the full streaming state at a batch boundary: carry
        leaves to the object store, tracker + key dictionary + the consumed
        *record* offset to the metadata store.  Record addressing (not batch
        indices) keeps resume correct when the log grows past a
        previously-partial final batch.  A restarted coordinator re-folds at
        most the batches since the last checkpoint; window emissions are
        idempotent (same carry → same bytes), keeping restart effectively
        exactly-once."""
        leaves = [np.asarray(leaf)
                  for leaf in jax.tree_util.tree_leaves(self._carry)]
        buf = io.BytesIO()
        np.savez(buf, **{f"leaf{i}": leaf for i, leaf in enumerate(leaves)})
        self.store.put(_carry_key(self.cfg.job_id), buf.getvalue())
        self.meta.set(_state_key(self.cfg.job_id), {
            "offset": self._records_consumed,
            "carry_shapes": [list(leaf.shape) for leaf in leaves],
            "tracker": self.tracker.state_dict(),
            "keys": list(self._id_keys),
            "bucket_keys": [[kid, keys]
                            for kid, keys in self._bucket_keys.items()],
            "hash_collisions": self._hash_collisions,
        })

    def _restore_state(self) -> int:
        """Load a prior run's checkpoint; returns the record offset to
        resume from (0 when starting fresh)."""
        state = self.meta.get(_state_key(self.cfg.job_id))
        if state is None:
            self._records_consumed = 0
            return 0
        if "carry_shapes" not in state:
            raise ValueError(
                f"checkpoint for job {self.cfg.job_id} predates the "
                f"execution-plan carry format (PR 2); restart the stream "
                f"under a fresh job_id or replay it from the log")
        leaves, treedef = jax.tree_util.tree_flatten(self._carry)
        shapes = [tuple(s) for s in state["carry_shapes"]]
        if shapes != [leaf.shape for leaf in leaves]:
            raise ValueError(
                f"checkpointed carry shapes {shapes} do not match this "
                f"coordinator's {[leaf.shape for leaf in leaves]}; the "
                f"streaming config changed under job {self.cfg.job_id}")
        blob = self.store.get(_carry_key(self.cfg.job_id))
        with np.load(io.BytesIO(blob)) as loaded:
            restored = [jnp.asarray(loaded[f"leaf{i}"])
                        for i in range(len(leaves))]
        self._carry = jax.tree_util.tree_unflatten(treedef, restored)
        self.tracker.load_state_dict(state["tracker"])
        self._id_keys = list(state["keys"])
        self._key_ids = {k: i for i, k in enumerate(self._id_keys)}
        self._bucket_keys = {int(kid): list(keys)
                             for kid, keys in state.get("bucket_keys", [])}
        self._raw_ids = {k: _fnv24(k)
                         for keys in self._bucket_keys.values() for k in keys}
        self._hash_collisions = int(state.get("hash_collisions", 0))
        self._records_consumed = int(state["offset"])
        return self._records_consumed

    # -- backpressure ----------------------------------------------------------
    def _autoscale(self, report: StreamReport) -> None:
        lag = self.bus.lag(self.CONSUMER_GROUP, TOPIC_STREAM_BATCH)
        report.max_lag = max(report.max_lag, lag)
        want = self.pool.desired_scale_from_backlog(lag)
        if want > self.pool.replicas():
            self.pool.ensure_scale(want)
            report.scale_events += 1
        elif want < self.pool.replicas():
            if self.pool.reap_idle():
                report.scale_events += 1

    # -- the streaming loop -----------------------------------------------------
    def announce(self, source: StreamSource, start_record: int = 0) -> int:
        """Publish one trigger CloudEvent per available micro-batch — the
        stand-in for a Kafka producer filling the topic ahead of the
        consumer.  The resulting consumer lag drives autoscaling.
        ``start_record`` skips already-processed records on resume so the
        lag signal reflects real backlog, not replayed history.  Uses
        record counts only (``batch_sizes``), so the log's payloads are
        parsed once — by the processing loop, not here."""
        n = 0
        for index, size in enumerate(source.batch_sizes(start_record)):
            self.bus.produce(
                TOPIC_STREAM_BATCH,
                batch_event(self.cfg.job_id, index, size),
                key=f"{self.cfg.job_id}/{index}")
            n += 1
        return n

    def _ingest_device(self, batch: MicroBatch,
                       report: StreamReport) -> None:
        """Device fan-out ingestion: one 5-column row per record; window
        *indices* are assigned host-side in float64 (bit-identical to the
        host-fan-out assigner) but the event × window expansion happens
        on-chip.  A batch that spans more windows than the ring holds folds
        and finalizes mid-batch instead of aborting — splitting the
        triggering record's coverage so pairs admitted before the mid-batch
        watermark advance still land, exactly like the host path."""
        cfg = self.cfg
        w0 = self.assigner.window(0)
        step = self.assigner.window(1).start - w0.start
        ts = np.array([r[0] for r in batch.records], np.float64)
        rel = ts - w0.start
        last = np.floor(rel / step).astype(np.int64)
        if cfg.window_slide is None:
            first = last
        else:
            first = np.floor((rel - w0.size) / step).astype(np.int64) + 1
        # rebase wire indices so they stay exact in float32 at any absolute
        # event time; a multiple of n_slots keeps w % n_slots unchanged
        base = (int(first.min()) // cfg.n_slots) * cfg.n_slots
        if int(last.max()) - base >= _MAX_WIRE_INT:
            raise ValueError(
                f"micro-batch {batch.index} spans "
                f"{int(last.max()) - base} windows, beyond the float32 "
                f"wire's exact-integer range; reduce batch_records or "
                f"raise the window slide")
        self._window_base = base
        rows = np.zeros((cfg.n_workers * self._per_worker, 5), np.float32)
        n = 0
        seen = float("-inf")        # stream position within this batch
        for i, (tsi, key, value) in enumerate(batch.records):
            report.records_in += 1
            seen = tsi if tsi > seen else seen
            kid = self._key_id(key)
            lo, hi = int(first[i]), int(last[i])
            start = lo
            for widx in range(lo, hi + 1):
                if widx in self.tracker.active or self.tracker.is_late(widx):
                    continue        # device masks + counts the late pairs
                try:
                    self.tracker.slot_for(widx)
                except LateEventError:
                    # ring full mid-batch: ship this record's already-safe
                    # window span, fold what we have, advance the watermark
                    # to the position reached, finalize ripe windows, then
                    # retry (a second failure is a genuine capacity error
                    # and propagates)
                    if widx > start:
                        rows[n] = (widx - 1 - base, widx - start, kid,
                                   value, 1.0)
                        n += 1
                        start = widx
                    if n:
                        self._fold_device(rows, report)
                        # the dispatched fold may zero-copy-alias the numpy
                        # buffer; a fresh buffer avoids racing the in-flight
                        # computation with our next writes
                        rows = np.zeros_like(rows)
                        n = 0
                    self.tracker.observe(seen)
                    self._finalize_ripe(report)
                    if not self.tracker.is_late(widx):
                        self.tracker.slot_for(widx)
                    # else: the watermark advance closed widx; the device
                    # masks + counts the pair (slot_for would double-count)
            if hi >= start:
                rows[n] = (hi - base, hi - start + 1, kid, value, 1.0)
                n += 1
        self._fold_device(rows, report)
        self.tracker.observe(batch.max_event_time)
        self._finalize_ripe(report)

    def _ingest_host(self, batch: MicroBatch, report: StreamReport) -> None:
        """Legacy host fan-out: expand every record into one row per
        containing window on the host (numpy), the PR 1 baseline the
        device path is benchmarked against."""
        cfg = self.cfg
        rows = np.zeros((cfg.n_workers * self._per_worker, 4), np.float32)
        n = 0
        seen = float("-inf")
        for ts, key, value in batch.records:
            report.records_in += 1
            seen = ts if ts > seen else seen
            for widx in self.assigner.assign(ts):
                try:
                    slot = self.tracker.slot_for(widx)
                except LateEventError:
                    if n:
                        self._fold_host(rows)
                        report.records_expanded += n
                        rows = np.zeros_like(rows)
                        n = 0
                    self.tracker.observe(seen)
                    self._finalize_ripe(report)
                    slot = self.tracker.slot_for(widx)
                if slot is None:        # late: window already emitted
                    continue
                rows[n] = (slot, self._key_id(key), value, 1.0)
                n += 1
        report.records_expanded += n
        self._fold_host(rows)
        self.tracker.observe(batch.max_event_time)
        self._finalize_ripe(report)

    def process_batch(self, batch: MicroBatch,
                      report: StreamReport) -> None:
        """One micro-batch round: admit → fold (device) → watermark →
        finalize.  Normally one fused collective per batch; a batch that
        spans more windows than the ring holds (low event rate relative to
        batch size) folds and finalizes mid-batch instead of aborting."""
        cfg = self.cfg
        if len(batch.records) > cfg.batch_records:
            raise ValueError(
                f"micro-batch {batch.index} carries {len(batch.records)} "
                f"records but the coordinator was sized for batch_records="
                f"{cfg.batch_records}; create the StreamSource with "
                f"batch_records <= the coordinator's")
        t0 = time.perf_counter()
        self.bus.poll(self.CONSUMER_GROUP, TOPIC_STREAM_BATCH,
                      timeout=0.01, max_records=1)
        self._autoscale(report)
        late_before = self.tracker.late_dropped
        if cfg.fanout == "device":
            self._ingest_device(batch, report)
        else:
            self._ingest_host(batch, report)
        report.late_dropped += self.tracker.late_dropped - late_before
        report.hash_collisions = self._hash_collisions
        report.batches += 1
        self._records_consumed += len(batch.records)
        # sparser checkpoints trade restart replay (the log is replayable
        # from the last checkpoint) for hot-path device syncs
        if (batch.index + 1) % self.cfg.checkpoint_interval == 0:
            self._save_state()
        report.batch_latencies.append(time.perf_counter() - t0)

    def run_stream(self, source: StreamSource, *, announce: bool = True,
                   flush: bool = True) -> StreamReport:
        """Consume the whole currently-available log; with ``flush`` also
        finalize the still-open windows at the end (end-of-stream watermark
        → +inf), which a truly continuous deployment would never do."""
        report = StreamReport(self.cfg.job_id)
        t_start = time.perf_counter()
        start = self._restore_state()
        try:
            if announce:
                self.announce(source, start_record=start)
            for batch in source.batches(start_record=start):
                self.process_batch(batch, report)
            if flush:
                # checkpoint BEFORE the artificial end-of-stream watermark:
                # a later run over a grown log must resume with the real
                # watermark, not +inf (which would drop every new event as
                # late); flushed windows then re-finalize idempotently
                if report.batches:
                    self._save_state()
                self.tracker.observe(float("inf"))
                self._finalize_ripe(report)
        except Exception as exc:
            report.error = str(exc)
            raise
        finally:
            report.wall_time = time.perf_counter() - t_start
        return report

    # -- introspection ---------------------------------------------------------
    def checkpointed_offset(self) -> int:
        state = self.meta.get(_state_key(self.cfg.job_id))
        return int(state["offset"]) if state else 0

    def pool_stats(self) -> dict[str, Any]:
        return self.pool.stats()
