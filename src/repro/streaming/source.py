"""Replayable event-log source — the Kafka-topic stand-in for streams.

A stream is a sequence of ``(event_time, key, value)`` records persisted as
JSON-lines *segment* objects under an object-store prefix (append-only, like
a Kafka partition's segment files).  ``StreamSource`` reads the log in key
order and chunks it into bounded micro-batches; because segments are
immutable, iteration is replayable from the start — the property worker
restarts and exactly-once-ish reprocessing rely on, same as the batch
engine's idempotent spills.

Producers call ``write_event_log`` (or ``StreamSource.from_records`` for
in-memory tests/benchmarks, which skips storage entirely).
"""

from __future__ import annotations

import io
import json
from dataclasses import dataclass
from typing import Any, Iterable, Iterator

from ..core.storage import ObjectStore


def write_event_log(store: ObjectStore, prefix: str,
                    events: Iterable[tuple[float, Any, float]],
                    segment_records: int = 4096) -> int:
    """Append events to the log as numbered JSON-lines segment objects.
    Returns the number of records written."""
    existing = len(store.list_objects(prefix.rstrip("/") + "/segment-"))
    buf = io.BytesIO()
    n_seg, n_rec, in_seg = existing, 0, 0

    def flush() -> None:
        nonlocal n_seg, in_seg
        if in_seg:
            # record count travels in the key (-nNNN) so readers can skip
            # or size segments without downloading them
            key = f"{prefix.rstrip('/')}/segment-{n_seg:06d}-n{in_seg}"
            store.put(key, buf.getvalue())
            n_seg += 1
            in_seg = 0
            buf.seek(0)
            buf.truncate()

    for ts, key, value in events:
        buf.write(json.dumps([ts, key, value],
                             separators=(",", ":")).encode())
        buf.write(b"\n")
        n_rec += 1
        in_seg += 1
        if in_seg >= segment_records:
            flush()
    flush()
    return n_rec


@dataclass
class MicroBatch:
    """A bounded chunk of the stream: the unit one incremental round folds."""

    index: int
    records: list  # of (event_time, key, value)

    @property
    def max_event_time(self) -> float:
        return max(r[0] for r in self.records)

    @property
    def min_event_time(self) -> float:
        return min(r[0] for r in self.records)

    def __len__(self) -> int:
        return len(self.records)


class StreamSource:
    """Chunk a persisted (or in-memory) event log into micro-batches."""

    def __init__(self, store: ObjectStore | None = None, prefix: str = "",
                 records: Iterable[tuple[float, Any, float]] | None = None,
                 batch_records: int = 1024) -> None:
        if (store is None) == (records is None):
            raise ValueError("pass exactly one of (store+prefix, records)")
        if batch_records < 1:
            raise ValueError("batch_records must be >= 1")
        self.store = store
        self.prefix = prefix
        self._records = list(records) if records is not None else None
        self.batch_records = batch_records

    @classmethod
    def from_records(cls, records: Iterable[tuple[float, Any, float]],
                     batch_records: int = 1024) -> "StreamSource":
        return cls(records=records, batch_records=batch_records)

    # -- reading ---------------------------------------------------------------
    def segments(self) -> list[str]:
        assert self.store is not None
        prefix = self.prefix.rstrip("/") + "/segment-"
        return sorted(m.key for m in self.store.list_objects(prefix))

    @staticmethod
    def _segment_count(key: str) -> int | None:
        """Record count embedded in the segment key (-nNNN suffix), or None
        for legacy keys that need a download to count."""
        tail = key.rsplit("-", 1)[-1]
        if tail.startswith("n") and tail[1:].isdigit():
            return int(tail[1:])
        return None

    def _events_from(self, skip: int) -> Iterator[tuple[float, Any, float]]:
        """Records in log order, skipping the first ``skip`` cheaply:
        store-backed logs drop whole already-consumed segments by their
        key-embedded record counts, without downloading them."""
        if self._records is not None:
            yield from self._records[skip:]
            return
        for seg in self.segments():
            count = self._segment_count(seg)
            if count is not None and skip >= count:
                skip -= count
                continue
            lines = [ln for ln in self.store.get(seg).splitlines() if ln]
            if skip >= len(lines):
                skip -= len(lines)
                continue
            for line in lines[skip:]:
                ts, key, value = json.loads(line)
                yield float(ts), key, float(value)
            skip = 0

    def events(self) -> Iterator[tuple[float, Any, float]]:
        """Every record in log order — a fresh, replayable pass."""
        return self._events_from(0)

    def events_from(self, skip: int) -> Iterator[tuple[float, Any, float]]:
        """Records from offset ``skip`` on, skipping consumed segments
        without downloading them — the shared-ingest pump's tail read."""
        return self._events_from(skip)

    def batch_sizes(self, start_record: int = 0) -> list[int]:
        """Per-batch record counts from metadata alone — key-embedded
        segment counts when available, a line count otherwise.  Lets a
        producer announce batch triggers without parsing (or, for counted
        segments, even downloading) the payloads a second time."""
        if self._records is not None:
            total = len(self._records)
        else:
            total = 0
            for seg in self.segments():
                count = self._segment_count(seg)
                if count is None:
                    count = len([ln for ln in self.store.get(seg).splitlines()
                                 if ln])
                total += count
        total = max(0, total - start_record)
        sizes = []
        while total > 0:
            sizes.append(min(total, self.batch_records))
            total -= sizes[-1]
        return sizes

    def batches(self, start_record: int = 0) -> Iterator[MicroBatch]:
        """Chunk the log from record ``start_record`` onward into
        micro-batches of ``batch_records``.

        Resume is record-addressed, not batch-addressed: a restarted
        StreamingCoordinator passes its checkpointed *record* offset, so
        chunk boundaries cannot drift when the log has grown past a
        previously-partial final batch.  Batch indices restart at 0 for each
        iteration — they identify batches within one run.
        """
        chunk: list = []
        index = 0
        for rec in self._events_from(start_record):
            chunk.append(rec)
            if len(chunk) >= self.batch_records:
                yield MicroBatch(index, chunk)
                index += 1
                chunk = []
        if chunk:
            yield MicroBatch(index, chunk)
