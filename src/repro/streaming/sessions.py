"""Session windows — gap-based, data-dependent event-time windows.

Fixed windows are pure arithmetic on the timestamp, so the host and device
can both compute them.  Session boundaries depend on the *observed* events
of each key: a session is a maximal run of events with no inactivity gap
longer than ``gap``, covering ``[first_event, last_event + gap)``.  That
makes assignment inherently host-side state — this module owns it, the way
``state.WindowTracker`` owns the fixed-window ring.

The carry story: a session holds exactly one key, so it does not need a
whole ring slot — it needs one *cell*, a (slot, bucket) pair of the same
scattered aggregate carry the fixed-window plans use.  Sessions of
different keys share slots freely (their buckets differ); two sessions of
the same key must sit in different slots.  When an out-of-order event
bridges two open sessions of one key, the tracker reports a cell *merge*
(src slot → dst slot, same bucket) that the coordinator applies on-device
(``CompiledStreamAggregate.merge_cell``) after folding any staged rows.

Under a hashed key space the tracker sees bucket ids, so keys that collide
into one bucket sessionize together — the same graceful degradation the
hashed aggregate path has.

A session finalizes once the watermark passes its end (last event + gap).
An event older than the watermark is admitted only if it lands inside a
still-open session; otherwise it is late — the session it would have
opened may already have been emitted.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .state import LateEventError


@dataclass
class Session:
    """One open session: ``[start, end)`` with ``end = last_event + gap``,
    carried in cell ``(slot, bucket)`` of the aggregate carry."""

    bucket: int
    start: float
    end: float
    slot: int


@dataclass
class SessionTracker:
    """Tracks open sessions per bucket, their carry cells, the watermark."""

    gap: float
    n_slots: int
    allowed_lateness: float = 0.0
    watermark: float = float("-inf")
    finalized: int = 0
    late_dropped: int = 0
    _open: dict[int, list[Session]] = field(default_factory=dict)
    _cells: set = field(default_factory=set)    # occupied (slot, bucket)

    def __post_init__(self) -> None:
        if self.gap <= 0:
            raise ValueError("session gap must be positive")
        if self.n_slots < 1:
            raise ValueError("need at least one session slot")

    # -- admission -----------------------------------------------------------
    def _overlapping(self, bucket: int, ts: float) -> list[Session]:
        """Open sessions of ``bucket`` the proto-window [ts, ts+gap)
        overlaps — the sessions this event extends or bridges.  Touching
        exactly (distance == gap) does not merge, matching the half-open
        window convention."""
        return [s for s in self._open.get(bucket, ())
                if s.start < ts + self.gap and ts < s.end]

    def admit(self, bucket: int, ts: float
              ) -> tuple[int, list[tuple[int, int]]] | None:
        """Admit one event: returns ``(slot, merges)`` or ``None`` for a
        late drop (the caller accounts it via ``note_late`` — admission
        never writes ``late_dropped`` itself, mirroring the fixed-window
        tracker's single-writer rule).  ``merges`` is a list of
        ``(src_slot, dst_slot)`` cell merges (same bucket) the caller must
        apply to the carry — after folding any rows already staged for the
        source slots — because the event bridged previously separate
        sessions.

        Raises ``LateEventError`` when a new session is needed but every
        slot's cell for this bucket is occupied (the ring is too small for
        the key's concurrent-session count); the caller may fold, advance
        the watermark, finalize, and retry — exactly the fixed-window
        mid-batch protocol.
        """
        hits = self._overlapping(bucket, ts)
        if not hits:
            if ts < self.watermark:
                return None
            sessions = self._open.setdefault(bucket, [])
            for slot in range(self.n_slots):
                if (slot, bucket) not in self._cells:
                    self._cells.add((slot, bucket))
                    sessions.append(Session(bucket, ts, ts + self.gap, slot))
                    return slot, []
            raise LateEventError(
                f"session ring full: all {self.n_slots} slots hold open "
                f"sessions for bucket {bucket}; raise n_slots or reduce "
                f"the session gap / allowed_lateness")
        hits.sort(key=lambda s: s.start)
        survivor = hits[0]
        survivor.start = min(survivor.start, ts)
        survivor.end = max(survivor.end, ts + self.gap)
        merges = []
        for other in hits[1:]:
            survivor.end = max(survivor.end, other.end)
            merges.append((other.slot, survivor.slot))
            self._cells.discard((other.slot, bucket))
            self._open[bucket].remove(other)
        return survivor.slot, merges

    # -- watermark ------------------------------------------------------------
    def observe(self, max_event_time: float) -> float:
        """Advance the watermark (monotone) past a batch's max event time."""
        wm = max_event_time - self.allowed_lateness
        if wm > self.watermark:
            self.watermark = wm
        return self.watermark

    def ripe(self) -> list[Session]:
        """Sessions whose end the watermark has passed, in (start, bucket)
        order — the finalization schedule."""
        done = [s for ss in self._open.values() for s in ss
                if s.end <= self.watermark]
        return sorted(done, key=lambda s: (s.start, s.bucket))

    def release(self, session: Session) -> None:
        """Return a finalized session's cell."""
        self._open[session.bucket].remove(session)
        if not self._open[session.bucket]:
            del self._open[session.bucket]
        self._cells.discard((session.slot, session.bucket))
        self.finalized += 1

    def note_late(self, n: int) -> None:
        """The only writer of ``late_dropped`` — see
        ``WindowTracker.note_late`` for the ownership rule."""
        self.late_dropped += int(n)

    @property
    def open_sessions(self) -> int:
        return sum(len(ss) for ss in self._open.values())

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable snapshot for the coordinator's checkpoint."""
        return {"kind": "session",
                "watermark": self.watermark,
                "sessions": [[s.bucket, s.start, s.end, s.slot]
                             for ss in self._open.values() for s in ss],
                "finalized": self.finalized,
                "late_dropped": self.late_dropped}

    def load_state_dict(self, d: dict) -> None:
        self.watermark = float(d["watermark"])
        self.finalized = int(d["finalized"])
        self.late_dropped = int(d["late_dropped"])
        self._open = {}
        self._cells = set()
        for bucket, start, end, slot in d["sessions"]:
            s = Session(int(bucket), float(start), float(end), int(slot))
            self._open.setdefault(s.bucket, []).append(s)
            self._cells.add((s.slot, s.bucket))
