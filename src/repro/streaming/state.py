"""Watermark bookkeeping and the in-flight window ring.

The device engine carries per-window partial aggregates in a bounded ring of
``n_slots`` carry slots (``core.mapreduce.init_window_carry``).  This module
owns the host-side view of that ring: which window index lives in which slot,
where the watermark stands, which windows are ripe for finalization, and
which events are too late to admit.

Watermark = max event time observed − allowed lateness.  A window finalizes
once the watermark reaches its end; finalization happens in window-start
order so downstream consumers see an ordered stream of closed windows.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .windows import WindowAssigner


class LateEventError(Exception):
    """An event arrived for a window that already finalized."""


@dataclass
class WindowTracker:
    """Tracks in-flight windows, their ring slots, and the watermark."""

    assigner: WindowAssigner
    n_slots: int
    allowed_lateness: float = 0.0
    watermark: float = float("-inf")
    active: dict[int, int] = field(default_factory=dict)   # window idx → slot
    finalized: int = 0
    late_dropped: int = 0
    _free: list[int] = field(default_factory=list)

    def __post_init__(self) -> None:
        if self.n_slots < 1:
            raise ValueError("need at least one window slot")
        self._free = list(range(self.n_slots - 1, -1, -1))

    # -- admission -----------------------------------------------------------
    def is_late(self, window_index: int) -> bool:
        """True when the window already closed (watermark passed its end)."""
        return self.assigner.window(window_index).end <= self.watermark

    def slot_for(self, window_index: int) -> int | None:
        """Ring slot carrying this window, allocating on first sight.

        Returns ``None`` for a late window (the event must be dropped — its
        aggregate was already emitted).  Raises ``LateEventError`` if the ring
        is full, which means ``n_slots`` is too small for the configured
        window span + lateness: admitting the event would corrupt a
        still-active window's carry slice.
        """
        if window_index in self.active:
            return self.active[window_index]
        if self.is_late(window_index):
            self.late_dropped += 1
            return None
        if not self._free:
            raise LateEventError(
                f"window ring full ({self.n_slots} slots, "
                f"{len(self.active)} active windows); raise n_slots or "
                f"reduce allowed_lateness / window overlap")
        slot = self._free.pop()
        self.active[window_index] = slot
        return slot

    # -- watermark ------------------------------------------------------------
    def observe(self, max_event_time: float) -> float:
        """Advance the watermark (monotone) past a batch's max event time."""
        wm = max_event_time - self.allowed_lateness
        if wm > self.watermark:
            self.watermark = wm
        return self.watermark

    def ripe(self) -> list[tuple[int, int]]:
        """(window_index, slot) pairs whose end the watermark has passed,
        in window-start order — the finalization schedule."""
        done = [(w, s) for w, s in self.active.items()
                if self.assigner.window(w).end <= self.watermark]
        return sorted(done, key=lambda ws: self.assigner.window(ws[0]).start)

    def release(self, window_index: int) -> None:
        """Return a finalized window's slot to the ring."""
        slot = self.active.pop(window_index)
        self._free.append(slot)
        self.finalized += 1

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable snapshot for the coordinator's checkpoint."""
        return {"watermark": self.watermark,
                "active": {str(w): s for w, s in self.active.items()},
                "free": list(self._free),
                "finalized": self.finalized,
                "late_dropped": self.late_dropped}

    def load_state_dict(self, d: dict) -> None:
        self.watermark = float(d["watermark"])
        self.active = {int(w): int(s) for w, s in d["active"].items()}
        self._free = [int(s) for s in d["free"]]
        self.finalized = int(d["finalized"])
        self.late_dropped = int(d["late_dropped"])
