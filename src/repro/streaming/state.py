"""Watermark bookkeeping and the in-flight window ring.

The device engine carries per-window partial aggregates in a bounded ring of
``n_slots`` carry slots (``engine.plan``'s streaming carries).  This module
owns the host-side view of that ring: which window index lives in which slot,
where the watermark stands, which windows are ripe for finalization, and
which events are too late to admit.

Slot addressing is *modular*: window ``w`` always lives in slot
``w % n_slots``.  The on-device fan-out stage computes the same expression
(``engine.stages.window_fanout``), so no slot table ever crosses the
host→device boundary — the tracker only validates that the slot is free and
remembers the assignment for finalization.

Watermark = max event time observed − allowed lateness.  A window finalizes
once the watermark reaches its end; finalization happens in window-start
order so downstream consumers see an ordered stream of closed windows.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from .windows import WindowAssigner


class LateEventError(Exception):
    """An event arrived for a window that already finalized."""


@dataclass
class WindowTracker:
    """Tracks in-flight windows, their ring slots, and the watermark."""

    assigner: WindowAssigner
    n_slots: int
    allowed_lateness: float = 0.0
    watermark: float = float("-inf")
    active: dict[int, int] = field(default_factory=dict)   # window idx → slot
    finalized: int = 0
    late_dropped: int = 0
    _slots: dict[int, int] = field(default_factory=dict)   # slot → window idx
    # wall-clock instant the watermark passed each active window's end —
    # the "close" end of the close-to-emit latency histogram.  Transient
    # (not checkpointed): latency is a property of one run's scheduling,
    # and a restored run re-times replayed windows from its own clock.
    closed_at: dict[int, float] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.n_slots < 1:
            raise ValueError("need at least one window slot")
        # fail at construction when the ring cannot hold the window span —
        # the same bound pipeline.lower enforces at build and planlint's
        # PL001 reports, so a hand-built tracker gets the pointed error
        # here instead of a mid-stream "window ring full"
        size = getattr(self.assigner, "size", None)
        if size is not None:
            from ..analysis.planlint import min_slots_required
            need = min_slots_required(size, getattr(self.assigner, "slide",
                                                    None),
                                      self.allowed_lateness)
            if self.n_slots < need:
                raise ValueError(
                    f"n_slots={self.n_slots} cannot hold the window span; "
                    f"need >= {need} for size={size}, "
                    f"slide={getattr(self.assigner, 'slide', None) or size},"
                    f" lateness={self.allowed_lateness}")
        self._slots = {s: w for w, s in self.active.items()}

    # -- admission -----------------------------------------------------------
    def is_late(self, window_index: int) -> bool:
        """True when the window already closed (watermark passed its end)."""
        return self.assigner.window(window_index).end <= self.watermark

    def min_admissible(self) -> int:
        """Smallest non-late window index at the current watermark — shipped
        to the device fan-out stage as its late-masking bound."""
        return self.assigner.min_live_index(self.watermark)

    def slot_for(self, window_index: int) -> int | None:
        """Ring slot carrying this window (``window_index % n_slots``),
        claiming it on first sight.

        Returns ``None`` for a late window (the event must be dropped — its
        aggregate was already emitted); the caller accounts the drop via
        ``note_late``, never this method — see the ownership note there.
        Raises ``LateEventError`` if the window's modular slot still
        carries an older active window, which means ``n_slots`` is too
        small for the configured window span + lateness: admitting the
        event would corrupt that window's carry slice.
        """
        if window_index in self.active:
            return self.active[window_index]
        if self.is_late(window_index):
            return None
        slot = window_index % self.n_slots
        owner = self._slots.get(slot)
        if owner is not None:
            raise LateEventError(
                f"window ring full: slot {slot} of {self.n_slots} still "
                f"carries active window {owner} ({len(self.active)} active); "
                f"raise n_slots or reduce allowed_lateness / window overlap")
        self.active[window_index] = slot
        self._slots[slot] = window_index
        return slot

    def note_late(self, n: int) -> None:
        """Account ``n`` dropped (event, window) pairs.

        The **only** writer of ``late_dropped``: the coordinator calls it
        with the device fan-out's masked-pair count (device wire) or once
        per ``slot_for``-returned-``None`` pair it drops host-side (host
        wire).  Admission methods never count on their own — a pair that
        is skipped host-side but still rides the wire inside a record's
        window span is counted exactly once, by the device mask.
        """
        self.late_dropped += int(n)

    # -- watermark ------------------------------------------------------------
    def observe(self, max_event_time: float) -> float:
        """Advance the watermark (monotone) past a batch's max event time,
        stamping the close instant of every window it passes."""
        wm = max_event_time - self.allowed_lateness
        if wm > self.watermark:
            self.watermark = wm
            now = time.perf_counter()
            for w in self.active:
                if w not in self.closed_at \
                        and self.assigner.window(w).end <= wm:
                    self.closed_at[w] = now
        return self.watermark

    def ripe(self) -> list[tuple[int, int]]:
        """(window_index, slot) pairs whose end the watermark has passed,
        in window-start order — the finalization schedule."""
        done = [(w, s) for w, s in self.active.items()
                if self.assigner.window(w).end <= self.watermark]
        return sorted(done, key=lambda ws: self.assigner.window(ws[0]).start)

    def release(self, window_index: int) -> None:
        """Return a finalized window's slot to the ring."""
        slot = self.active.pop(window_index)
        del self._slots[slot]
        self.closed_at.pop(window_index, None)
        self.finalized += 1

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable snapshot for the coordinator's checkpoint."""
        return {"watermark": self.watermark,
                "active": {str(w): s for w, s in self.active.items()},
                "free": [s for s in range(self.n_slots)
                         if s not in self._slots],
                "finalized": self.finalized,
                "late_dropped": self.late_dropped}

    def load_state_dict(self, d: dict) -> None:
        self.watermark = float(d["watermark"])
        self.active = {int(w): int(s) for w, s in d["active"].items()}
        self._slots = {s: w for w, s in self.active.items()}
        self.finalized = int(d["finalized"])
        self.late_dropped = int(d["late_dropped"])
