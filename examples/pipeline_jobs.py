"""Paper Fig. 4: two jobs submitted through the client package, run
asynchronously — the second job chains two map functions before its reduce
(executed as two MapReduce jobs under the hood, §III-D).

    PYTHONPATH=src python examples/pipeline_jobs.py
"""

import json

from repro.core import Coordinator, Job, MapReduce, MemoryStore, MetadataStore
from repro.core.job import JobConfig
from repro.data.pipeline import synth_corpus


# -- user-defined functions (shipped as source, like Fig. 5) -----------------

def mapper_fn(key, chunk):
    for word in chunk.split():
        yield word, 1


def reducer_fn(key, values):
    return key, sum(values)


def mapper_fn2(key, chunk):              # stage 1 of job 2: normalize
    for word in chunk.split():
        yield word.strip(".,").lower(), 1


def mapper_fn3(key, chunk):              # stage 2: bucket by first letter
    import json                          # UDFs ship as source → imports
    for line in chunk.splitlines():      # live inside the function (§III-D)
        if line.strip():
            k, v = json.loads(line)
            yield (k[:1] or "_"), v


def reducer_fn2(key, values):
    return key, sum(values)


def main() -> None:
    store = MemoryStore()
    store.put("input/corpus.txt",
              synth_corpus(60_000, vocab_words=500, seed=1).encode())
    coordinator = Coordinator(store, MetadataStore())

    def build_containers():
        print("[build] container images built "
              "(stand-in for the packaging step)")
    build_containers()

    config1 = JobConfig(n_mappers=4, n_reducers=2)
    config2 = JobConfig(n_mappers=4, n_reducers=2)
    job_list = [
        Job(payload=config1, mappers=[mapper_fn], reducer=reducer_fn),
        Job(payload=config2, mappers=[mapper_fn2, mapper_fn3],
            reducer=reducer_fn2),
    ]
    mapreduce = MapReduce(coordinator=coordinator, jobs=job_list,
                          logging=False)
    job_results = mapreduce.run_sync()
    print("Completed jobs:", job_results)

    from repro.core import read_final_output
    out1 = read_final_output(job_list[0].build_stages()[-1], store)
    out2 = read_final_output(job_list[1].build_stages()[-1], store)
    print(f"job1: {len(out1)} words; total={sum(out1.values())}")
    print(f"job2: letter-bucket counts: "
          f"{dict(sorted(out2.items())[:8])} ...")
    assert sum(out1.values()) == sum(out2.values())
    print("conservation across pipelines ✓")


if __name__ == "__main__":
    main()
