"""Paper Fig. 4, re-expressed on the declarative Pipeline API: the two
chained jobs become dataflow graphs — the second job's two map functions
are adjacent ``.map`` nodes that fuse into one stage at build time instead
of running as two consecutive MapReduce jobs (§III-D), and a third graph
adds ``top_k`` to rank the hot words, all through the same front door the
streaming engine uses.  (The original host-plane client path —
``JobConfig``/``Coordinator`` — still works and stays exercised by
``tests/test_coordinator_client.py``.)

    PYTHONPATH=src python examples/pipeline_jobs.py
"""

import json

from repro.core import MemoryStore
from repro.data.pipeline import synth_corpus
from repro.pipeline import Pipeline, Windowing

BUCKETS = 1024      # dense key-id space (vocab is 500 words + variants)
WORKERS = 4
WINDOW = Windowing.tumbling(1.0)    # one global window: a batch job


def normalize(rec):                  # stage 1 of job 2: normalize
    ts, word, one = rec
    return ts, word.strip(".,").lower(), one


def first_letter(rec):               # stage 2: bucket by first letter
    ts, word, one = rec
    return ts, (word[:1] or "_"), one


def main() -> None:
    corpus = synth_corpus(60_000, vocab_words=500, seed=1)
    # the Splitter's record form: one (event_time, key, value) per word
    words = [(0.0, w, 1.0) for w in corpus.split()]

    wordcount = (Pipeline.from_source(records=words)
                 .key_by()
                 .window(WINDOW)
                 .reduce("count"))
    letters = (Pipeline.from_source(records=words)
               .map(normalize)
               .map(first_letter)     # fuses with normalize: one stage
               .key_by()
               .window(WINDOW)
               .reduce("count"))
    hot = (Pipeline.from_source(records=words)
           .map(normalize)
           .key_by()
           .window(WINDOW)
           .reduce("count")
           .top_k(8))

    out1, rep1 = wordcount.build(num_buckets=BUCKETS, n_workers=WORKERS,
                                 job_id="words").run_batch(MemoryStore())
    out2, rep2 = letters.build(num_buckets=BUCKETS, n_workers=WORKERS,
                               job_id="letters").run_batch(MemoryStore())
    out3, _ = hot.build(num_buckets=BUCKETS, n_workers=WORKERS,
                        job_id="hot").run_batch(MemoryStore())

    def decode(outputs):
        (blob,) = outputs.values()
        return [json.loads(line) for line in blob.splitlines()]

    counts1, counts2, top = decode(out1), decode(out2), decode(out3)
    total1 = sum(v for _k, v in counts1)
    total2 = sum(v for _k, v in counts2)
    print(f"job1 (wordcount): {len(counts1)} words, total={total1}")
    print(f"job2 (two fused maps → letter buckets): "
          f"{dict(counts2[:8])} ...")
    print(f"job3 (top_k node): hottest words {top}")
    assert total1 == total2 == len(words)
    print("conservation across pipelines ✓")
    print(f"[{rep1.batches + rep2.batches} batch drives; the same graphs "
          f"run continuously via .run_streaming(...)]")


if __name__ == "__main__":
    main()
