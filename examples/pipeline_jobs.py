"""Paper Fig. 4, re-expressed on the declarative Pipeline API: the two
chained jobs become dataflow graphs — the second job's two map functions
are adjacent ``.map`` nodes that fuse into one stage at build time instead
of running as two consecutive MapReduce jobs (§III-D), a third graph
adds ``top_k`` to rank the hot words, and a fourth is a **two-phase
multi-stage chain** — count per key per minute, then top-k over those
counts per five minutes — where the paper would run two separate jobs
with an object-store round-trip between them, the chain continues past
the first reduce and the finalized windows hand off to the second plan
through the carry (on device: no re-serialization between stages).  All
through the same front door the streaming engine uses.  (The original
host-plane client path — ``JobConfig``/``Coordinator`` — still works and
stays exercised by ``tests/test_coordinator_client.py``.)

    PYTHONPATH=src python examples/pipeline_jobs.py
"""

import json

from repro.core import MemoryStore
from repro.data.pipeline import synth_corpus
from repro.pipeline import Pipeline, Windowing

BUCKETS = 1024      # dense key-id space (vocab is 500 words + variants)
WORKERS = 4
WINDOW = Windowing.tumbling(1.0)    # one global window: a batch job


def normalize(rec):                  # stage 1 of job 2: normalize
    ts, word, one = rec
    return ts, word.strip(".,").lower(), one


def first_letter(rec):               # stage 2: bucket by first letter
    ts, word, one = rec
    return ts, (word[:1] or "_"), one


def main() -> None:
    corpus = synth_corpus(60_000, vocab_words=500, seed=1)
    # the Splitter's record form: one (event_time, key, value) per word
    words = [(0.0, w, 1.0) for w in corpus.split()]

    wordcount = (Pipeline.from_source(records=words)
                 .key_by()
                 .window(WINDOW)
                 .reduce("count"))
    letters = (Pipeline.from_source(records=words)
               .map(normalize)
               .map(first_letter)     # fuses with normalize: one stage
               .key_by()
               .window(WINDOW)
               .reduce("count"))
    hot = (Pipeline.from_source(records=words)
           .map(normalize)
           .key_by()
           .window(WINDOW)
           .reduce("count")
           .top_k(8))

    out1, rep1 = wordcount.build(num_buckets=BUCKETS, n_workers=WORKERS,
                                 job_id="words").run_batch(MemoryStore())
    out2, rep2 = letters.build(num_buckets=BUCKETS, n_workers=WORKERS,
                               job_id="letters").run_batch(MemoryStore())
    out3, _ = hot.build(num_buckets=BUCKETS, n_workers=WORKERS,
                        job_id="hot").run_batch(MemoryStore())

    def decode(outputs):
        (blob,) = outputs.values()
        return [json.loads(line) for line in blob.splitlines()]

    counts1, counts2, top = decode(out1), decode(out2), decode(out3)
    total1 = sum(v for _k, v in counts1)
    total2 = sum(v for _k, v in counts2)
    print(f"job1 (wordcount): {len(counts1)} words, total={total1}")
    print(f"job2 (two fused maps → letter buckets): "
          f"{dict(counts2[:8])} ...")
    print(f"job3 (top_k node): hottest words {top}")
    assert total1 == total2 == len(words)
    print("conservation across pipelines ✓")

    # job 4 — a two-phase chain: count per word per "minute" of event
    # time, then the 5 heaviest words per "five minutes" of those counts.
    # One graph, two stages, carry handoff between them — and the same
    # graph runs batch (here) or streaming, bit-identically per window.
    timed = [(float(i % 300), w, 1.0) for i, w in enumerate(corpus.split())]
    two_phase = (Pipeline.from_source(records=timed)
                 .map(normalize)
                 .key_by()
                 .window(Windowing.tumbling(60.0))
                 .reduce("count")                   # phase 1: count/minute
                 .window(Windowing.tumbling(300.0))
                 .reduce("sum")                     # phase 2: re-window …
                 .top_k(5))                         # … and rank the counts
    built = two_phase.build(num_buckets=BUCKETS, n_workers=WORKERS,
                            job_id="two-phase")
    out4, rep4 = built.run_batch(MemoryStore())
    hot5 = decode(out4)
    print(f"job4 (two-phase chain, {len(built.stages)} stages, "
          f"{rep4.handoffs} carry handoffs): top-5 over minute-counts "
          f"{hot5}")
    assert len(built.stages) == 2 and built.stages[0].handoff_device
    assert [w for w, _c in hot5] == [w for w, _c in top[:5]]
    print("two-phase ranking agrees with the single-window top_k ✓")
    print(f"[{rep1.batches + rep2.batches + rep4.batches} batch drives; "
          f"the same graphs run continuously via .run_streaming(...)]")


if __name__ == "__main__":
    main()
