"""Paper Fig. 4, re-expressed on the declarative Pipeline API: the two
chained jobs become dataflow graphs — the second job's two map functions
are adjacent ``.map`` nodes that fuse into one stage at build time instead
of running as two consecutive MapReduce jobs (§III-D), a third graph
adds ``top_k`` to rank the hot words, and a fourth is a **two-phase
multi-stage chain** — count per key per minute, then top-k over those
counts per five minutes — where the paper would run two separate jobs
with an object-store round-trip between them, the chain continues past
the first reduce and the finalized windows hand off to the second plan
through the carry (on device: no re-serialization between stages).  A
fifth job is a **DAG fan-out**: a GPS stream's per-minute counts tee into
two concurrent consumers — a top-k branch and a per-region rollup branch
— off ONE shared intermediate (the Kafka-ML shape: one ingested stream,
many consumers), each tee edge picking its own handoff transport.  All
through the same front door the streaming engine uses.  (The original
host-plane client path — ``JobConfig``/``Coordinator`` — still works and
stays exercised by ``tests/test_coordinator_client.py``.)

    PYTHONPATH=src python examples/pipeline_jobs.py
"""

import json

from repro.core import MemoryStore
from repro.data.pipeline import synth_corpus
from repro.pipeline import Pipeline, RunOptions, Windowing

BUCKETS = 1024      # dense key-id space (vocab is 500 words + variants)
WORKERS = 4
WINDOW = Windowing.tumbling(1.0)    # one global window: a batch job


def normalize(rec):                  # stage 1 of job 2: normalize
    ts, word, one = rec
    return ts, word.strip(".,").lower(), one


def first_letter(rec):               # stage 2: bucket by first letter
    ts, word, one = rec
    return ts, (word[:1] or "_"), one


def build_pipelines():
    """Planlint hook (``python -m repro.analysis.planlint examples``):
    the demo's graph shapes — single-stage, fused-map, top-k, two-phase
    chain, and the tee'd DAG — built over stub records (bound data does
    not affect the lowered plan, so the checks see exactly the programs
    ``main`` runs)."""
    stub = [(0.0, "w", 1.0)]

    def src():
        return Pipeline.from_source(records=stub, batch_records=2048)

    two_phase = (src().map(normalize).key_by()
                 .window(Windowing.tumbling(60.0)).reduce("count")
                 .window(Windowing.tumbling(300.0)).reduce("sum").top_k(5))
    fan = (src().key_by()
           .window(Windowing.tumbling(60.0)).reduce("count")
           .tee(Pipeline.branch()
                .window(Windowing.tumbling(300.0))
                .reduce("sum").top_k(5).sink("gps-busy/"),
                Pipeline.branch()
                .map(normalize).key_by()
                .window(Windowing.tumbling(300.0))
                .reduce("sum").sink("gps-region/")))
    return {
        "words": (src().key_by().window(WINDOW).reduce("count")
                  .build(num_buckets=BUCKETS, n_workers=WORKERS,
                         job_id="words")),
        "letters": (src().map(normalize).map(first_letter).key_by()
                    .window(WINDOW).reduce("count")
                    .build(num_buckets=BUCKETS, n_workers=WORKERS,
                           job_id="letters")),
        "hot": (src().map(normalize).key_by().window(WINDOW)
                .reduce("count").top_k(8)
                .build(num_buckets=BUCKETS, n_workers=WORKERS,
                       job_id="hot")),
        "two-phase": two_phase.build(num_buckets=BUCKETS,
                                     n_workers=WORKERS,
                                     job_id="two-phase"),
        "gps-fan": fan.build(num_buckets=64, n_workers=WORKERS,
                             job_id="gps-fan"),
    }


def main() -> None:
    corpus = synth_corpus(60_000, vocab_words=500, seed=1)
    # the Splitter's record form: one (event_time, key, value) per word
    words = [(0.0, w, 1.0) for w in corpus.split()]

    wordcount = (Pipeline.from_source(records=words)
                 .key_by()
                 .window(WINDOW)
                 .reduce("count"))
    letters = (Pipeline.from_source(records=words)
               .map(normalize)
               .map(first_letter)     # fuses with normalize: one stage
               .key_by()
               .window(WINDOW)
               .reduce("count"))
    hot = (Pipeline.from_source(records=words)
           .map(normalize)
           .key_by()
           .window(WINDOW)
           .reduce("count")
           .top_k(8))

    # one front door: records-bound graphs dispatch to a one-shot batch
    out1, rep1 = wordcount.build(num_buckets=BUCKETS, n_workers=WORKERS,
                                 job_id="words").run()
    out2, rep2 = letters.build(num_buckets=BUCKETS, n_workers=WORKERS,
                               job_id="letters").run()
    out3, _ = hot.build(num_buckets=BUCKETS, n_workers=WORKERS,
                        job_id="hot").run()

    def decode(outputs):
        (blob,) = outputs.values()
        return [json.loads(line) for line in blob.splitlines()]

    counts1, counts2, top = decode(out1), decode(out2), decode(out3)
    total1 = sum(v for _k, v in counts1)
    total2 = sum(v for _k, v in counts2)
    print(f"job1 (wordcount): {len(counts1)} words, total={total1}")
    print(f"job2 (two fused maps → letter buckets): "
          f"{dict(counts2[:8])} ...")
    print(f"job3 (top_k node): hottest words {top}")
    assert total1 == total2 == len(words)
    print("conservation across pipelines ✓")

    # job 4 — a two-phase chain: count per word per "minute" of event
    # time, then the 5 heaviest words per "five minutes" of those counts.
    # One graph, two stages, carry handoff between them — and the same
    # graph runs batch (here) or streaming, bit-identically per window.
    timed = [(float(i % 300), w, 1.0) for i, w in enumerate(corpus.split())]
    two_phase = (Pipeline.from_source(records=timed)
                 .map(normalize)
                 .key_by()
                 .window(Windowing.tumbling(60.0))
                 .reduce("count")                   # phase 1: count/minute
                 .window(Windowing.tumbling(300.0))
                 .reduce("sum")                     # phase 2: re-window …
                 .top_k(5))                         # … and rank the counts
    built = two_phase.build(num_buckets=BUCKETS, n_workers=WORKERS,
                            job_id="two-phase")
    out4, rep4 = built.run()
    hot5 = decode(out4)
    print(f"job4 (two-phase chain, {len(built.stages)} stages, "
          f"{rep4.handoffs} carry handoffs): top-5 over minute-counts "
          f"{hot5}")
    assert len(built.stages) == 2 and built.stages[0].handoff_device
    assert [w for w, _c in hot5] == [w for w, _c in top[:5]]
    print("two-phase ranking agrees with the single-window top_k ✓")

    # job 5 — DAG fan-out: a GPS fleet stream (event_time, vehicle, speed),
    # counted per vehicle per "minute", then TEE'd: one branch ranks the 5
    # busiest vehicles per five minutes (identity boundary → on-device
    # handoff), the other rolls the counts up per region (a host map
    # between the stages → host-record handoff).  One ingested stream, two
    # concurrent consumers, one shared intermediate — and the same graph
    # runs batch and streaming with bit-identical windows on BOTH sinks.
    import numpy as np
    rng = np.random.default_rng(7)
    gps = [(float(t % 1800), f"v{int(v):02d}", float(s))
           for t, v, s in zip(rng.uniform(0, 1800, 20_000),
                              rng.integers(0, 24, 20_000),
                              rng.uniform(0, 30, 20_000))]
    gps.sort()

    def to_region(rec):
        ts, vehicle, count = rec
        return ts, f"region-{int(vehicle[1:]) % 4}", count

    fan = (Pipeline.from_source(records=gps, batch_records=2048)
           .key_by()
           .window(Windowing.tumbling(60.0))
           .reduce("count")                        # per-minute counts, once
           .tee(Pipeline.branch()                  # consumer 1: busiest
                .window(Windowing.tumbling(300.0))
                .reduce("sum").top_k(5)
                .sink("gps-busy/"),
                Pipeline.branch()                  # consumer 2: region load
                .map(to_region).key_by()
                .window(Windowing.tumbling(300.0))
                .reduce("sum")
                .sink("gps-region/")))
    built5 = fan.build(num_buckets=64, n_workers=WORKERS, job_id="gps-fan")
    transports = sorted(e.device for e in built5.edges)
    assert len(built5.stages) == 3 and transports == [False, True]
    out5, rep5 = built5.run()
    stream_store = MemoryStore()
    rep5s = built5.run(store=stream_store, mode="streaming",
                       options=RunOptions(overlap=True, prefetch_batches=2))
    streamed5 = built5.collect_outputs(stream_store)
    assert streamed5 and streamed5 == out5
    busy = {k: v for k, v in out5.items() if k.startswith("gps-busy/")}
    region = {k: v for k, v in out5.items() if k.startswith("gps-region/")}
    first_busy = [json.loads(ln)
                  for ln in sorted(busy.items())[0][1].splitlines()]
    first_region = [json.loads(ln)
                    for ln in sorted(region.items())[0][1].splitlines()]
    print(f"job5 (DAG fan-out, {len(built5.stages)} stages, "
          f"{rep5.handoffs} edge handoffs): busiest vehicles "
          f"{first_busy} | region load {dict(first_region)}")
    print("tee'd branches: batch ↔ streaming bit-identical on both sinks ✓")
    print(f"[{rep1.batches + rep2.batches + rep4.batches + rep5.batches} "
          f"batch drives + {rep5s.batches} streaming micro-batches "
          f"(close→emit p99 {rep5s.p99_emit_latency * 1e3:.2f} ms); one "
          f"front door — .run(..., options=RunOptions(...)) — both modes]")


if __name__ == "__main__":
    main()
