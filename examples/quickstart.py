"""Quickstart: the paper's word-count workflow (Fig. 5), end to end.

Runs the full serverless pipeline — Coordinator → Splitter → Mappers
(sort+combine+spill) → Reducers (k-way merge) → Finalizer — against the
in-process S3/Redis/Kafka stand-ins, then the same job on the device engine
(the TPU-plane shuffle), and checks they agree.

    PYTHONPATH=src python examples/quickstart.py
"""

from collections import Counter

import numpy as np

from repro.core import (Coordinator, MemoryStore, MetadataStore,
                        make_wordcount_job, read_final_output)
from repro.core.mapreduce import wordcount_map_factory
from repro.data.pipeline import synth_corpus
from repro.pipeline import Pipeline


def build_pipelines():
    """Planlint hook (``python -m repro.analysis.planlint examples``):
    the device-engine word-count as ``main`` builds it, over a stub shard
    (the data shape doesn't change the plan)."""
    shard = np.zeros((8, 4, 2), dtype=np.int32)
    return {"wordcount": (Pipeline.from_source(shards=shard)
                          .map(wordcount_map_factory(16))
                          .reduce("sum")
                          .build(num_buckets=16, n_workers=8,
                                 backend="vmap", job_id="wordcount"))}


def main() -> None:
    # 1. input data in the object store ("S3 bucket")
    corpus = synth_corpus(100_000, vocab_words=2000, seed=0)
    store = MemoryStore()
    store.put("input/corpus.txt", corpus.encode())

    # 2. the paper's JSON job: 4 mappers, 2 reducers, combiner + finalizer
    cfg = make_wordcount_job(n_mappers=4, n_reducers=2)
    coord = Coordinator(store, MetadataStore())
    report = coord.run_job(cfg)
    print(f"job {cfg.job_id}: {report.state.value} in {report.wall_time:.3f}s")
    print("  per-component avg seconds:",
          {k: round(v, 4) for k, v in report.component_times().items()})

    out = read_final_output(cfg, store)
    expected = Counter(corpus.split())
    assert out == dict(expected)
    print(f"  exact counts for {len(out)} distinct words ✓")

    # 3. same job on the device engine: hash-partition shuffle on the mesh,
    # authored as the two-node array pipeline the old mapreduce() façade
    # lowers to (the deprecated shim would warn here)
    vocab = {w: i for i, w in enumerate(sorted(expected))}
    tok = np.array([vocab[w] for w in corpus.split()], dtype=np.int32)
    W = 8
    n = (len(tok) + W - 1) // W * W
    toks = np.concatenate([tok, np.full(n - len(tok), -1, np.int32)])
    shard = np.stack([toks.reshape(W, -1),
                      np.ones((W, n // W), np.int32)], axis=-1)
    built = (Pipeline.from_source(shards=shard)
             .map(wordcount_map_factory(len(vocab)))
             .reduce("sum")
             .build(num_buckets=len(vocab), n_workers=W, backend="vmap"))
    res, _stats = built.run_batch(data=shard)
    res = np.asarray(res)
    for w, c in expected.items():
        assert res[vocab[w]] == c
    print(f"  device engine agrees across {W} workers ✓")


if __name__ == "__main__":
    main()
