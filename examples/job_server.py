"""Multi-tenant job service: two tenants, one shared GPS ingest, and a
scale-to-zero round trip.

The paper's platform shape — many jobs from many teams against one
serverless deployment — on the repo's job server: a fleet-operations
tenant (mean speed per region per minute) and a billing tenant (ping
counts per region) both subscribe to the SAME physical GPS log.  The
server materializes the log once onto a bus topic and fans it out through
per-subscriber replay cursors, so adding the second tenant adds zero
object-store reads.  When the stream goes quiet both jobs park: state
checkpointed at a micro-batch barrier, coordinators dropped, worker pool
scaled to zero.  The next batch of pings cold-restores them (latency
recorded — the serverless trade) and every sink ends byte-identical to
the tenant running alone on a private deployment.

    PYTHONPATH=src python examples/job_server.py
    JOB_SERVER_DURATION=120 PYTHONPATH=src python examples/job_server.py  # CI cap
"""

import os

import numpy as np

from repro.core import JobServiceClient, MemoryStore, MetadataStore
from repro.launch.serve import JobRPC
from repro.pipeline import Pipeline, Windowing
from repro.service import JobServer, JobStatus, ParkPolicy
from repro.streaming import (StreamSource, StreamingCoordinator,
                             write_event_log)

REGIONS = ["north", "south", "east", "west", "centre", "port", "depot", "hub"]
WINDOW = 60.0          # 1-minute tumbling windows
RATE = 40.0            # events per second of event time
DURATION = float(os.environ.get("JOB_SERVER_DURATION", 300.0))
BATCH = 1024


def synth_gps_events(seed: int = 0):
    """A fleet's GPS pings: (event_time, region, speed_kmh) — in arrival
    order (the shared log is totally ordered; every subscriber replays
    the same sequence)."""
    rng = np.random.default_rng(seed)
    n = int(RATE * DURATION)
    ts = np.sort(rng.uniform(0, DURATION, n))
    regions = rng.integers(0, len(REGIONS), n)
    speeds = rng.integers(5, 110, n).astype(float)
    return [(float(t), REGIONS[r], float(s))
            for t, r, s in zip(ts, regions, speeds)]


def tenant_program(job_id: str, agg: str):
    return (Pipeline.from_source(batch_records=BATCH)
            .key_by(lambda r: r[1])
            .window(Windowing.tumbling(WINDOW))
            .reduce(agg)
            .sink("stream-output/")
            .build(num_buckets=8, n_workers=4, batch_records=BATCH,
                   job_id=job_id))


def rogue_program(job_id: str):
    """A tenant submission planlint must reject at admission: it sinks
    under the reserved ``jobs/`` checkpoint namespace, so its restore
    scans would list the carry blob as a persisted window (PL005)."""
    return (Pipeline.from_source(batch_records=BATCH)
            .key_by(lambda r: r[1])
            .window(Windowing.tumbling(WINDOW))
            .reduce("count")
            .sink("jobs/")
            .build(num_buckets=8, n_workers=4, batch_records=BATCH,
                   job_id=job_id))


def build_pipelines():
    """Planlint hook: every program this example builds, for
    ``python -m repro.analysis.planlint examples`` (the CI analysis
    gate).  The rogue program is deliberately absent — it exists to be
    rejected, and the demo asserts that it is."""
    return {"speed-rollup": tenant_program("gps-speed", "mean"),
            "ping-billing": tenant_program("gps-bill", "count")}


def standalone_sink(events, job_id: str, agg: str):
    """Ground truth: the same program on a private single-tenant store."""
    store = MemoryStore()
    coord = StreamingCoordinator(store, MetadataStore(),
                                 program=tenant_program(job_id, agg))
    coord.run_stream(StreamSource.from_records(events, batch_records=BATCH))
    return {m.key: store.get(m.key)
            for m in store.list_objects(f"stream-output/{job_id}/")}


def tenant_sink(store, tenant: str, job_id: str):
    ns = f"tenants/{tenant}/"
    return {m.key[len(ns):]: store.get(m.key)
            for m in store.list_objects(f"{ns}stream-output/{job_id}/")}


def main() -> None:
    events = synth_gps_events()
    first, second = events[: len(events) // 2], events[len(events) // 2:]

    # 1. producers fill the shared log's first half
    store = MemoryStore()
    write_event_log(store, "streams/gps", first, segment_records=4096)

    # 2. the control plane: one server, two tenants, the RPC skeleton
    # park as soon as a drive round finds a job idle (idle_seconds=0.0)
    server = JobServer(store, MetadataStore(),
                       park_policy=ParkPolicy(idle_seconds=0.0))
    server.add_tenant("fleet-ops")
    server.add_tenant("billing")
    rpc = JobRPC(server)
    client = JobServiceClient(server)
    rpc.handle({"method": "register", "name": "speed-rollup",
                "program": tenant_program("gps-speed", "mean")})
    rpc.handle({"method": "register", "name": "ping-billing",
                "program": tenant_program("gps-bill", "count")})
    a = rpc.handle({"method": "submit", "tenant": "fleet-ops",
                    "program": "speed-rollup",
                    "source_prefix": "streams/gps"})["result"]
    b = rpc.handle({"method": "submit", "tenant": "billing",
                    "program": "ping-billing",
                    "source_prefix": "streams/gps"})["result"]
    print(f"submitted {a!r} (fleet-ops) and {b!r} (billing) against one "
          f"shared ingest")

    # 2b. admission control: a program that fails planlint is rejected
    # before it registers — the build already warned (PlanLintWarning),
    # and the submit fails for this tenant only
    import warnings

    from repro.analysis import PlanLintWarning
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PlanLintWarning)  # shown at submit
        rogue = rogue_program("gps-rogue")
    server.add_tenant("rogue-team")
    rpc.handle({"method": "register", "name": "rogue", "program": rogue})
    rej = rpc.handle({"method": "submit", "tenant": "rogue-team",
                      "program": "rogue", "source_prefix": "streams/gps"})
    assert not rej["ok"] and "PlanRejected" in rej["error"]
    assert client.status(a)["state"] is not None     # neighbors unaffected
    print(f"rogue submit rejected by planlint: {rej['error'].split(':')[0]} "
          f"(PL005 — sink under the reserved jobs/ namespace); "
          f"other tenants unaffected")

    # 3. drive until the stream goes quiet: both jobs drain, checkpoint,
    # park — and the pool scales to zero
    while server.step():
        pass
    assert client.status(a)["state"] == JobStatus.PARKED
    assert client.status(b)["state"] == JobStatus.PARKED
    pool = server.pool.stats()
    assert pool["replicas"] == 0
    print(f"stream idle → both jobs parked, pool at {pool['replicas']} "
          f"replicas ({pool['scale_downs']} scale-downs)")

    # 4. the second half of the night's pings arrives: the next step
    # cold-restores both jobs from their checkpoints and folds the tail
    write_event_log(store, "streams/gps", second, segment_records=4096)
    states = server.run_until_complete()
    assert states == {a: JobStatus.DONE, b: JobStatus.DONE}
    for jid in (a, b):
        rec = client.status(jid)
        lat = max(server.jobs[jid].cold_start_latencies) * 1e3
        print(f"  {jid}: parks={rec['parks']} restores={rec['restores']} "
              f"cold-start {lat:.1f} ms → {rec['state']}")

    # 5. physical-once: the log was read exactly once for both tenants
    ing = server.stats()["ingests"]["streams/gps"]
    assert ing["pumped"] == len(events) and ing["subscribers"] == 2
    print(f"shared ingest: {ing['pumped']} records materialized once for "
          f"{ing['subscribers']} subscribers")

    # 6. byte parity: each tenant's sink == the same program running alone
    assert tenant_sink(store, "fleet-ops", "gps-speed") == \
        standalone_sink(events, "gps-speed", "mean")
    assert tenant_sink(store, "billing", "gps-bill") == \
        standalone_sink(events, "gps-bill", "count")
    print("sinks byte-identical to standalone single-tenant runs ✓")


if __name__ == "__main__":
    main()
