"""End-to-end driver: train a ~100M-parameter qwen3-family LM for a few
hundred steps on CPU, with the full production substrate engaged — byte-range
sharded data pipeline, prefetch, AdamW + cosine schedule, async sharded
checkpoints, restart-capable Trainer.

Defaults are sized so this finishes on a single CPU core (~15-30 min for 200
steps).  Use --steps 20 for a smoke run.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

from repro import configs
from repro.core.metadata import MetadataStore
from repro.core.storage import MemoryStore
from repro.data import HashTokenizer, PackedLMDataset, Prefetcher
from repro.data.pipeline import make_store_with_corpus
from repro.optim import AdamW
from repro.optim.schedule import cosine_schedule
from repro.runtime import Trainer, TrainerConfig


def build_100m_config():
    """~100M params in the qwen3 family: 12L, d=512, 8 heads (kv=4),
    d_ff=2048, vocab=32768 → ≈ 72M embed + 38M blocks ≈ 110M."""
    return configs.get("qwen3-32b").replace(
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab=32_768,
        param_dtype="float32", compute_dtype="float32", remat=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--corpus-words", type=int, default=2_000_000)
    args = ap.parse_args()

    cfg = build_100m_config()
    print(f"[train_lm] {cfg.n_params()/1e6:.0f}M params, "
          f"{args.steps} steps of {args.batch}×{args.seq} tokens")

    store, prefix = make_store_with_corpus(args.corpus_words, vocab_words=20_000)
    ds = PackedLMDataset(store, prefix, HashTokenizer(cfg.vocab),
                         batch=args.batch, seq_len=args.seq)
    opt = AdamW(lr=cosine_schedule(args.lr, args.steps // 10, args.steps))
    trainer = Trainer(cfg, opt, MemoryStore(), MetadataStore(),
                      TrainerConfig(checkpoint_every=max(50, args.steps // 4),
                                    log_every=10))
    trainer.run(Prefetcher(iter(ds)), args.steps)
    first, last = trainer.metrics_log[0], trainer.metrics_log[-1]
    print(f"[train_lm] loss {first['loss']:.3f} → {last['loss']:.3f} "
          f"({last['steps_per_s']:.2f} steps/s)")
    assert last["loss"] < first["loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
