"""Serving example: batched request serving with KV caches and slot reuse
(continuous-batching-lite), on a reduced gemma2 (alternating local/global
windows + softcaps — the serving-hard arch of the pool).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro import configs
from repro.launch.serve import BatchedServer, Request
from repro.models import init_params


def main() -> None:
    cfg = configs.get_reduced("gemma2-9b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(cfg, params, n_slots=4, max_len=96)

    rng = np.random.default_rng(0)
    n_requests, prompt_len, max_new = 10, 12, 24
    for i in range(n_requests):
        server.submit(Request(
            id=i,
            prompt=rng.integers(0, cfg.vocab, prompt_len, dtype=np.int32),
            max_new=max_new))

    t0 = time.perf_counter()
    done, steps, served = [], 0, 0
    while any(server.slots) or server.queue:
        served += server.step()
        steps += 1
    dt = time.perf_counter() - t0
    print(f"[serve_lm] {n_requests} requests × {max_new} new tokens: "
          f"{served} tokens in {dt:.2f}s "
          f"({served/dt:.1f} tok/s, {steps} batched steps, "
          f"{steps/n_requests:.1f} steps/request)")
    assert served == n_requests * max_new


if __name__ == "__main__":
    main()
