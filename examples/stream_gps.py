"""Streaming logistics: windowed per-region GPS aggregates, end to end.

The paper's motivating workload — continuous GPS/IoT event streams from a
logistics fleet — run through the streaming micro-batch engine: a replayable
event log ("Kafka topic") in the object store, tumbling event-time windows,
one fused incremental map→shuffle→reduce round per micro-batch on the device
engine, watermark-driven window finalization, and lag-driven pool scaling.
The emitted windows are then checked against a one-shot batch computation
over the same records.

    PYTHONPATH=src python examples/stream_gps.py
"""

from collections import defaultdict

import numpy as np

from repro.core import MemoryStore, MetadataStore
from repro.core.events import EventBus, TOPIC_STREAM_WINDOW
from repro.streaming import (StreamSource, StreamingConfig,
                             StreamingCoordinator, write_event_log)

REGIONS = ["north", "south", "east", "west", "centre", "port", "depot", "hub"]
WINDOW = 60.0          # 1-minute tumbling windows
RATE = 40.0            # events per second of event time
DURATION = 600.0       # 10 minutes of fleet telemetry


def synth_gps_events(seed: int = 0):
    """A fleet's GPS pings: (event_time, region, speed_kmh), mildly
    out-of-order like real device uploads."""
    rng = np.random.default_rng(seed)
    n = int(RATE * DURATION)
    ts = np.sort(rng.uniform(0, DURATION, n))
    ts = ts + rng.normal(0, 0.5, n)          # upload jitter → out-of-order
    ts = np.clip(ts, 0, None)
    regions = rng.integers(0, len(REGIONS), n)
    speeds = rng.integers(5, 110, n).astype(float)
    return [(float(t), REGIONS[r], float(s))
            for t, r, s in zip(ts, regions, speeds)]


def main() -> None:
    events = synth_gps_events()

    # 1. producers append to the replayable event log (the Kafka stand-in)
    store = MemoryStore()
    n = write_event_log(store, "streams/gps", events, segment_records=4096)
    print(f"event log: {n} GPS pings, "
          f"{len(store.list_objects('streams/gps'))} segments")

    # 2. continuous job: mean speed per region per 1-minute window
    bus = EventBus()
    cfg = StreamingConfig(num_buckets=8, n_workers=4, window_size=WINDOW,
                          allowed_lateness=5.0, batch_records=2048,
                          aggregation="mean", job_id="gps-fleet")
    coord = StreamingCoordinator(store, MetadataStore(), cfg, bus=bus)
    source = StreamSource(store=store, prefix="streams/gps",
                          batch_records=2048)
    report = coord.run_stream(source)

    print(f"stream {cfg.job_id}: {report.batches} micro-batches, "
          f"{report.records_in} records in {report.wall_time:.3f}s "
          f"({report.records_per_sec:,.0f} rec/s)")
    print(f"  windows emitted: {report.windows_emitted}, "
          f"late dropped: {report.late_dropped}, "
          f"mean batch latency: {report.mean_batch_latency * 1e3:.2f} ms")
    print(f"  backpressure: max lag {report.max_lag}, "
          f"{report.scale_events} scale events → pool {coord.pool_stats()}")

    # 3. downstream consumers see finalized windows as CloudEvents
    recs = bus.poll("dashboard", TOPIC_STREAM_WINDOW, timeout=0.1,
                    max_records=64)
    print(f"  {len(recs)} window-finalized events on the bus; first: "
          f"{recs[0].value.data['output_key']}")

    # 4. agreement with a one-shot batch computation over the same log
    batch: dict[int, dict[str, list[float]]] = defaultdict(
        lambda: defaultdict(list))
    for ts, region, speed in events:
        batch[int(ts // WINDOW)][region].append(speed)
    worst = 0.0
    checked = 0
    import json
    for widx, per_region in batch.items():
        key = (f"stream-output/gps-fleet/"
               f"window-{widx * WINDOW:.3f}-{(widx + 1) * WINDOW:.3f}")
        got = dict(json.loads(line) for line in store.get(key).splitlines())
        for region, speeds in per_region.items():
            want = sum(speeds) / len(speeds)
            worst = max(worst, abs(got[region] - want))
            checked += 1
    assert worst < 1e-3, worst
    print(f"  incremental == one-shot batch on {checked} (window, region) "
          f"aggregates (max |Δ| = {worst:.2e}) ✓")


if __name__ == "__main__":
    main()
