"""Streaming logistics on the Pipeline API: windowed per-region GPS
aggregates, end to end — one declarative definition, flipped between
streaming and batch execution.

The paper's motivating workload — continuous GPS/IoT event streams from a
logistics fleet — authored once as a dataflow graph: event log → key_by
region → 1-minute tumbling windows → mean speed.  The same built pipeline
then runs (1) continuously through the streaming micro-batch engine
(replayable event log, watermark finalization, lag-driven pool scaling)
and (2) as a one-shot batch drive over the same prefix, and the emitted
windows are asserted byte-identical.  A second graph sessionizes each
vehicle's pings into trips (``Windowing.session``) — the data-dependent
window variant.

    PYTHONPATH=src python examples/stream_gps.py
    STREAM_GPS_DURATION=120 PYTHONPATH=src python examples/stream_gps.py  # CI cap
"""

import os
from collections import defaultdict

import numpy as np

from repro.core import MemoryStore
from repro.core.events import EventBus, TOPIC_STREAM_WINDOW
from repro.pipeline import Pipeline, RunOptions, Windowing
from repro.streaming import write_event_log

REGIONS = ["north", "south", "east", "west", "centre", "port", "depot", "hub"]
WINDOW = 60.0          # 1-minute tumbling windows
RATE = 40.0            # events per second of event time
DURATION = float(os.environ.get("STREAM_GPS_DURATION", 600.0))


def synth_gps_events(seed: int = 0):
    """A fleet's GPS pings: (event_time, region, speed_kmh), mildly
    out-of-order like real device uploads."""
    rng = np.random.default_rng(seed)
    n = int(RATE * DURATION)
    ts = np.sort(rng.uniform(0, DURATION, n))
    ts = ts + rng.normal(0, 0.5, n)          # upload jitter → out-of-order
    ts = np.clip(ts, 0, None)
    regions = rng.integers(0, len(REGIONS), n)
    speeds = rng.integers(5, 110, n).astype(float)
    return [(float(t), REGIONS[r], float(s))
            for t, r, s in zip(ts, regions, speeds)]


def fleet_pipeline():
    """The demo's main program: mean speed per region per minute."""
    return (Pipeline.from_source(prefix="streams/gps", batch_records=2048)
            .key_by(lambda r: r[1])
            .window(Windowing.tumbling(WINDOW))
            .reduce("mean")
            .sink("stream-output/"))


def build_pipelines():
    """Planlint hook (``python -m repro.analysis.planlint examples``):
    the example's programs, built exactly as the demo builds them (the
    session job with stub records — sources don't affect the plan)."""
    return {
        "gps-fleet": fleet_pipeline().build(
            num_buckets=8, n_workers=4, allowed_lateness=5.0,
            job_id="gps-fleet"),
        "gps-trips": (Pipeline.from_source(records=[], batch_records=512)
                      .key_by()
                      .window(Windowing.session(gap=30.0))
                      .reduce("mean")
                      .build(num_buckets=8, n_workers=4, n_slots=4,
                             job_id="gps-trips")),
    }


def main() -> None:
    events = synth_gps_events()

    # 1. producers append to the replayable event log (the Kafka stand-in)
    store = MemoryStore()
    n = write_event_log(store, "streams/gps", events, segment_records=4096)
    print(f"event log: {n} GPS pings, "
          f"{len(store.list_objects('streams/gps'))} segments")

    # 2. ONE definition: mean speed per region per 1-minute window
    built = fleet_pipeline().build(num_buckets=8, n_workers=4,
                                   allowed_lateness=5.0, job_id="gps-fleet")

    # 2a. streaming mode through the one front door: the graph's bound
    # source is a log prefix, so run() dispatches to the streaming
    # coordinator — here with the pipelined scheduler's knobs spelled out
    # (all on by default: prefetch + host-prepare the next micro-batch
    # while the device folds this one, drain stats and sink writes at the
    # batch barrier, donate the carry buffers)
    bus = EventBus()
    report = built.run(store=store, bus=bus,
                       options=RunOptions(overlap=True, prefetch_batches=2,
                                          sink_batching=True))
    print(f"stream {built.job_id}: {report.batches} micro-batches, "
          f"{report.records_in} records in {report.wall_time:.3f}s "
          f"({report.records_per_sec:,.0f} rec/s)")
    print(f"  windows emitted: {report.windows_emitted}, "
          f"late dropped: {report.late_dropped}, "
          f"mean batch latency: {report.mean_batch_latency * 1e3:.2f} ms")
    print(f"  close→emit latency: p50 {report.p50_emit_latency * 1e3:.2f} ms, "
          f"p99 {report.p99_emit_latency * 1e3:.2f} ms")
    print(f"  backpressure: max lag {report.max_lag}, "
          f"{report.scale_events} scale events")

    # 2b. batch mode: the SAME built pipeline, one drive over the prefix
    # (mode= pins the dispatch; a log-bound graph would otherwise stream)
    batch_store = MemoryStore()
    for m in store.list_objects("streams/gps"):
        batch_store.put(m.key, store.get(m.key))
    batch_out, _ = built.run(store=batch_store, mode="batch")
    stream_out = {m.key: store.get(m.key)
                  for m in store.list_objects("stream-output/gps-fleet/")}
    assert stream_out and stream_out == batch_out
    print(f"  batch flip: {len(batch_out)} windows, byte-identical to the "
          f"streaming run ✓")

    # 3. downstream consumers see finalized windows as CloudEvents
    recs = bus.poll("dashboard", TOPIC_STREAM_WINDOW, timeout=0.1,
                    max_records=64)
    print(f"  {len(recs)} window-finalized events on the bus; first: "
          f"{recs[0].value.data['output_key']}")

    # 4. agreement with a host-side oracle over the same log
    batch: dict[int, dict[str, list[float]]] = defaultdict(
        lambda: defaultdict(list))
    for ts, region, speed in events:
        batch[int(ts // WINDOW)][region].append(speed)
    worst = 0.0
    checked = 0
    import json
    for widx, per_region in batch.items():
        key = (f"stream-output/gps-fleet/"
               f"window-{widx * WINDOW:.3f}-{(widx + 1) * WINDOW:.3f}")
        got = dict(json.loads(line) for line in store.get(key).splitlines())
        for region, speeds in per_region.items():
            want = sum(speeds) / len(speeds)
            worst = max(worst, abs(got[region] - want))
            checked += 1
    assert worst < 1e-3, worst
    print(f"  incremental == oracle on {checked} (window, region) "
          f"aggregates (max |Δ| = {worst:.2e}) ✓")

    # 5. sessionized GPS traces: each vehicle's pings split into trips by
    # a 30s inactivity gap — the data-dependent window variant
    rng = np.random.default_rng(1)
    trips = []
    for v in range(6):
        t = float(rng.uniform(0, 30.0))
        while t < DURATION:
            for _ in range(int(rng.integers(5, 20))):    # one trip's pings
                trips.append((t, f"vehicle-{v}", float(rng.integers(5, 110))))
                t += float(rng.uniform(0.5, 8.0))
            t += float(rng.uniform(60.0, 180.0))         # parked > gap
    trips.sort()
    sess = (Pipeline.from_source(records=trips, batch_records=512)
            .key_by()
            .window(Windowing.session(gap=30.0))
            .reduce("mean"))
    outs, srep = sess.build(num_buckets=8, n_workers=4, n_slots=4,
                            job_id="gps-trips").run(store=store)
    print(f"  sessionized trips: {srep.windows_emitted} trips from "
          f"{len(trips)} pings across 6 vehicles "
          f"(e.g. {sorted(outs)[0].rsplit('/', 1)[1]})")


if __name__ == "__main__":
    main()
