"""Minimal stand-in for the ``hypothesis`` API surface these tests use.

The property tests prefer real hypothesis (declared in the ``test`` extra and
installed in CI, where shrinking and edge-case search matter).  In
environments without it — like the hermetic container the tier-1 suite runs
in — this shim keeps the same tests executable as seeded random sampling:
``@given`` draws ``max_examples`` pseudo-random examples per test from a
deterministic RNG, so collection never fails and the invariants still get
exercised.

Only the strategies the suite actually uses are implemented: text, integers,
floats, binary, lists, tuples, and ``.flatmap``.
"""

from __future__ import annotations

import functools
import inspect
import random
import string
import struct
from typing import Any, Callable


class Strategy:
    """A draw function ``rng -> value`` with hypothesis's combinator API."""

    def __init__(self, draw: Callable[[random.Random], Any]) -> None:
        self._draw = draw

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def flatmap(self, fn: "Callable[[Any], Strategy]") -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)).example(rng))

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)))


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 1 << 16) -> Strategy:
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0,
               allow_nan: bool = False, width: int = 64) -> Strategy:
        def draw(rng: random.Random) -> float:
            x = rng.uniform(min_value, max_value)
            if width == 32:  # round-trip through float32 like hypothesis does
                x = struct.unpack("f", struct.pack("f", x))[0]
                x = min(max(x, min_value), max_value)
            return x
        return Strategy(draw)

    @staticmethod
    def text(alphabet: str | None = None, min_size: int = 0,
             max_size: int | None = None) -> Strategy:
        chars = alphabet or (string.ascii_letters + string.digits +
                             " .,;:!?\n\t'\"-_/\\()[]{}éüλ中")
        hi = max_size if max_size is not None else min_size + 40
        return Strategy(lambda rng: "".join(
            rng.choice(chars) for _ in range(rng.randint(min_size, hi))))

    @staticmethod
    def binary(min_size: int = 0, max_size: int | None = None) -> Strategy:
        hi = max_size if max_size is not None else min_size + 64
        return Strategy(lambda rng: bytes(
            rng.getrandbits(8) for _ in range(rng.randint(min_size, hi))))

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0,
              max_size: int | None = None) -> Strategy:
        hi = max_size if max_size is not None else min_size + 16
        return Strategy(lambda rng: [
            elements.example(rng)
            for _ in range(rng.randint(min_size, hi))])

    @staticmethod
    def tuples(*strats: Strategy) -> Strategy:
        return Strategy(lambda rng: tuple(s.example(rng) for s in strats))


class settings:
    """Profile registry — only max_examples matters to the shim."""

    _profiles: dict[str, dict[str, Any]] = {"default": {"max_examples": 25}}
    _current: dict[str, Any] = _profiles["default"]

    def __init__(self, **kwargs: Any) -> None:  # used as a decorator arg bag
        self.kwargs = kwargs

    def __call__(self, fn: Callable) -> Callable:
        return fn

    @classmethod
    def register_profile(cls, name: str, **kwargs: Any) -> None:
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name: str) -> None:
        cls._current = cls._profiles[name]

    @classmethod
    def max_examples(cls) -> int:
        return int(cls._current.get("max_examples") or 25)


def given(*strats: Strategy) -> Callable:
    """Run the test once per drawn example, deterministically seeded."""

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any) -> None:
            rng = random.Random(f"repro:{fn.__module__}.{fn.__qualname__}")
            for _ in range(settings.max_examples()):
                drawn = tuple(s.example(rng) for s in strats)
                fn(*args, *drawn, **kwargs)
        # hide the drawn parameters from pytest's fixture resolution,
        # like hypothesis's own wrapper does
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
