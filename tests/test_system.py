"""End-to-end behaviour: the paper's word-count workflow (Fig. 5) through the
full Coordinator pipeline, host and device engines agreeing with the oracle
and with each other."""

from collections import Counter

import numpy as np
import pytest

from repro.core import (Coordinator, JobState, MemoryStore, MetadataStore,
                        make_wordcount_job, read_final_output)
from repro.core.mapreduce import wordcount_map_factory
from repro.data.pipeline import synth_corpus
from repro.pipeline import Pipeline


@pytest.fixture(scope="module")
def corpus():
    return synth_corpus(30_000, vocab_words=200, seed=7)


@pytest.fixture()
def stack(corpus):
    store = MemoryStore()
    store.put("input/corpus.txt", corpus.encode())
    meta = MetadataStore()
    coord = Coordinator(store, meta)
    return store, meta, coord


def test_wordcount_end_to_end(stack, corpus):
    store, meta, coord = stack
    cfg = make_wordcount_job(n_mappers=4, n_reducers=2)
    report = coord.run_job(cfg)
    assert report.state == JobState.DONE, report.error
    out = read_final_output(cfg, store)
    assert out == dict(Counter(corpus.split()))


def test_wordcount_many_workers(stack, corpus):
    store, meta, coord = stack
    cfg = make_wordcount_job(n_mappers=7, n_reducers=3)
    report = coord.run_job(cfg)
    assert report.state == JobState.DONE
    assert read_final_output(cfg, store) == dict(Counter(corpus.split()))


def test_map_only_workflow(stack, corpus):
    """§III-B: Reducer and Finalizer are optional."""
    store, meta, coord = stack
    cfg = make_wordcount_job(n_mappers=3, n_reducers=0, run_finalizer=False)
    report = coord.run_job(cfg)
    assert report.state == JobState.DONE
    spills = store.list_objects(f"jobs/{cfg.job_id}/intermediate/")
    assert spills, "map-only workflow must leave intermediate spills"


def test_combiner_equivalence(stack, corpus):
    """Combiner on/off must not change results, only spill volume."""
    store, meta, coord = stack
    cfg_on = make_wordcount_job(n_mappers=4, n_reducers=2, run_combiner=True)
    cfg_off = make_wordcount_job(n_mappers=4, n_reducers=2, run_combiner=False)
    r_on = coord.run_job(cfg_on)
    r_off = coord.run_job(cfg_off)
    assert r_on.state == r_off.state == JobState.DONE
    assert read_final_output(cfg_on, store) == read_final_output(cfg_off, store)
    bytes_on = sum(t.times.bytes_out for t in r_on.task_results
                   if t.role == "mapper")
    bytes_off = sum(t.times.bytes_out for t in r_off.task_results
                    if t.role == "mapper")
    assert bytes_on < bytes_off, "combiner must reduce spill volume"


def test_host_vs_device_engine(corpus):
    """The TPU-plane engine and the container-plane engine agree."""
    words = corpus.split()
    expected = Counter(words)
    vocab = {w: i for i, w in enumerate(sorted(expected))}
    tok = np.array([vocab[w] for w in words], dtype=np.int32)
    W = 8
    n = (len(tok) + W - 1) // W * W
    toks = np.concatenate([tok, np.full(n - len(tok), -1, np.int32)])
    shard = np.stack([toks.reshape(W, -1),
                      np.ones((W, n // W), np.int32)], axis=-1)
    nb = 256
    built = (Pipeline.from_source(shards=shard)
             .map(wordcount_map_factory(nb)).reduce("sum")
             .build(num_buckets=nb, n_workers=W, backend="vmap"))
    res, _stats = built.run_batch(data=shard)
    res = np.asarray(res)
    for w, c in expected.items():
        assert res[vocab[w]] == c
