"""Shared test configuration: deterministic seeding for every test.

Property tests draw from seeded strategies already; this fixture pins the
global numpy/python RNGs too, so tests that use ``np.random`` directly are
reproducible regardless of execution order.
"""

import random

import numpy as np
import pytest

DEFAULT_SEED = 0


@pytest.fixture(autouse=True)
def _deterministic_seed():
    random.seed(DEFAULT_SEED)
    np.random.seed(DEFAULT_SEED)
    yield
