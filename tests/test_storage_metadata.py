"""Storage (S3 stand-in) and metadata (Redis stand-in) layer semantics."""


import pytest

from repro.core.metadata import MetadataStore
from repro.core.storage import (FileStore, MemoryStore, MultipartWriter,
                                NoSuchKey, StorageError, parse_spill_key,
                                spill_key)


@pytest.fixture(params=["memory", "file"])
def store(request, tmp_path):
    if request.param == "memory":
        return MemoryStore()
    return FileStore(str(tmp_path / "bucket"))


def test_put_get_head_delete(store):
    store.put("a/b", b"hello world")
    assert store.get("a/b") == b"hello world"
    assert store.head("a/b").size == 11
    assert store.exists("a/b")
    store.delete("a/b")
    assert not store.exists("a/b")
    with pytest.raises(NoSuchKey):
        store.get("a/b")


def test_ranged_get(store):
    store.put("k", bytes(range(100)))
    assert store.get("k", (10, 20)) == bytes(range(10, 20))
    assert store.get("k", (90, 200)) == bytes(range(90, 100))


def test_list_prefix_and_total_size(store):
    store.put("in/a", b"x" * 10)
    store.put("in/b", b"y" * 20)
    store.put("out/c", b"z")
    assert [m.key for m in store.list_objects("in/")] == ["in/a", "in/b"]
    assert store.total_size("in/") == 30


def test_multipart_upload(store):
    w = MultipartWriter(part_size=8)
    w.write(b"0123456789abcdef")
    w.write(b"ghij")
    parts = w.finish()
    assert [len(p) for p in parts] == [8, 8, 4]
    store.multipart_upload("mp", parts, part_size=8)
    assert store.get("mp") == b"0123456789abcdefghij"


def test_multipart_rejects_short_part(store):
    with pytest.raises(StorageError):
        store.multipart_upload("mp", [b"ab", b"c"], part_size=8)


def test_stream_concat_no_append_semantics(store):
    """Finalizer primitive: S3 cannot append, so concat makes a new object."""
    store.put("p/0", b"aaa")
    store.put("p/1", b"bbb")
    n = store.stream_concat("final", ["p/0", "p/1"], chunk_size=2)
    assert n == 6
    assert store.get("final") == b"aaabbb"


def test_spill_key_roundtrip():
    k = spill_key("job1", 3, 7, 11)
    assert k.endswith("spill-3-7-11")
    assert parse_spill_key(k) == (3, 7, 11)


def test_file_store_persistence(tmp_path):
    root = str(tmp_path / "bucket")
    FileStore(root).put("x/y", b"data")
    assert FileStore(root).get("x/y") == b"data"   # new instance sees it


# -- metadata -----------------------------------------------------------------

def test_metadata_kv_hash_incr():
    m = MetadataStore()
    m.set("k", {"a": 1})
    assert m.get("k") == {"a": 1}
    m.hset("h", "f1", 10)
    m.hset("h", "f2", 20)
    assert m.hgetall("h") == {"f1": 10, "f2": 20}
    assert m.incr("c") == 1 and m.incr("c", 2) == 3
    assert m.keys("k") == ["k"]


def test_metadata_snapshot_restore(tmp_path):
    p = str(tmp_path / "meta.json")
    m = MetadataStore(persist_path=p)
    m.set("job:1:state", "MAPPING")
    m.incr("job:1:mapper:done", 3)
    m.snapshot()
    m2 = MetadataStore(persist_path=p)       # restart
    assert m2.get("job:1:state") == "MAPPING"
    assert m2.get("job:1:mapper:done") == 3


def test_metadata_watch():
    m = MetadataStore()
    seen = []
    m.watch(lambda k, v: seen.append((k, v)))
    m.set("x", 1)
    assert seen == [("x", 1)]
