"""Checkpoint layer (sharded/async/elastic/CRC) and optimizer substrate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.core.storage import MemoryStore
from repro.optim import AdamW, apply_updates
from repro.optim.compression import (compress_int8, compressed_psum,
                                     decompress_int8)
from repro.optim.schedule import cosine_schedule


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (64, 32)),
            "b": jnp.zeros((32,)),
            "nested": {"emb": jax.random.normal(k, (100, 16)),
                       "step": jnp.int32(7)}}


def test_checkpoint_roundtrip():
    store = MemoryStore()
    tree = _tree()
    save_checkpoint(store, "ckpt", 10, tree, n_shards=4)
    restored, step = restore_checkpoint(store, "ckpt", tree)
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_elastic_shard_counts():
    """Written by N workers, restored regardless of N — the re-mesh path."""
    store = MemoryStore()
    tree = _tree(1)
    save_checkpoint(store, "ckpt", 5, tree, n_shards=7)
    restored, _ = restore_checkpoint(store, "ckpt", tree)
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.asarray(restored["w"]))


def test_checkpoint_crc_detects_corruption():
    store = MemoryStore()
    save_checkpoint(store, "ckpt", 1, _tree(), n_shards=2)
    key = [m.key for m in store.list_objects("ckpt/")
           if "shard-0" in m.key][0]
    store.put(key, b"corrupted bytes")
    with pytest.raises(IOError):
        restore_checkpoint(store, "ckpt", _tree())


def test_latest_step_and_manifest_commit_point():
    store = MemoryStore()
    save_checkpoint(store, "ckpt", 10, _tree())
    save_checkpoint(store, "ckpt", 20, _tree())
    assert latest_step(store, "ckpt") == 20
    # delete a manifest → that step is invisible (commit-point semantics)
    store.delete("ckpt/step-00000020/MANIFEST.json")
    assert latest_step(store, "ckpt") == 10


def test_async_checkpointer():
    store = MemoryStore()
    ck = AsyncCheckpointer(store, "ckpt", n_shards=2, keep=2)
    for s in (1, 2, 3):
        ck.save(s, _tree(s))
    ck.wait()
    assert latest_step(store, "ckpt") == 3
    # GC keeps only `keep` checkpoints
    steps = {int(m.key.split("step-")[1][:8])
             for m in store.list_objects("ckpt/") if "step-" in m.key}
    assert steps == {2, 3}
    ck.close()


# -- optimizer ------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    def loss(p):
        return jnp.sum(p["x"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        upd, state, _ = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-3


def test_grad_clipping_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"x": jnp.full(4, 1e6)}
    _, _, stats = opt.update(huge, state, params)
    assert float(stats["grad_norm"]) > 1e5   # reported pre-clip


def test_cosine_schedule_shape():
    fn = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(fn(jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_int8_compression_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, scale = compress_int8(x)
    y = decompress_int8(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(x - y))) <= float(scale) * 0.5 + 1e-6


def test_compressed_psum_approximates_mean():
    """int8 gradient all-reduce over a vmap axis ≈ exact mean."""
    g = jax.random.normal(jax.random.PRNGKey(1), (8, 256))

    def worker(x):
        out = compressed_psum({"g": x}, "w")
        return out["g"]

    got = jax.vmap(worker, axis_name="w")(g)
    want = jnp.mean(g, axis=0)
    # every worker sees the same reduced value
    np.testing.assert_allclose(got[0], got[3], rtol=0, atol=0)
    err = float(jnp.max(jnp.abs(got[0] - want)))
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert err <= scale * 1.01
